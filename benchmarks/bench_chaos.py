"""Chaos soak bench: latency tail and recovery under socket faults (PR 9).

Every other bench measures the network tier on a clean loopback. This
one puts a seeded `ChaosProxy` in front of every `FViewServer` and
measures what the paper's tail-latency story costs when the network
misbehaves — the disaggregated-memory pitch dies if a flaky link turns
p99 unbounded. Three phases per node count, every round byte-checked
against the healthy reference (a fast wrong answer is not a recovery):

  clean      pass-through proxies: baseline p50/p99 round latency for
             mixed selection + group-aggregate scatter rounds.
  soak       jittered delivery + frame corruption + duplicated frames.
             Corrupt frames fail the CRC typed and failover reroutes;
             rounds retry through typed errors only. Reported
             chaos_tail_ratio = p99(soak) / p50(clean) is the CI guard
             (`check_regression --max-chaos-ratio`): chaos may cost
             retries, never an unbounded tail.
  degraded   ONE node slowed (per-frame delay), NOT killed — the
             gray-failure case. Hedged failover re-issues the slow
             primary's partitions on the cyclic replica after
             `hedge_after_s`; mid-flight strikes escalate the laggard
             out of the routing set. recovery_frac = degraded/clean
             throughput must clear 0.9 (`--min-chaos-recovery`): a
             slow node costs its share of the cluster, not the tail.

Fault logs: with FARVIEW_NET_LOG_DIR set, every proxy's injection log
is written as JSON-lines (`chaos-nodeN.jsonl`) — the CI lane uploads
them as the failure artifact, and the seed makes any run replayable.

Standalone:  python -m benchmarks.bench_chaos --json BENCH.json --seed 7
"""
from __future__ import annotations

import os
import time

import numpy as np

from benchmarks import common
from repro.core import operators as op
from repro.core.cluster import FarCluster
from repro.core.table import Column, FTable
from repro.distributed.health import DEAD
from repro.net.chaos import FaultSchedule, proxied_endpoints
from repro.net.client import RemoteNodeHandle
from repro.net.server import FViewServer

COLS = tuple(Column(f"c{i}", "i32" if i == 0 else "f32") for i in range(6))
N_KEYS = 64
CAPACITY = 128 * 2**20

PIPES = (
    (op.Select((op.Predicate("c1", "<", 0.2),)),),
    (op.GroupBy("c0", ("c1", "c2"), n_buckets=256),),
)

SOAK = FaultSchedule(jitter_s=0.001, corrupt_prob=0.02,
                     duplicate_prob=0.03)


def _data(rng, keys):
    d = {"c0": np.asarray(keys, np.int32)}
    for i in range(1, 6):
        # integer-valued floats: merges stay exact under any order
        d[f"c{i}"] = rng.integers(-50, 50, len(keys)).astype(np.float32)
    return d


def _round(cl, cqp, ct):
    pends = [cl.submit_request(cqp, ct, pipe) for pipe in PIPES]
    return [p.wait().finalize() for p in pends]


def _assert_parity(results, ref):
    for res, r in zip(results, ref):
        if res.kind == "groups":
            assert set(res.groups) == set(r.groups)
            for key in r.groups:
                for a, b in zip(r.groups[key], res.groups[key]):
                    np.testing.assert_array_equal(np.asarray(a),
                                                  np.asarray(b))
        else:
            assert res.count == r.count
            np.testing.assert_array_equal(np.asarray(res.rows),
                                          np.asarray(r.rows))


def _percentile(xs, q):
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(q * len(xs)))]


def _measure(cl, cqp, ct, rounds, ref, *, retry=0):
    """Per-round wall times; typed faults cost a retry (revive + rerun,
    the retry time stays IN the round's clock — tails are honest).
    Returns (times, retries_used)."""
    times, retries = [], 0
    for _ in range(rounds):
        t0 = time.perf_counter()
        for attempt in range(retry + 1):
            try:
                results = _round(cl, cqp, ct)
                break
            except Exception:       # noqa: BLE001 - typed fault: reroute
                if attempt == retry:
                    raise
                retries += 1
                for i in range(cl.n_nodes):
                    cl.health.revive(i)
                time.sleep(0.06)    # reconnect breakers reach HALF_OPEN
        times.append(time.perf_counter() - t0)
        _assert_parity(results, ref)
    return times, retries


def run(seed: int = 0) -> None:
    import gc

    q = common.quick()
    n = 1 << (13 if q else 15)
    rounds = 5 if q else 20
    node_counts = (2,) if q else (2, 4)
    log_dir = os.environ.get("FARVIEW_NET_LOG_DIR")

    rng = np.random.default_rng(seed)
    keys = rng.integers(0, N_KEYS, n).astype(np.int32)
    words = FTable("t", COLS, n_rows=n).encode(_data(rng, keys))

    for k in node_counts:
        gc.collect()
        servers = [FViewServer.start_in_thread(
            node_id=i, capacity_bytes=CAPACITY) for i in range(k)]
        proxies, endpoints = proxied_endpoints(servers, seed=seed)
        handles = [RemoteNodeHandle(h, p, node_id=i, timeout_s=60.0,
                                    reconnect_backoff_s=0.02,
                                    reconnect_reset_s=0.05)
                   for i, (h, p) in enumerate(endpoints)]
        cl = FarCluster(nodes=handles, replicas=2, dead_after=2,
                        slow_after_s=0.1, hedge_after_s=0.1)
        cqp = cl.open_connection()
        try:
            ct = cl.alloc_table_mem(cqp, FTable("t", COLS, n_rows=n),
                                    partitioner="hash", keys=keys)
            cl.table_write(cqp, ct, words)
            ref = _round(cl, cqp, ct)   # warmup + parity reference

            # ---- clean baseline
            times, _ = _measure(cl, cqp, ct, rounds, ref)
            clean_p50 = _percentile(times, 0.50)
            clean_thru = len(PIPES) * n / clean_p50
            common.row("chaos", f"clean_{k}nodes", clean_p50 * 1e6,
                       nodes=k, rows=n, replicas=2, rounds=rounds,
                       p99_us=round(_percentile(times, 0.99) * 1e6, 1),
                       mrows_per_s=round(clean_thru / 1e6, 2))

            # ---- seeded soak: corruption, duplicates, jitter
            for p in proxies:
                p.set_schedule(SOAK)
            times, retries = _measure(cl, cqp, ct, rounds, ref, retry=8)
            soak_p99 = _percentile(times, 0.99)
            faults = sum(len(p.fault_log) for p in proxies)
            common.row("chaos", f"soak_{k}nodes",
                       _percentile(times, 0.50) * 1e6,
                       nodes=k, rows=n, replicas=2, rounds=rounds,
                       seed=seed, faults=faults, retries=retries,
                       p99_us=round(soak_p99 * 1e6, 1),
                       chaos_tail_ratio=round(soak_p99 / clean_p50, 2))

            # ---- gray failure: slow ONE node, never kill it
            for p in proxies:
                p.set_schedule(FaultSchedule())
            for i in range(cl.n_nodes):
                cl.health.revive(i)
            victim = k - 1
            proxies[victim].set_schedule(FaultSchedule(delay_s=0.25))
            # detection: hedges answer each round while slow drains and
            # mid-flight strikes escalate the laggard out of the routing
            # set (dead_after=2 -> typically 2 rounds, bounded at 6)
            for _ in range(6):
                _measure(cl, cqp, ct, 1, ref, retry=8)
                if cl.health.state(victim) == DEAD:
                    break
            # fence the detected node: cut its stalled backlog so its
            # drain lock frees — steady state, not the detection bill,
            # is what recovery_frac measures
            proxies[victim].drop_all()
            time.sleep(0.1)
            times, retries = _measure(cl, cqp, ct, rounds, ref, retry=8)
            deg_p50 = _percentile(times, 0.50)
            deg_thru = len(PIPES) * n / deg_p50
            common.row("chaos", f"degraded_{k}nodes", deg_p50 * 1e6,
                       nodes=k, rows=n, replicas=2, rounds=rounds,
                       victim=victim, retries=retries,
                       p99_us=round(_percentile(times, 0.99) * 1e6, 1),
                       mrows_per_s=round(deg_thru / 1e6, 2),
                       recovery_frac=round(deg_thru / clean_thru, 3))

            if log_dir:
                os.makedirs(log_dir, exist_ok=True)
                for i, p in enumerate(proxies):
                    p.save_fault_log(os.path.join(
                        log_dir, f"chaos-{k}nodes-node{i}.jsonl"))
        finally:
            for h in handles:
                try:
                    h.close()
                except Exception:   # noqa: BLE001
                    pass
            for p in proxies:
                try:
                    p.stop_thread()
                except Exception:   # noqa: BLE001
                    pass
            for s in servers:
                try:
                    s.stop_thread()
                except Exception:   # noqa: BLE001
                    pass
        del cl, cqp, ct


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=0,
                    help="chaos schedule seed (replayable fault runs)")
    args = ap.parse_args()
    if args.quick:
        common.QUICK = True
    run(seed=args.seed)
    common.print_csv()
    if args.json:
        common.write_json(args.json)


if __name__ == "__main__":
    main()
