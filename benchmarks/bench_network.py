"""Network tier under fan-in: latency vs connection count + shed behavior.

One `FViewServer` (thread-hosted, same binary the CI server-smoke lane
runs as a subprocess) and an asyncio load generator speaking raw
`net/wire.py` frames — no client-library batching, so what is measured
is the server's own multiplexing: admission, the 2 ms coalescing
window, ONE worker-thread flush per drain round, completion-order
replies.

Two phases:

  submit_cC    C concurrent connections over one shared table, each
               issuing sequential SUBMITs (selection pipeline) and
               awaiting its RESULT. Reported us_per_call is the p50
               request latency, plus p99_us — the fan-in curve
               p99(C)/p50(1) is the CI guard
               (`check_regression --max-p99-ratio`): connection count
               must buy throughput, not unbounded tail latency. Every
               request in this phase must complete (depth 4096 admits
               the whole sweep); a shed here fails the bench.

  overload_cC  a deliberately tiny admission bound (depth 64), every
               connection bursting SUBMITs without awaiting. The
               contract under load: shed requests get an immediate
               typed OVERLOADED frame (never a hang, never a
               half-run), accepted requests ALL complete, and
               shed + completed == sent exactly.

Full mode sweeps 1/64/256/1024 connections (the 1k+ acceptance row);
quick mode keeps 1 and 256 for the regression guard.

Standalone:  python -m benchmarks.bench_network --json BENCH.json
"""
from __future__ import annotations

import asyncio
import time

import numpy as np

from benchmarks import common
from repro.core import operators as op
from repro.core.table import Column, FTable
from repro.net import wire
from repro.net.server import FViewServer

N = 4096
COLS = tuple(Column(f"c{i}", "i32" if i == 0 else "f32") for i in range(6))
# ~5% selectivity: responses stay small, the wire cost is the protocol,
# not a bulk row ship
PIPE = (op.Select((op.Predicate("c1", "<", -45.0),)),)
CONNECT_PARALLELISM = 128


def _make_words(rng) -> np.ndarray:
    d = {"c0": rng.integers(0, 13, N).astype(np.int32)}
    for i in range(1, 6):
        d[f"c{i}"] = rng.integers(-50, 50, N).astype(np.float32)
    return FTable("t", COLS, n_rows=N).encode(d)


async def _read_frame(reader):
    hdr = await reader.readexactly(wire.HEADER_SIZE)
    ftype, req_id, length = wire.parse_header(hdr)
    body = await reader.readexactly(length) if length else b""
    trailer = await reader.readexactly(wire.TRAILER_SIZE)
    wire.check_crc(hdr, body, trailer)
    return ftype, req_id, (wire.decode_value(body) if length else None)


async def _open_conn(host, port, vqp_out):
    reader, writer = await asyncio.open_connection(host, port)
    writer.write(wire.encode_frame(wire.HELLO, 0,
                                   {"version": wire.VERSION}))
    await writer.drain()
    ftype, _, _ = await _read_frame(reader)
    assert ftype == wire.HELLO_OK
    writer.write(wire.encode_frame(wire.OPEN_QP, 1))
    await writer.drain()
    _, _, payload = await _read_frame(reader)
    vqp_out.append(payload["qp"])
    return reader, writer


def _submit_payload(vqp: int, table_id: int) -> dict:
    return {"qp": vqp, "table_id": table_id, "pipeline": PIPE,
            "lengths": None, "strings": None, "row_ids": None}


async def _latency_client(host, port, table_id, n_reqs, latencies):
    vqp = []
    reader, writer = await _open_conn(host, port, vqp)
    payload = _submit_payload(vqp[0], table_id)
    try:
        for i in range(n_reqs):
            t0 = time.perf_counter()
            writer.write(wire.encode_frame(wire.SUBMIT, 2 + i, payload))
            await writer.drain()
            ftype, _, _ = await _read_frame(reader)
            latencies.append(time.perf_counter() - t0)
            if ftype != wire.RESULT:
                raise RuntimeError(
                    f"latency sweep expected RESULT, got "
                    f"{wire.FRAME_NAMES.get(ftype, ftype)}")
    finally:
        writer.close()


async def _burst_client(host, port, table_id, burst, counts):
    vqp = []
    reader, writer = await _open_conn(host, port, vqp)
    payload = _submit_payload(vqp[0], table_id)
    try:
        for i in range(burst):
            writer.write(wire.encode_frame(wire.SUBMIT, 2 + i, payload))
        await writer.drain()
        for _ in range(burst):
            ftype, _, _ = await _read_frame(reader)
            if ftype == wire.RESULT:
                counts["completed"] += 1
            elif ftype == wire.OVERLOADED:
                counts["shed"] += 1
            else:
                raise RuntimeError(
                    f"burst expected RESULT/OVERLOADED, got "
                    f"{wire.FRAME_NAMES.get(ftype, ftype)}")
    finally:
        writer.close()


async def _fan_out(factory, n_conns):
    """Run one client task per connection, opening conns in bounded
    parallel waves so 1k+ connects don't SYN-storm the accept loop."""
    sem = asyncio.Semaphore(CONNECT_PARALLELISM)

    async def _one(i):
        async with sem:
            return await factory(i)

    results = await asyncio.gather(*(_one(i) for i in range(n_conns)))
    return results


def _percentile(xs, q):
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(q * len(xs)))]


def _alloc_shared_table(server, words) -> int:
    """Alloc + write the one table every connection hammers (in-process:
    the bench owns the server, so it uses the node directly)."""
    ft = FTable("t", COLS, n_rows=N)
    server.node.pool.alloc_table(ft)
    server.node.pool.write_table(ft, words)
    server._tables[ft.table_id] = ft
    return ft.table_id


def run() -> None:
    q = common.quick()
    conn_counts = (1, 256) if q else (1, 64, 256, 1024)
    rng = np.random.default_rng(0)
    words = _make_words(rng)

    # ---- phase 1: latency vs fan-in (no shedding permitted) ----------
    server = FViewServer.start_in_thread(max_queue_depth=4096,
                                         max_conns=8192)
    table_id = _alloc_shared_table(server, words)
    host, port = server.host, server.port
    try:
        for n_conns in conn_counts:
            n_reqs = (max(4, 512 // n_conns) if q
                      else max(8, 2048 // n_conns))
            # warmup at THIS fan-in: the first rounds pay the jit
            # compile per stack-size bucket; keep them out of p50/p99
            asyncio.run(_fan_out(
                lambda i: _latency_client(host, port, table_id, 2, []),
                n_conns))
            latencies: list[float] = []
            t0 = time.perf_counter()
            asyncio.run(_fan_out(
                lambda i: _latency_client(host, port, table_id, n_reqs,
                                          latencies), n_conns))
            wall = time.perf_counter() - t0
            total = n_conns * n_reqs
            common.row("network", f"submit_c{n_conns}",
                       _percentile(latencies, 0.50) * 1e6,
                       connections=n_conns, reqs=total,
                       p99_us=round(_percentile(latencies, 0.99) * 1e6, 1),
                       reqs_per_s=round(total / wall, 1), shed=0)
    finally:
        server.stop_thread()

    # ---- phase 2: overload -> typed shed, accepted all complete ------
    over = FViewServer.start_in_thread(max_queue_depth=64, max_conns=8192)
    table_id = _alloc_shared_table(over, words)
    host, port = over.host, over.port
    try:
        n_conns = conn_counts[-1]
        burst = 4 if q else 8
        counts = {"completed": 0, "shed": 0}
        t0 = time.perf_counter()
        asyncio.run(_fan_out(
            lambda i: _burst_client(host, port, table_id, burst, counts),
            n_conns))
        wall = time.perf_counter() - t0
        sent = n_conns * burst
        assert counts["completed"] + counts["shed"] == sent, counts
        assert counts["shed"] > 0, "overload phase never hit the bound"
        assert counts["completed"] > 0, "admission starved everyone"
        common.row("network", f"overload_c{n_conns}", wall / sent * 1e6,
                   connections=n_conns, reqs=sent,
                   completed=counts["completed"], shed=counts["shed"],
                   shed_frac=round(counts["shed"] / sent, 3))
    finally:
        over.stop_thread()


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.quick:
        common.QUICK = True
    run()
    common.print_csv()
    if args.json:
        common.write_json(args.json)


if __name__ == "__main__":
    main()
