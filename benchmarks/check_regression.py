"""Benchmark regression guard: diff a fresh --json run against a committed
BENCH_*.json and fail on a >THRESHOLD x p50 regression in any shared key.

    python -m benchmarks.check_regression current.json            # auto-pick
    python -m benchmarks.check_regression current.json --against BENCH_PR4.json

Shared key = (bench, name) present in both files AND whose size context
matches: rows whose `rows` / `nodes` / `clients` fields differ are skipped
(a --quick run shrinks problem sizes, so comparing them against full-mode
numbers would be apples-to-oranges, not a regression). Baselines faster
than --floor microseconds are skipped too — dispatch-overhead-sized rows
drown in scheduler noise.

Reads both JSON formats: the bare record list (<= PR 3) and the
{"meta": ..., "rows": [...]} provenance-stamped format (>= PR 4).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys

_CONTEXT_KEYS = ("rows", "nodes", "clients")
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load_rows(path: str) -> tuple[list[dict], dict]:
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, list):          # <= PR 3 bare-list format
        return data, {}
    return data.get("rows", []), data.get("meta", {})


def latest_committed_baseline(quick: "bool | None" = None) -> str | None:
    """The most recently committed BENCH_*.json (git commit date; falls
    back to lexical order when git is unavailable). When `quick` is given
    and any candidate's meta carries a matching quick flag, only matching
    candidates are considered — quick-mode CI runs compare against a
    quick-mode baseline, never against full-mode problem sizes."""
    cands = sorted(glob.glob(os.path.join(_ROOT, "BENCH_*.json")))
    if not cands:
        return None
    if quick is not None:
        matching = [p for p in cands
                    if load_rows(p)[1].get("quick") == quick]
        if matching:
            cands = matching

    def commit_ts(p: str) -> int:
        try:
            out = subprocess.run(
                ["git", "log", "-1", "--format=%ct", "--", p],
                capture_output=True, text=True, cwd=_ROOT, timeout=10)
            return int(out.stdout.strip() or 0)
        except Exception:
            return 0

    return max(cands, key=lambda p: (commit_ts(p), p))


def compare(cur_rows: list[dict], base_rows: list[dict], *,
            threshold: float, floor_us: float) -> tuple[list, list]:
    base = {(r["bench"], r["name"]): r for r in base_rows}
    checked, failed = [], []
    for r in cur_rows:
        b = base.get((r["bench"], r["name"]))
        if b is None:
            continue
        if any(k in r and k in b and r[k] != b[k] for k in _CONTEXT_KEYS):
            continue                    # different problem size: not comparable
        if b["us_per_call"] < floor_us:
            continue
        ratio = r["us_per_call"] / max(b["us_per_call"], 1e-9)
        entry = (r["bench"], r["name"], b["us_per_call"], r["us_per_call"],
                 ratio)
        checked.append(entry)
        if ratio > threshold:
            failed.append(entry)
    return checked, failed


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="fresh benchmarks.run --json output")
    ap.add_argument("--against", default=None,
                    help="baseline BENCH_*.json (default: latest committed)")
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="fail when current p50 > threshold x baseline")
    ap.add_argument("--floor", type=float, default=200.0,
                    help="skip baselines faster than this many us")
    args = ap.parse_args()

    cur_rows, cur_meta = load_rows(args.current)
    baseline = args.against or latest_committed_baseline(
        cur_meta.get("quick"))
    if baseline is None:
        print("# no committed BENCH_*.json baseline; nothing to check")
        return 0
    base_rows, base_meta = load_rows(baseline)
    print(f"# current  {args.current} (quick={cur_meta.get('quick')}, "
          f"platform={cur_meta.get('platform')})")
    print(f"# baseline {baseline} (quick={base_meta.get('quick')}, "
          f"commit={str(base_meta.get('git_commit'))[:12]})")

    checked, failed = compare(cur_rows, base_rows,
                              threshold=args.threshold, floor_us=args.floor)
    for bench, name, bus, cus, ratio in checked:
        flag = "  << REGRESSION" if ratio > args.threshold else ""
        print(f"{bench:>20s} {name:<36s} {bus:>12.1f} -> {cus:>12.1f} "
              f"({ratio:5.2f}x){flag}")
    print(f"# {len(checked)} shared keys checked, {len(failed)} regressed "
          f"(threshold {args.threshold}x, floor {args.floor}us)")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
