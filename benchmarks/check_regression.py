"""Benchmark regression guard: diff a fresh --json run against a committed
BENCH_*.json and fail on a >THRESHOLD x p50 regression in any shared key.

    python -m benchmarks.check_regression current.json            # auto-pick
    python -m benchmarks.check_regression current.json --against BENCH_PR4.json

Shared key = (bench, name) present in both files AND whose size context
matches: rows whose `rows` / `nodes` / `clients` fields differ are skipped
(a --quick run shrinks problem sizes, so comparing them against full-mode
numbers would be apples-to-oranges, not a regression). Baselines faster
than --floor microseconds are skipped too — dispatch-overhead-sized rows
drown in scheduler noise.

Reads both JSON formats: the bare record list (<= PR 3) and the
{"meta": ..., "rows": [...]} provenance-stamped format (>= PR 4).
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys

_CONTEXT_KEYS = ("rows", "nodes", "clients")
_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def load_rows(path: str) -> tuple[list[dict], dict]:
    with open(path) as f:
        data = json.load(f)
    if isinstance(data, list):          # <= PR 3 bare-list format
        return data, {}
    return data.get("rows", []), data.get("meta", {})


def latest_committed_baseline(quick: "bool | None" = None) -> str | None:
    """The most recently committed BENCH_*.json (git commit date; falls
    back to lexical order when git is unavailable). When `quick` is given
    and any candidate's meta carries a matching quick flag, only matching
    candidates are considered — quick-mode CI runs compare against a
    quick-mode baseline, never against full-mode problem sizes."""
    cands = sorted(glob.glob(os.path.join(_ROOT, "BENCH_*.json")))
    if not cands:
        return None
    if quick is not None:
        matching = [p for p in cands
                    if load_rows(p)[1].get("quick") == quick]
        if matching:
            cands = matching

    def commit_ts(p: str) -> int:
        try:
            out = subprocess.run(
                ["git", "log", "-1", "--format=%ct", "--", p],
                capture_output=True, text=True, cwd=_ROOT, timeout=10)
            return int(out.stdout.strip() or 0)
        except Exception:
            return 0

    return max(cands, key=lambda p: (commit_ts(p), p))


def compare(cur_rows: list[dict], base_rows: list[dict], *,
            threshold: float, floor_us: float) -> tuple[list, list]:
    base = {(r["bench"], r["name"]): r for r in base_rows}
    checked, failed = [], []
    for r in cur_rows:
        b = base.get((r["bench"], r["name"]))
        if b is None:
            continue
        if any(k in r and k in b and r[k] != b[k] for k in _CONTEXT_KEYS):
            continue                    # different problem size: not comparable
        if b["us_per_call"] < floor_us:
            continue
        ratio = r["us_per_call"] / max(b["us_per_call"], 1e-9)
        entry = (r["bench"], r["name"], b["us_per_call"], r["us_per_call"],
                 ratio)
        checked.append(entry)
        if ratio > threshold:
            failed.append(entry)
    return checked, failed


def check_failover(cur_rows: list[dict], *, min_recovery: float,
                   min_dip: float) -> list[str]:
    """PR 6 chaos guards, checked against the CURRENT run only (no
    baseline needed): every failover row that reports a recovery_frac
    (post-heal throughput / pre-kill) must clear `min_recovery`, and
    every dip_frac (during-kill throughput / pre-kill) must clear
    `min_dip` — the cluster degrades under a node kill, it never
    stalls. Returns human-readable failure lines."""
    failures = []
    for r in cur_rows:
        if r.get("bench") != "failover":
            continue
        rec = r.get("recovery_frac")
        if rec is not None and rec < min_recovery:
            failures.append(
                f"failover {r['name']}: recovery_frac {rec:.3f} "
                f"< {min_recovery} (post-heal throughput did not recover)")
        dip = r.get("dip_frac")
        if dip is not None and dip < min_dip:
            failures.append(
                f"failover {r['name']}: dip_frac {dip:.3f} < {min_dip} "
                f"(cluster stalled during the kill)")
    return failures


def check_network(cur_rows: list[dict], *,
                  max_p99_ratio: float) -> list[str]:
    """PR 8 fan-in guard, checked against the CURRENT run only: the p99
    request latency at the highest measured connection count up to 256
    must stay within `max_p99_ratio` x the single-connection p50 —
    connection count buys throughput, never an unbounded tail (an
    event-loop stall or a broken batching window shows up here as a
    runaway ratio). Also re-checks the shed invariants the bench
    asserts: the latency sweep sheds nothing, the overload phase sheds
    typed (shed > 0) without starving (completed > 0)."""
    failures = []
    subs = {r["connections"]: r for r in cur_rows
            if r.get("bench") == "network"
            and r.get("name", "").startswith("submit_c")}
    base = subs.get(1)
    fan_in = [c for c in subs if 1 < c <= 256]
    if base is not None and fan_in:
        c = max(fan_in)
        p99 = subs[c].get("p99_us")
        p50_1 = base["us_per_call"]
        if p99 is not None and p50_1 > 0:
            ratio = p99 / p50_1
            if ratio > max_p99_ratio:
                failures.append(
                    f"network submit_c{c}: p99 {p99:.0f}us is "
                    f"{ratio:.1f}x the 1-conn p50 ({p50_1:.0f}us), over "
                    f"the {max_p99_ratio}x bound (tail latency collapse)")
    for r in subs.values():
        if r.get("shed", 0):
            failures.append(
                f"network {r['name']}: {r['shed']} sheds in the latency "
                f"sweep (admission bit under its own depth)")
    for r in cur_rows:
        if (r.get("bench") == "network"
                and r.get("name", "").startswith("overload_")):
            if not r.get("shed"):
                failures.append(
                    f"network {r['name']}: overload phase shed nothing "
                    f"(the admission bound never engaged)")
            if not r.get("completed"):
                failures.append(
                    f"network {r['name']}: nothing completed under "
                    f"overload (admission starved every tenant)")
    return failures


def check_chaos(cur_rows: list[dict], *, max_chaos_ratio: float,
                min_chaos_recovery: float) -> list[str]:
    """PR 9 chaos-tail guards, checked against the CURRENT run only:
    every chaos row reporting a chaos_tail_ratio (p99 under the seeded
    fault soak / clean p50) must stay under `max_chaos_ratio` — faults
    may cost retries, never an unbounded tail — and every degraded-node
    row's recovery_frac (hedged throughput with one slowed-not-killed
    node / clean) must clear `min_chaos_recovery`: a gray-failing node
    costs its share of the cluster, not the tail."""
    failures = []
    for r in cur_rows:
        if r.get("bench") != "chaos":
            continue
        ratio = r.get("chaos_tail_ratio")
        if ratio is not None and ratio > max_chaos_ratio:
            failures.append(
                f"chaos {r['name']}: chaos_tail_ratio {ratio:.2f} > "
                f"{max_chaos_ratio} (p99 under faults ran away from the "
                f"clean p50)")
        rec = r.get("recovery_frac")
        if rec is not None and rec < min_chaos_recovery:
            failures.append(
                f"chaos {r['name']}: recovery_frac {rec:.3f} < "
                f"{min_chaos_recovery} (hedging did not route around "
                f"the degraded node)")
    return failures


def check_tiering(cur_rows: list[dict], *, min_capacity: float,
                  max_cold_read_frac: float,
                  max_hot_ratio: float) -> list[str]:
    """PR 10 tiering guards, checked against the CURRENT run only:
    demoting the analytics table must buy at least `min_capacity`x
    effective capacity (logical bytes served per physical DRAM byte); a
    cold scan must read at most `max_cold_read_frac` of the hot scan's
    bytes (the fused decompress runs off the COMPRESSED frames) while
    shipping byte-identical results (`shipped_delta` == 0); the
    demote->promote round-trip must leave the hot p50 within
    `max_hot_ratio`x of the original; and a warm client-cache read must
    ship ZERO bytes with a perfect hit rate."""
    failures = []
    for r in cur_rows:
        if r.get("bench") != "tiering":
            continue
        cap = r.get("effective_capacity")
        if cap is not None and cap < min_capacity:
            failures.append(
                f"tiering {r['name']}: effective_capacity {cap:.2f}x < "
                f"{min_capacity}x (cold compression bought too little)")
        frac = r.get("cold_read_frac")
        if frac is not None and frac > max_cold_read_frac:
            failures.append(
                f"tiering {r['name']}: cold_read_frac {frac:.3f} > "
                f"{max_cold_read_frac} (cold scan did not measurably "
                f"cut read bytes)")
        if r.get("shipped_delta"):
            failures.append(
                f"tiering {r['name']}: shipped_delta "
                f"{r['shipped_delta']} != 0 (cold results are not "
                f"byte-identical to hot)")
        ratio = r.get("hot_p50_ratio")
        if ratio is not None and ratio > max_hot_ratio:
            failures.append(
                f"tiering {r['name']}: hot_p50_ratio {ratio:.2f}x > "
                f"{max_hot_ratio}x (the tier round-trip taxed the hot "
                f"path)")
        if r.get("warm_shipped_bytes"):
            failures.append(
                f"tiering {r['name']}: warm cache read shipped "
                f"{r['warm_shipped_bytes']} bytes (a hit must move "
                f"nothing)")
        hf = r.get("hit_frac")
        if hf is not None and hf < 1.0:
            failures.append(
                f"tiering {r['name']}: hit_frac {hf:.3f} < 1.0 (warm "
                f"reads missed the client cache)")
    return failures


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("current", help="fresh benchmarks.run --json output")
    ap.add_argument("--against", default=None,
                    help="baseline BENCH_*.json (default: latest committed)")
    ap.add_argument("--threshold", type=float, default=2.0,
                    help="fail when current p50 > threshold x baseline")
    ap.add_argument("--floor", type=float, default=200.0,
                    help="skip baselines faster than this many us")
    ap.add_argument("--min-recovery", type=float, default=0.75,
                    help="fail when a failover row's post-heal throughput "
                         "recovers to less than this fraction of pre-kill")
    ap.add_argument("--min-dip", type=float, default=0.05,
                    help="fail when during-kill throughput drops below "
                         "this fraction of pre-kill (stall, not a dip)")
    ap.add_argument("--max-p99-ratio", type=float, default=500.0,
                    help="fail when the network bench's p99 at the "
                         "highest <=256-connection fan-in exceeds this "
                         "multiple of the 1-connection p50")
    ap.add_argument("--max-chaos-ratio", type=float, default=50.0,
                    help="fail when a chaos soak row's p99 exceeds this "
                         "multiple of the clean p50 (bounded tail under "
                         "seeded socket faults)")
    ap.add_argument("--min-chaos-recovery", type=float, default=0.9,
                    help="fail when hedged throughput with one degraded "
                         "(slowed, not killed) node recovers to less "
                         "than this fraction of clean")
    ap.add_argument("--min-capacity", type=float, default=1.5,
                    help="fail when the tiering bench's effective "
                         "capacity multiplier falls below this")
    ap.add_argument("--max-cold-read-frac", type=float, default=0.9,
                    help="fail when a cold scan reads more than this "
                         "fraction of the hot scan's bytes")
    ap.add_argument("--max-hot-ratio", type=float, default=2.0,
                    help="fail when the post-promote hot scan p50 "
                         "exceeds this multiple of the original hot p50")
    args = ap.parse_args()

    cur_rows, cur_meta = load_rows(args.current)
    chaos_failures = check_failover(cur_rows, min_recovery=args.min_recovery,
                                    min_dip=args.min_dip)
    n_chaos = sum(1 for r in cur_rows if r.get("bench") == "failover"
                  and ("recovery_frac" in r or "dip_frac" in r))
    for line in chaos_failures:
        print(f"CHAOS GUARD FAILED: {line}")
    if n_chaos:
        print(f"# {n_chaos} failover rows checked "
              f"(min-recovery {args.min_recovery}, min-dip {args.min_dip}), "
              f"{len(chaos_failures)} failed")
    net_failures = check_network(cur_rows,
                                 max_p99_ratio=args.max_p99_ratio)
    n_net = sum(1 for r in cur_rows if r.get("bench") == "network")
    for line in net_failures:
        print(f"NETWORK GUARD FAILED: {line}")
    if n_net:
        print(f"# {n_net} network rows checked "
              f"(max-p99-ratio {args.max_p99_ratio}), "
              f"{len(net_failures)} failed")
    chaos_failures += net_failures
    tail_failures = check_chaos(cur_rows,
                                max_chaos_ratio=args.max_chaos_ratio,
                                min_chaos_recovery=args.min_chaos_recovery)
    n_tail = sum(1 for r in cur_rows if r.get("bench") == "chaos"
                 and ("chaos_tail_ratio" in r or "recovery_frac" in r))
    for line in tail_failures:
        print(f"CHAOS TAIL GUARD FAILED: {line}")
    if n_tail:
        print(f"# {n_tail} chaos rows checked "
              f"(max-chaos-ratio {args.max_chaos_ratio}, "
              f"min-chaos-recovery {args.min_chaos_recovery}), "
              f"{len(tail_failures)} failed")
    chaos_failures += tail_failures
    tier_failures = check_tiering(
        cur_rows, min_capacity=args.min_capacity,
        max_cold_read_frac=args.max_cold_read_frac,
        max_hot_ratio=args.max_hot_ratio)
    n_tier = sum(1 for r in cur_rows if r.get("bench") == "tiering")
    for line in tier_failures:
        print(f"TIERING GUARD FAILED: {line}")
    if n_tier:
        print(f"# {n_tier} tiering rows checked "
              f"(min-capacity {args.min_capacity}, max-cold-read-frac "
              f"{args.max_cold_read_frac}, max-hot-ratio "
              f"{args.max_hot_ratio}), {len(tier_failures)} failed")
    chaos_failures += tier_failures
    baseline = args.against or latest_committed_baseline(
        cur_meta.get("quick"))
    if baseline is None:
        print("# no committed BENCH_*.json baseline; nothing to diff")
        return 1 if chaos_failures else 0
    base_rows, base_meta = load_rows(baseline)
    print(f"# current  {args.current} (quick={cur_meta.get('quick')}, "
          f"platform={cur_meta.get('platform')})")
    print(f"# baseline {baseline} (quick={base_meta.get('quick')}, "
          f"commit={str(base_meta.get('git_commit'))[:12]})")

    checked, failed = compare(cur_rows, base_rows,
                              threshold=args.threshold, floor_us=args.floor)
    for bench, name, bus, cus, ratio in checked:
        flag = "  << REGRESSION" if ratio > args.threshold else ""
        print(f"{bench:>20s} {name:<36s} {bus:>12.1f} -> {cus:>12.1f} "
              f"({ratio:5.2f}x){flag}")
    print(f"# {len(checked)} shared keys checked, {len(failed)} regressed "
          f"(threshold {args.threshold}x, floor {args.floor}us)")
    return 1 if failed or chaos_failures else 0


if __name__ == "__main__":
    sys.exit(main())
