"""Skew-drift rebalancing: throughput recovery after an induced flip (PR 5).

The lifecycle the tentpole exists for, measured end-to-end at 2/4 nodes:

  pre_flip        hash-partitioned table, uniform keys — balanced scatter.
  post_flip       the induced skew flip: a rekeying rewrite whose new key
                  distribution lives entirely on ONE node under the stale
                  hash rule (`table_write(..., keys=)` routes by the
                  captured rule, so the pile-up is what a real system
                  would do to keep co-location). Every verb now waits on
                  the hot node's straggler dispatch — and the hot
                  partition rounds up to a 2x pow2 shape bucket on top.
  post_rebalance  `auto_rebalance` fires on the observed heat (the drift
                  ratio is reported), live-migrates to the skew-aware LPT
                  placement, and the same workload is measured again.
  fresh           the recovery target: a brand-new cluster allocated with
                  partitioner="skew" over the post-flip keys — what the
                  map would look like had it never gone stale. The
                  acceptance bar is post_rebalance within ~15% of this.

Every row carries valid vs pow2-padded row counts (the shape-bucketing
waste item from ROADMAP) and the drift ratio / recovery fraction, so
BENCH json records the whole story, not just wall times.

Standalone:  python -m benchmarks.bench_rebalance --json BENCH.json
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import operators as op
from repro.core.cluster import FarCluster
from repro.core.table import Column, FTable

COLS = tuple(Column(f"c{i}", "i32" if i == 0 else "f32") for i in range(8))
N_KEYS = 64


def _data(rng, keys):
    d = {"c0": np.asarray(keys, np.int32)}
    for i in range(1, 8):
        d[f"c{i}"] = rng.normal(size=len(keys)).astype(np.float32)
    return d


PIPES = (
    (op.Select((op.Predicate("c1", "<", 0.2),)),),
    (op.GroupBy("c0", ("c1", "c2"), n_buckets=256),),
)


def _round(cl, cqp, ct):
    """One scatter-gather round: all PIPES submitted, then gathered."""
    pends = [cl.submit_request(cqp, ct, pipe) for pipe in PIPES]
    for p in pends:
        p.wait().finalize()


def _measure(cl, cqp, ct, n, repeat):
    """p50 wall time of one round and the implied rows/s throughput."""
    _round(cl, cqp, ct)                             # warmup: trace + caches
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        _round(cl, cqp, ct)
        ts.append(time.perf_counter() - t0)
    sec = sorted(ts)[len(ts) // 2]
    return sec, len(PIPES) * n / sec


def _measure_pair(setups, n, repeat):
    """p50s for two setups with INTERLEAVED rounds (a, b, a, b, ...), so
    host-load drift hits both equally — the recovery fraction compares
    post_rebalance against fresh under the same conditions."""
    for s in setups:
        _round(*s)                                  # warmup both first
    ts = [[], []]
    for _ in range(repeat):
        for i, s in enumerate(setups):
            t0 = time.perf_counter()
            _round(*s)
            ts[i].append(time.perf_counter() - t0)
    out = []
    for samples in ts:
        sec = sorted(samples)[len(samples) // 2]
        out.append((sec, len(PIPES) * n / sec))
    return out


def run() -> None:
    import gc

    q = common.quick()
    n = 1 << (15 if q else 19)
    # keep balanced hash partitions just under their pow2 bucket so the
    # padded/valid gap isolates the HOT partition's round-up
    n = int(n * 0.95)
    repeat = 1 if q else 5
    node_counts = (2,) if q else (2, 4)
    rng = np.random.default_rng(0)
    keys = rng.integers(0, N_KEYS, n).astype(np.int32)

    for k in node_counts:
        # drop earlier phases' (and earlier benches') device buffers
        # before timing: the migration phases are allocation-heavy and
        # leftover pools distort the interleaved comparison
        gc.collect()
        cl = FarCluster(k, 64 * 2**20)
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(cqp, FTable("t", COLS, n_rows=n),
                                partitioner="hash", keys=keys)
        cl.table_write(cqp, ct, FTable("t", COLS, n_rows=n).encode(
            _data(rng, keys)))

        sec, thru = _measure(cl, cqp, ct, n, repeat)
        base = thru
        valid, padded = common.cluster_padding(ct)
        common.row("rebalance", f"pre_flip_{k}nodes", sec * 1e6,
                   nodes=k, rows=n, mrows_per_s=round(thru / 1e6, 2),
                   valid_rows=valid, padded_rows=padded)

        # induced skew flip: every new key is owned by node 0 under the
        # captured hash rule, so the rekeying write piles the table there
        owners = ct.co_spec.owners_of(np.arange(N_KEYS))
        hot = np.arange(N_KEYS)[owners == 0]
        new_keys = hot[rng.integers(0, len(hot), n)].astype(np.int32)
        cl.table_write(cqp, ct, FTable("t", COLS, n_rows=n).encode(
            _data(rng, new_keys)), keys=new_keys)

        sec, thru = _measure(cl, cqp, ct, n, repeat)
        drift = cl.check_drift()["t"]
        valid, padded = common.cluster_padding(ct)
        common.row("rebalance", f"post_flip_{k}nodes", sec * 1e6,
                   nodes=k, rows=n, mrows_per_s=round(thru / 1e6, 2),
                   slowdown=round(base / thru, 2),
                   drift_ratio=round(drift.ratio, 2),
                   valid_rows=valid, padded_rows=padded)
        assert drift.drifted, "detector must flag the induced flip"

        plans = cl.auto_rebalance(cqp)
        moved_bytes = sum(p.total_bytes for p in plans.values())

        # recovery target: a never-stale map over the post-flip keys —
        # measured INTERLEAVED with the rebalanced cluster so the
        # recovery fraction is insensitive to host-load drift
        cl2 = FarCluster(k, 64 * 2**20)
        cqp2 = cl2.open_connection()
        ct2 = cl2.alloc_table_mem(cqp2, FTable("t", COLS, n_rows=n),
                                  partitioner="skew", keys=new_keys)
        cl2.table_write(cqp2, ct2, FTable("t", COLS, n_rows=n).encode(
            _data(rng, new_keys)))
        (rsec, reb), (fsec, fresh) = _measure_pair(
            [(cl, cqp, ct), (cl2, cqp2, ct2)], n, repeat)
        valid, padded = common.cluster_padding(ct)
        common.row("rebalance", f"post_rebalance_{k}nodes", rsec * 1e6,
                   nodes=k, rows=n, mrows_per_s=round(reb / 1e6, 2),
                   moved_bytes=moved_bytes,
                   valid_rows=valid, padded_rows=padded)
        valid, padded = common.cluster_padding(ct2)
        common.row("rebalance", f"fresh_{k}nodes", fsec * 1e6,
                   nodes=k, rows=n, mrows_per_s=round(fresh / 1e6, 2),
                   recovery_frac=round(reb / fresh, 3),
                   valid_rows=valid, padded_rows=padded)
        del cl, cl2, ct, ct2, cqp, cqp2        # release pools before next k


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.quick:
        common.QUICK = True
    run()
    common.print_csv()
    if args.json:
        common.write_json(args.json)


if __name__ == "__main__":
    main()
