"""Shared benchmark utilities: timing, CSV rows, the LCPU/RCPU baselines.

Baselines (paper §6.1):
  FV    — Farview pipeline on the pool (kernels, interpret mode on CPU)
  LCPU  — local buffer cache + numpy processing on the "client CPU"
  RCPU  — remote buffer cache: full table "shipped" (bytes accounted), then
          numpy processing client-side.
On this container both baselines run on the same CPU, so wall-times are
indicative; the byte accounting (shipped/read) is exact and is the number
the paper's economics rest on. Each row reports both.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import numpy as np

ROWS: list[dict] = []


def timeit(fn, *, repeat: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        fn()
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def row(bench: str, name: str, us: float, **derived):
    r = {"bench": bench, "name": name, "us_per_call": round(us, 1)}
    r.update(derived)
    ROWS.append(r)
    return r


def print_csv():
    keys = ["bench", "name", "us_per_call"]
    extra = sorted({k for r in ROWS for k in r} - set(keys))
    cols = keys + extra
    print(",".join(cols))
    for r in ROWS:
        print(",".join(str(r.get(k, "")) for k in cols))
