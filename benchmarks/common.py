"""Shared benchmark utilities: timing, CSV rows, the LCPU/RCPU baselines.

Baselines (paper §6.1):
  FV    — Farview pipeline on the pool (fused jitted request path)
  LCPU  — local buffer cache + numpy processing on the "client CPU"
  RCPU  — remote buffer cache: full table "shipped" (bytes accounted), then
          numpy processing client-side.
On this container both baselines run on the same CPU, so wall-times are
indicative; the byte accounting (shipped/read) is exact and is the number
the paper's economics rest on. Each row reports both.

Timing is BLOCKING: `timeit` materializes whatever the closure returns
inside the timed region — lazy `PipelineResult`s are finalized and device
arrays are `jax.block_until_ready`-ed — so FV rows measure completed work,
never async dispatch. Reported value is the p50 (median) across repeats.
"""
from __future__ import annotations

import os
import time

import jax
import numpy as np

ROWS: list[dict] = []

# Quick/smoke mode (CI benchmark job): single warmup + single repeat and
# reduced problem sizes where a bench opts in via `quick()`. Enabled by
# `benchmarks.run --quick` or the FARVIEW_BENCH_QUICK env var. Timings in
# this mode are indicative only — the JSON artifact tracks that the bench
# *runs* and its exact byte accounting, not p50 stability.
QUICK = os.environ.get("FARVIEW_BENCH_QUICK", "") not in ("", "0")


def quick() -> bool:
    return QUICK


def _materialize(x) -> None:
    """Block on the timed closure's result: finalize lazy pipeline results,
    wait for device arrays; plain python/numpy values pass through."""
    if x is None:
        return
    if hasattr(x, "finalize"):
        x.finalize()
        return
    if isinstance(x, (list, tuple)):
        for e in x:
            _materialize(e)
        return
    try:
        jax.block_until_ready(x)
    except Exception:
        pass


def timeit(fn, *, repeat: int = 5, warmup: int = 2) -> float:
    """p50 wall time of `fn()` including result materialization (seconds)."""
    if QUICK:
        repeat, warmup = 1, 1
    for _ in range(warmup):
        _materialize(fn())
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        _materialize(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def cluster_padding(*ctables) -> tuple[int, int]:
    """(valid_rows, padded_rows) across cluster tables: what the nodes'
    shape-bucketed executables actually run vs the rows that carry data.
    The gap was the ROADMAP's bucketing-waste item — hash partitions of
    pow2 tables land at n/k+eps rows; the quarter-octave `shape_bucket`
    ladder caps the round-up at 1.25x where pow2 paid up to 2x —
    reported per bench row so the waste stays visible in BENCH json."""
    from repro.core.operators import shape_bucket
    valid = padded = 0
    for ct in ctables:
        for p in ct.parts:
            if p is not None and p.n_rows:
                valid += p.n_rows
                padded += shape_bucket(p.n_rows)
    return valid, padded


def row(bench: str, name: str, us: float, **derived):
    r = {"bench": bench, "name": name, "us_per_call": round(us, 1)}
    r.update(derived)
    ROWS.append(r)
    return r


def _plain(v):
    """JSON/CSV-safe scalar (numpy ints/floats -> python)."""
    if isinstance(v, (np.integer, np.floating)):
        return v.item()
    return v


def rows_as_records() -> list[dict]:
    return [{k: _plain(v) for k, v in r.items()} for r in ROWS]


def bench_meta() -> dict:
    """Provenance stamp for --json output: git commit, jax version, device
    platform, quick-mode flag. BENCH_*.json files carry it so the perf
    trajectory is comparable PR over PR (and the CI regression guard can
    refuse to compare quick-mode against full-mode numbers)."""
    import subprocess
    try:
        commit = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)),
            timeout=10).stdout.strip() or None
    except Exception:
        commit = None
    return {
        "git_commit": commit,
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "quick": QUICK,
    }


def write_json(path: str) -> None:
    """Write {"meta": ..., "rows": [...]} (the post-PR4 BENCH format; the
    regression guard still reads the older bare-list files)."""
    import json
    with open(path, "w") as f:
        json.dump({"meta": bench_meta(), "rows": rows_as_records()}, f,
                  indent=2, default=str)


def print_csv():
    keys = ["bench", "name", "us_per_call"]
    extra = sorted({k for r in ROWS for k in r} - set(keys))
    cols = keys + extra
    print(",".join(cols))
    for r in ROWS:
        print(",".join(str(r.get(k, "")) for k in cols))
