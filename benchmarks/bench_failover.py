"""Kill-a-node failover: dip and recovery under mixed load (PR 6).

The availability story the replication tentpole exists for, measured
end-to-end at 2 and 4 nodes with `replicas=2`:

  pre_kill     hash-partitioned table, mixed selection + group-aggregate
               rounds on a healthy cluster — the baseline throughput and
               the byte-parity reference for every later phase.
  during_kill  a node is killed BETWEEN submit and gather, so the
               in-flight round eats the full failure path: dead dispatch,
               health strike, reroute to the cyclic replica, re-sliced
               resubmit, merge. The round must still return results
               byte-identical to the healthy reference; its wall time is
               the availability dip (dip_frac = during/pre throughput —
               the guard is that it stays well above zero, i.e. the
               cluster degrades instead of stalling).
  heal         `FarCluster.heal` promotes replicas to primaries and
               re-replicates onto the survivors; its wall time is the
               recovery time (heal_s), reported per row.
  post_heal    the same rounds on the healed map (dead node never
               touched again). recovery_frac = post_heal/pre_kill
               throughput; the acceptance bar is >= 0.9 at 4 nodes —
               losing 1 of 4 overlap-only nodes must not cost more than
               the lost overlap.

Every during_kill / post_heal row asserts byte-identity against the
healthy reference before it reports a time: a fast wrong answer is not a
recovery.

Standalone:  python -m benchmarks.bench_failover --json BENCH.json --seed 7
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import operators as op
from repro.core.cluster import FarCluster
from repro.core.table import Column, FTable
from repro.distributed.health import FaultInjector

COLS = tuple(Column(f"c{i}", "i32" if i == 0 else "f32") for i in range(8))
N_KEYS = 64

PIPES = (
    (op.Select((op.Predicate("c1", "<", 0.2),)),),
    (op.GroupBy("c0", ("c1", "c2"), n_buckets=256),),
)


def _data(rng, keys):
    d = {"c0": np.asarray(keys, np.int32)}
    for i in range(1, 8):
        # integer-valued floats: group sums are order-insensitive, so the
        # byte-parity asserts are meaningful for the aggregate pipe too
        d[f"c{i}"] = rng.integers(-50, 50, len(keys)).astype(np.float32)
    return d


def _round(cl, cqp, ct):
    """One mixed scatter-gather round; returns the finalized results."""
    pends = [cl.submit_request(cqp, ct, pipe) for pipe in PIPES]
    return [p.wait().finalize() for p in pends]


def _assert_parity(results, ref):
    """Byte-identical to the healthy reference — zero wrong bytes."""
    for res, r in zip(results, ref):
        if res.kind == "groups":
            assert set(res.groups) == set(r.groups)
            for key in r.groups:
                for a, b in zip(r.groups[key], res.groups[key]):
                    np.testing.assert_array_equal(np.asarray(a),
                                                  np.asarray(b))
        else:
            assert res.count == r.count
            np.testing.assert_array_equal(np.asarray(res.rows),
                                          np.asarray(r.rows))


def _measure(cl, cqp, ct, n, repeat, ref=None):
    """p50 round wall time and implied rows/s; parity-checked if ref."""
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        results = _round(cl, cqp, ct)
        ts.append(time.perf_counter() - t0)
        if ref is not None:
            _assert_parity(results, ref)
    sec = sorted(ts)[len(ts) // 2]
    return sec, len(PIPES) * n / sec


def run(seed: int = 0) -> None:
    import gc

    q = common.quick()
    n = 1 << (14 if q else 18)
    repeat = 1 if q else 5
    node_counts = (2, 4)        # the 4-node row carries the recovery bar
    rng = np.random.default_rng(seed)
    keys = rng.integers(0, N_KEYS, n).astype(np.int32)
    words = FTable("t", COLS, n_rows=n).encode(_data(rng, keys))

    for k in node_counts:
        gc.collect()
        # the seeded injector makes every fault point replayable from
        # the CLI (--seed) — a flaky failover run can be re-driven exactly
        cl = FarCluster(k, 128 * 2**20, replicas=2,
                        fault=FaultInjector(seed=seed))
        cqp = cl.open_connection()
        ct = cl.alloc_table_mem(cqp, FTable("t", COLS, n_rows=n),
                                partitioner="hash", keys=keys)
        cl.table_write(cqp, ct, words)

        ref = _round(cl, cqp, ct)               # warmup + parity reference
        sec, base = _measure(cl, cqp, ct, n, repeat, ref)
        common.row("failover", f"pre_kill_{k}nodes", sec * 1e6,
                   nodes=k, rows=n, replicas=2,
                   mrows_per_s=round(base / 1e6, 2))

        # the failure round: kill AFTER submit, so the gather itself hits
        # the dead node and pays detection + reroute + resubmit inline
        victim = k - 1
        t0 = time.perf_counter()
        pends = [cl.submit_request(cqp, ct, pipe) for pipe in PIPES]
        cl.fault.kill(victim)
        results = [p.wait().finalize() for p in pends]
        dip_sec = time.perf_counter() - t0
        _assert_parity(results, ref)
        assert cl.health.state(victim) == "dead"
        dip_thru = len(PIPES) * n / dip_sec
        common.row("failover", f"during_kill_{k}nodes", dip_sec * 1e6,
                   nodes=k, rows=n, replicas=2, victim=victim,
                   mrows_per_s=round(dip_thru / 1e6, 2),
                   dip_frac=round(dip_thru / base, 3),
                   failovers=int(ct.heat.failovers))

        t0 = time.perf_counter()
        report = cl.heal(cqp)
        heal_sec = time.perf_counter() - t0
        assert victim in report["dead_nodes"]
        common.row("failover", f"heal_{k}nodes", heal_sec * 1e6,
                   nodes=k, rows=n, replicas=2,
                   promoted=len(report["promoted"]),
                   re_replicated=len(report["re_replicated"]),
                   heal_s=round(heal_sec, 3))

        # healed map: the victim is never dispatched to again
        before = cl.nodes[victim].dispatches
        _round(cl, cqp, ct)                     # warmup the promoted routes
        sec, thru = _measure(cl, cqp, ct, n, repeat, ref)
        assert cl.nodes[victim].dispatches == before
        common.row("failover", f"post_heal_{k}nodes", sec * 1e6,
                   nodes=k, rows=n, replicas=2,
                   mrows_per_s=round(thru / 1e6, 2),
                   recovery_frac=round(thru / base, 3),
                   heal_s=round(heal_sec, 3))
        del cl, cqp, ct                         # release pools before next k


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for data and the fault injector's rng")
    args = ap.parse_args()
    if args.quick:
        common.QUICK = True
    run(seed=args.seed)
    common.print_csv()
    if args.json:
        common.write_json(args.json)


if __name__ == "__main__":
    main()
