"""LM integration benchmark: far-KV decode bytes, push-down vs naive fetch.

The Farview economics applied to serving: per decode step per layer, mode
"far" ships Hq*(D+2) floats of partial-softmax state; mode "naive" ships
the raw KV rows. The table sweeps context length and reports the modeled
reduction factor plus a measured CPU walltime for the shard-level attention
(partial_attention + merge vs full gather + attention)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core.far_kv import shipped_bytes_per_layer
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def run() -> None:
    b, hq, hkv, d, tp = 8, 32, 8, 128, 16
    for s in (4096, 32768, 524288):
        far = shipped_bytes_per_layer("far", batch=b, hq=hq, hkv=hkv,
                                      head_dim=d, seq_len=s, tp=tp)
        nai = shipped_bytes_per_layer("naive", batch=b, hq=hq, hkv=hkv,
                                      head_dim=d, seq_len=s, tp=tp)
        row("far_kv", f"bytes_far_S{s}", 0, bytes_per_layer=far,
            reduction=round(nai / far, 1))
        row("far_kv", f"bytes_naive_S{s}", 0, bytes_per_layer=nai,
            reduction=1.0)

    # measured: partial attention on one shard + merge vs full attention
    rng = np.random.default_rng(0)
    s_loc = 2048
    q = jnp.asarray(rng.normal(size=(b, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s_loc, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s_loc, hkv, d)), jnp.float32)
    lens = jnp.full((b,), s_loc, jnp.int32)
    kops.decode_attention(q, k, v, lens)
    us_shard = timeit(
        lambda: np.asarray(kops.decode_attention(q, k, v, lens)[0]),
        repeat=3) * 1e6
    us_full = timeit(
        lambda: np.asarray(kref.full_attention_oracle(q, k, v, lens)),
        repeat=3) * 1e6
    row("far_kv", f"kernel_shard_S{s_loc}", us_shard)
    row("far_kv", f"oracle_full_S{s_loc}", us_full)
