"""Mixed-size + mixed-kind multiclient round (PR 2 bucket-batched scheduler).

Eight clients on one node submit in the same scheduling round:
  * 3 selection requests over SAME-layout tables of DIFFERENT sizes that
    share one power-of-two bucket (5k/6k/8k rows -> 8192 bucket),
  * 3 regex requests over string tables of different row counts/widths,
  * 2 join probes sharing one small build table.

The round must cost exactly THREE stacked executable launches — one per
(signature, layout, bucket) group, however many clients stacked — which is
asserted via the node's dispatch counter, not just timed. Rows compare the
stacked round against the sum of solo dispatches (what PR 1's
exact-shape coalescing would have paid for the mixed sizes: everything
solo) and the LCPU/RCPU baselines.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.core import operators as op
from repro.core.client import (FViewNode, alloc_table_mem, farview_request,
                               open_connection, submit_request, table_write)
from repro.core.table import FTable, Column, string_table

WORD_SIZES = (5 << 10, 6 << 10, 8 << 10)          # one 8192 bucket
STR_ROWS = (3 << 10, 4 << 10, 3 << 10)            # one 4096 bucket
JOIN_SIZES = (6 << 10, 8 << 10)                   # one 8192 bucket
SEL_PIPE = (op.Select((op.Predicate("c1", "<", 0.2),)),)
RE_PIPE = (op.RegexMatch("error"),)


def _setup(node):
    rng = np.random.default_rng(11)
    word, strs, joins = [], [], []
    for i, n in enumerate(WORD_SIZES):
        qp = open_connection(node)
        cols = tuple(Column(f"c{i}") for i in range(8))
        ft = FTable(f"w{i}", cols, n_rows=n)
        alloc_table_mem(qp, ft)
        table_write(qp, ft, rng.normal(size=(n, 8)).astype(np.float32))
        word.append((qp, ft))
    samples = [b"error: disk full", b"all fine", b"warn: error", b"ok"]
    for i, (n, w) in enumerate(zip(STR_ROWS, (24, 32, 20))):
        qp = open_connection(node)
        picks = [samples[j] for j in rng.integers(0, len(samples), n)]
        ft, mat, lens = string_table(f"s{i}", picks, w)
        strs.append((qp, ft, mat, lens))
    qb = open_connection(node)
    build = FTable("dim", (Column("k", "i32"), Column("v")), n_rows=64)
    alloc_table_mem(qb, build)
    table_write(qb, build, build.encode(
        {"k": rng.permutation(128)[:64].astype(np.int32),
         "v": rng.random(64).astype(np.float32)}))
    jpipe = (op.JoinSmall(probe_key="c0", build_table="dim",
                          build_key="k", build_cols=("v",)),)
    for i, n in enumerate(JOIN_SIZES):
        qp = open_connection(node)
        cols = (Column("c0", "i32"),) + tuple(
            Column(f"c{j}") for j in range(1, 8))
        ft = FTable(f"j{i}", cols, n_rows=n)
        alloc_table_mem(qp, ft)
        data = {"c0": rng.integers(0, 128, n).astype(np.int32)}
        data.update({f"c{j}": rng.normal(size=n).astype(np.float32)
                     for j in range(1, 8)})
        table_write(qp, ft, ft.encode(data))
        joins.append((qp, ft, jpipe))
    return word, strs, joins


def run() -> None:
    node = FViewNode(1 << 30, n_regions=9)
    word, strs, joins = _setup(node)
    n_clients = len(word) + len(strs) + len(joins)

    def one_round():
        pend = [submit_request(qp, ft, SEL_PIPE) for qp, ft in word]
        pend += [submit_request(qp, ft, RE_PIPE, strings=m, lengths=l)
                 for qp, ft, m, l in strs]
        pend += [submit_request(qp, ft, p) for qp, ft, p in joins]
        node.flush()
        return [p.result for p in pend]

    def all_solo():
        out = [farview_request(qp, ft, SEL_PIPE) for qp, ft in word]
        out += [farview_request(qp, ft, RE_PIPE, strings=m, lengths=l)
                for qp, ft, m, l in strs]
        out += [farview_request(qp, ft, p) for qp, ft, p in joins]
        return out

    before = node.dispatches
    one_round()                                    # warm the stacked paths
    stacked_dispatches = node.dispatches - before
    assert stacked_dispatches == 3, stacked_dispatches   # the SLO itself
    all_solo()                                     # warm the solo paths

    us_round = timeit(one_round, repeat=3) * 1e6
    us_solo = timeit(all_solo, repeat=3) * 1e6
    row("multiclient_mixed", f"FV_{n_clients}clients_3groups", us_round,
        dispatches=stacked_dispatches)
    row("multiclient_mixed", f"FV_{n_clients}solo_sum", us_solo,
        dispatches=n_clients)

    def lcpu():
        for qp, ft in word:
            rows = np.asarray(qp.node.pool.read_table(ft))
            rows[rows[:, 1] < 0.2]
        for _, _, m, l in strs:
            [bytes(r[:n]).find(b"error") >= 0 for r, n in zip(m, l)]

    us_lcpu = timeit(lcpu, repeat=3) * 1e6
    row("multiclient_mixed", "LCPU_wordstr", us_lcpu,
        shipped_bytes=sum(ft.n_bytes for _, ft in word))
