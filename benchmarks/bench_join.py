"""Small-table join (paper §Conclusions future work): FV in-memory join vs
LCPU/RCPU dict-merge baselines. FV ships only matched+selected rows with
the build values appended; RCPU ships the whole probe table.

`FV_join_scaleout_{k}nodes_{copart|repl}` (PR 4): the same join scattered
over a FarCluster of 1/2/4 nodes, comparing the replicated broadcast build
(N pool copies, N× write traffic) against the co-partitioned build-probe
layout (build hash-placed by the probe's key rule: ONE copy cluster-wide,
every node joins locally). `build_bytes_written` is the exact pool write
traffic for the build table under each layout."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from benchmarks.common import row, timeit
from repro.core import operators as op
from repro.core.client import (FViewNode, alloc_table_mem, farview_request,
                               open_connection, table_write)
from repro.core.cluster import FarCluster
from repro.core.table import FTable, Column


def _join_scaleout() -> None:
    q = common.quick()
    n = 1 << (13 if q else 18)
    node_counts = (1, 2) if q else (1, 2, 4)
    repeat = 1 if q else 5
    n_build = 512
    rng = np.random.default_rng(6)
    pk = rng.integers(0, 1024, n).astype(np.int32)
    pd = {"k": pk, "a": rng.random(n).astype(np.float32),
          "b": rng.random(n).astype(np.float32)}
    bk = rng.permutation(1024)[:n_build].astype(np.int32)
    bv = rng.random(n_build).astype(np.float32)
    pipe = (op.JoinSmall(probe_key="k", build_table="dim",
                         build_key="k", build_cols=("v",)),)
    pcols = (Column("k", "i32"), Column("a"), Column("b"))
    bcols = (Column("k", "i32"), Column("v"))

    for k in node_counts:
        for mode in ("copart", "repl"):
            cl = FarCluster(k, 256 * 2**20)
            cqp = cl.open_connection()
            probe = FTable("probe", pcols, n_rows=n)
            ct = cl.alloc_table_mem(cqp, probe, partitioner="hash", keys=pk)
            cl.table_write(cqp, ct, probe.encode(pd))
            build = FTable("dim", bcols, n_rows=n_build)
            w0 = cl.stats.bytes_written
            if mode == "copart":
                cb = cl.alloc_table_mem(cqp, build, co_partition=ct, keys=bk)
            else:
                cb = cl.alloc_table_mem(cqp, build, replicate=True)
            cl.table_write(cqp, cb, build.encode({"k": bk, "v": bv}))
            build_written = cl.stats.bytes_written - w0

            def verb(cl=cl, cqp=cqp, ct=ct):
                return cl.farview_request(cqp, ct, pipe).finalize()

            res = verb()
            samples = []
            for _ in range(repeat):
                t0 = time.perf_counter()
                verb()
                samples.append(time.perf_counter() - t0)
            sec = sorted(samples)[len(samples) // 2]            # p50
            row("join", f"FV_join_scaleout_{k}nodes_{mode}", sec * 1e6,
                nodes=k, rows=n, matched=int(res.count),
                shipped_bytes=res.shipped_bytes,
                build_bytes_written=build_written,
                mrows_per_s=round(n / sec / 1e6, 2))


def run(n_rows: int = 1 << 14) -> None:
    node = FViewNode(256 * 2**20)
    qp = open_connection(node)
    rng = np.random.default_rng(5)
    probe = FTable("probe", (Column("k", "i32"), Column("a"), Column("b")),
                   n_rows=n_rows)
    alloc_table_mem(qp, probe)
    pk = rng.integers(0, 1024, n_rows).astype(np.int32)
    pd = {"k": pk, "a": rng.random(n_rows).astype(np.float32),
          "b": rng.random(n_rows).astype(np.float32)}
    table_write(qp, probe, probe.encode(pd))

    for k_build, match_pct in ((64, 6), (512, 50)):
        bname = f"build{k_build}"
        build = FTable(bname, (Column("k", "i32"), Column("v")),
                       n_rows=k_build)
        alloc_table_mem(qp, build)
        bk = rng.permutation(1024)[:k_build].astype(np.int32)
        bv = rng.random(k_build).astype(np.float32)
        table_write(qp, build, build.encode({"k": bk, "v": bv}))

        pipe = (op.JoinSmall(probe_key="k", build_table=bname,
                             build_key="k", build_cols=("v",)),)
        res = farview_request(qp, probe, pipe)
        us_fv = timeit(lambda: farview_request(qp, probe, pipe),
                       repeat=3) * 1e6

        lut = {int(kk): float(vv) for kk, vv in zip(bk, bv)}

        def lcpu():
            out = []
            for i in range(n_rows):
                v = lut.get(int(pk[i]))
                if v is not None:
                    out.append((pk[i], v))
            return out

        us_lcpu = timeit(lcpu, repeat=3) * 1e6
        row("join", f"FV_join_{match_pct}pct", us_fv,
            shipped_bytes=res.shipped_bytes, rows=n_rows,
            matched=int(res.count))
        row("join", f"LCPU_join_{match_pct}pct", us_lcpu, shipped_bytes=0,
            rows=n_rows)
        row("join", f"RCPU_join_{match_pct}pct", us_lcpu,
            shipped_bytes=probe.n_bytes, rows=n_rows)

    # cluster join scale-out: co-partitioned vs replicated build, 1/2/4 nodes
    _join_scaleout()
