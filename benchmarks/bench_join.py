"""Small-table join (paper §Conclusions future work): FV in-memory join vs
LCPU/RCPU dict-merge baselines. FV ships only matched+selected rows with
the build values appended; RCPU ships the whole probe table."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.core import operators as op
from repro.core.client import (FViewNode, alloc_table_mem, farview_request,
                               open_connection, table_write)
from repro.core.table import FTable, Column


def run(n_rows: int = 1 << 14) -> None:
    node = FViewNode(256 * 2**20)
    qp = open_connection(node)
    rng = np.random.default_rng(5)
    probe = FTable("probe", (Column("k", "i32"), Column("a"), Column("b")),
                   n_rows=n_rows)
    alloc_table_mem(qp, probe)
    pk = rng.integers(0, 1024, n_rows).astype(np.int32)
    pd = {"k": pk, "a": rng.random(n_rows).astype(np.float32),
          "b": rng.random(n_rows).astype(np.float32)}
    table_write(qp, probe, probe.encode(pd))

    for k_build, match_pct in ((64, 6), (512, 50)):
        bname = f"build{k_build}"
        build = FTable(bname, (Column("k", "i32"), Column("v")),
                       n_rows=k_build)
        alloc_table_mem(qp, build)
        bk = rng.permutation(1024)[:k_build].astype(np.int32)
        bv = rng.random(k_build).astype(np.float32)
        table_write(qp, build, build.encode({"k": bk, "v": bv}))

        pipe = (op.JoinSmall(probe_key="k", build_table=bname,
                             build_key="k", build_cols=("v",)),)
        res = farview_request(qp, probe, pipe)
        us_fv = timeit(lambda: farview_request(qp, probe, pipe),
                       repeat=3) * 1e6

        lut = {int(kk): float(vv) for kk, vv in zip(bk, bv)}

        def lcpu():
            out = []
            for i in range(n_rows):
                v = lut.get(int(pk[i]))
                if v is not None:
                    out.append((pk[i], v))
            return out

        us_lcpu = timeit(lcpu, repeat=3) * 1e6
        row("join", f"FV_join_{match_pct}pct", us_fv,
            shipped_bytes=res.shipped_bytes, rows=n_rows,
            matched=int(res.count))
        row("join", f"LCPU_join_{match_pct}pct", us_lcpu, shipped_bytes=0,
            rows=n_rows)
        row("join", f"RCPU_join_{match_pct}pct", us_lcpu,
            shipped_bytes=probe.n_bytes, rows=n_rows)
