"""Fig. 11: encryption/decryption on the read path.

(a) response time: decrypt-while-reading (FV) vs read-then-CPU-decrypt;
(b) throughput delta: plain read vs read+decrypt — the paper's claim is
the delta is ~0 because the cipher is fused into the stream. Here the FV
path fuses the crypt kernel into the pipeline; the measured delta is the
kernel's marginal cost."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core import operators as op
from repro.core.client import (FViewNode, alloc_table_mem, farview_request,
                               open_connection, table_write)
from repro.core.table import FTable, Column
from repro.data.pipeline import db_table_columns
from repro.kernels import ops as kops
from repro.kernels import ref as kref


def run(n_rows: int = 1 << 14) -> None:
    node = FViewNode(256 * 2**20)
    qp = open_connection(node)
    ft = FTable("e", tuple(Column(f"c{i}") for i in range(8)),
                n_rows=n_rows)
    alloc_table_mem(qp, ft)
    data = db_table_columns(n_rows)
    words = ft.encode(data)
    key = np.array([11, 13], np.uint32)
    u32 = jnp.asarray(words.reshape(-1), jnp.float32).view(jnp.uint32)
    enc = kops.crypt(u32, key, 5)
    table_write(qp, ft, np.asarray(enc.view(jnp.float32)).reshape(
        words.shape))

    pipe_dec = (op.Crypt(key=(11, 13), nonce=5, when="pre"),)
    pipe_plain = ()
    farview_request(qp, ft, pipe_dec)
    us_fv_dec = timeit(lambda: farview_request(qp, ft, pipe_dec),
                       repeat=3) * 1e6
    us_fv_plain = timeit(lambda: farview_request(qp, ft, pipe_plain),
                         repeat=3) * 1e6

    # LCPU: read raw + decrypt on the client CPU with the jnp reference
    enc_np = np.asarray(enc)

    def lcpu():
        return np.asarray(kref.ctr_crypt(jnp.asarray(enc_np),
                                         jnp.asarray(key), 5))

    us_lcpu = timeit(lcpu, repeat=3) * 1e6
    row("crypto", "FV_read", us_fv_plain, mb=round(ft.n_bytes / 2**20, 2))
    row("crypto", "FV_read+dec", us_fv_dec, mb=round(ft.n_bytes / 2**20, 2),
        overhead_pct=round(100 * (us_fv_dec - us_fv_plain)
                           / max(us_fv_plain, 1e-9), 1))
    row("crypto", "LCPU_read+dec", us_fv_plain + us_lcpu,
        mb=round(ft.n_bytes / 2**20, 2))
    row("crypto", "RCPU_read+dec", us_fv_plain + us_lcpu,
        mb=round(ft.n_bytes / 2**20, 2))
