"""Hot/cold tiering economics (PR 10): capacity, cold-scan bytes, cache.

What the paper's tiering claim has to survive as numbers:

  capacity_analytics   demote a dict-friendly analytics table (low-
                       cardinality int columns, the regime column
                       stores compress best) and report the pool's
                       `effective_capacity` — logical bytes served per
                       physical DRAM byte. GUARDED: >= 1.5x.
  scan_hot / scan_cold the same selection scan over the same table
                       before and after demotion. The cold row carries
                       `cold_read_frac` (cold physical read bytes /
                       hot logical read bytes) — GUARDED < 0.9: a cold
                       scan must measurably read FEWER bytes, because
                       the fused kernel decompresses at line rate
                       instead of promoting first. `shipped_delta` must
                       be 0: results are byte-identical, the response
                       never reflects the tier.
  scan_promoted        demote + promote round-trip, then the hot scan
                       again. `hot_p50_ratio` (promoted p50 / original
                       hot p50) is GUARDED <= 2x: tiering must not tax
                       the hot path it left behind.
  read_cold            plain `table_read` of the demoted table:
                       `shipped_frac` = physical bytes billed / logical
                       table bytes (the compressed-wire half of the
                       accounting contract).
  cache_miss / cache_warm   2-node cluster with a client page cache:
                       the warm read's `warm_shipped_bytes` is GUARDED
                       == 0 (a hit moves no bytes) and `hit_frac` == 1.

Standalone:  python -m benchmarks.bench_tiering --quick --json BENCH.json
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import operators as op
from repro.core.client import (FViewNode, alloc_table_mem, farview_request,
                               open_connection, table_read, table_write)
from repro.core.cluster import FarCluster
from repro.core.table import Column, FTable

COLS = tuple(Column(f"c{i}", "i32" if i == 0 else "f32") for i in range(8))
PAGE = 64 * 1024        # small enough that quick mode still spans pages

PIPE = (op.Select((op.Predicate("c1", "<", 64.0),
                   op.Predicate("c2", ">", 16.0))),)


def _analytics_data(rng, n):
    """The regime the capacity claim is about: every column draws from a
    small vocabulary (dict mode packs to ~a byte per 4-byte word)."""
    d = {"c0": rng.integers(0, 64, n).astype(np.int32)}
    for i in range(1, 8):
        d[f"c{i}"] = rng.integers(0, 128, n).astype(np.float32)
    return d


def _scan_p50(qp, ft, repeat):
    res = farview_request(qp, ft, PIPE).finalize()      # warmup: trace
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        farview_request(qp, ft, PIPE).finalize()
        ts.append(time.perf_counter() - t0)
    return sorted(ts)[len(ts) // 2], res


def run() -> None:
    q = common.quick()
    n = 1 << (14 if q else 18)
    repeat = 1 if q else 5
    rng = np.random.default_rng(0)
    data = _analytics_data(rng, n)
    ft_schema = FTable("facts", COLS, n_rows=n)
    words = ft_schema.encode(data)

    # hysteresis disabled: the bench scans the cold table repeatedly and
    # must measure the FUSED decompress path, not a promotion
    node = FViewNode(256 * 2**20, page_bytes=PAGE, promote_after=10**9)
    qp = open_connection(node)
    ft = FTable("facts", COLS, n_rows=n)
    alloc_table_mem(qp, ft)
    table_write(qp, ft, words)

    sec_hot, res_hot = _scan_p50(qp, ft, repeat)
    common.row("tiering", "scan_hot", sec_hot * 1e6, rows=n,
               read_mb=round(res_hot.read_bytes / 2**20, 3),
               mrows_per_s=round(n / sec_hot / 1e6, 2))

    t0 = time.perf_counter()
    demoted = node.pool.demote_table(ft)
    demote_us = (time.perf_counter() - t0) * 1e6
    s = node.pool.tier_summary()
    common.row("tiering", "capacity_analytics", demote_us, rows=n,
               cold_pages=demoted,
               logical_mb=round(s["logical_bytes"] / 2**20, 3),
               physical_mb=round(s["physical_bytes"] / 2**20, 3),
               effective_capacity=round(s["effective_capacity"], 2))

    sec_cold, res_cold = _scan_p50(qp, ft, repeat)
    common.row("tiering", "scan_cold", sec_cold * 1e6, rows=n,
               read_mb=round(res_cold.read_bytes / 2**20, 3),
               cold_read_frac=round(res_cold.read_bytes
                                    / max(res_hot.read_bytes, 1), 3),
               shipped_delta=res_cold.shipped_bytes - res_hot.shipped_bytes,
               mrows_per_s=round(n / sec_cold / 1e6, 2))

    shipped0 = qp.bytes_shipped
    t0 = time.perf_counter()
    table_read(qp, ft)
    read_us = (time.perf_counter() - t0) * 1e6
    common.row("tiering", "read_cold", read_us, rows=n,
               shipped_frac=round((qp.bytes_shipped - shipped0)
                                  / ft.n_bytes, 3))

    # round-trip back to hot: the tier must not tax the path it left
    node.pool.promote_table(ft)
    sec_back, res_back = _scan_p50(qp, ft, repeat)
    assert res_back.shipped_bytes == res_hot.shipped_bytes
    common.row("tiering", "scan_promoted", sec_back * 1e6, rows=n,
               hot_p50_ratio=round(sec_back / max(sec_hot, 1e-9), 2))
    del node, qp, ft

    # client cache: a warm partitioned read ships nothing
    cl = FarCluster(2, 256 * 2**20, cache_bytes=256 * 2**20)
    cqp = cl.open_connection()
    ct = cl.alloc_table_mem(cqp, FTable("facts", COLS, n_rows=n))
    cl.table_write(cqp, ct, words)
    live = sum(1 for p in ct.parts if p is not None and p.n_rows > 0)

    def _miss_read():
        cl.cache.drop_table("facts")
        t0 = time.perf_counter()
        cl.table_read(cqp, ct)
        return time.perf_counter() - t0

    miss = sorted(_miss_read() for _ in range(repeat))[repeat // 2]
    common.row("tiering", "cache_miss_2nodes", miss * 1e6, rows=n,
               nodes=2)
    cl.table_read(cqp, ct)                          # fill
    h0, s0 = cqp.cache_hits, cqp.bytes_shipped
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        cl.table_read(cqp, ct)
        ts.append(time.perf_counter() - t0)
    warm = sorted(ts)[len(ts) // 2]
    common.row("tiering", "cache_warm_2nodes", warm * 1e6, rows=n,
               nodes=2, warm_shipped_bytes=cqp.bytes_shipped - s0,
               hit_frac=round((cqp.cache_hits - h0) / (repeat * live), 3),
               speedup=round(miss / max(warm, 1e-9), 1))
    del cl, cqp, ct


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.quick:
        common.QUICK = True
    run()
    common.print_csv()
    if args.json:
        common.write_json(args.json)


if __name__ == "__main__":
    main()
