"""Fig. 9: DISTINCT and GROUP BY+SUM vs LCPU/RCPU dict baselines.

(a) distinct with #distinct == #rows (worst case), (b) group-by with
growing data size, (c) group-by with fixed group count. The FV path is the
hash_group kernel + client-side overflow merge; the baseline is a python
dict (the paper used a fast C++ hash map — CPU numbers are indicative,
shipped bytes exact)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.core import operators as op
from repro.core.client import (FViewNode, alloc_table_mem, farview_request,
                               open_connection,
                               table_write)
from repro.core.table import FTable, Column


def run() -> None:
    node = FViewNode(512 * 2**20)
    qp = open_connection(node)
    rng = np.random.default_rng(1)

    # (a) DISTINCT, all-unique worst case + low-cardinality best case
    for n, card, tag in [(1 << 11, 1 << 11, "unique"), (1 << 13, 64, "c64")]:
        ft = FTable("d", (Column("k", "i32"), Column("v")), n_rows=n)
        alloc_table_mem(qp, ft)
        keys = (np.arange(n, dtype=np.int32) if card == n
                else rng.integers(0, card, n).astype(np.int32))
        data = {"k": keys, "v": rng.normal(size=n).astype(np.float32)}
        table_write(qp, ft, ft.encode(data))
        pipe = (op.Distinct(("k",), n_buckets=1 << 12),)
        res = farview_request(qp, ft, pipe)
        us_fv = timeit(lambda: farview_request(qp, ft, pipe), repeat=3) * 1e6
        us_lcpu = timeit(lambda: np.unique(keys), repeat=3) * 1e6
        row("grouping", f"FV_distinct_{tag}", us_fv,
            shipped_bytes=res.shipped_bytes, rows=n)
        row("grouping", f"LCPU_distinct_{tag}", us_lcpu,
            shipped_bytes=0, rows=n)
        row("grouping", f"RCPU_distinct_{tag}", us_lcpu,
            shipped_bytes=ft.n_bytes, rows=n)
        node.pool.free_table(ft)

    # (b)+(c) GROUP BY k SUM(v): data-size sweep at card=256
    for n in (1 << 12, 1 << 13, 1 << 14):
        ft = FTable("g", (Column("k", "i32"), Column("v")), n_rows=n)
        alloc_table_mem(qp, ft)
        keys = rng.integers(0, 256, n).astype(np.int32)
        vals = rng.normal(size=n).astype(np.float32)
        table_write(qp, ft, ft.encode({"k": keys, "v": vals}))
        pipe = (op.GroupBy("k", ("v",), n_buckets=1024),)
        res = farview_request(qp, ft, pipe)
        us_fv = timeit(lambda: farview_request(qp, ft, pipe), repeat=3) * 1e6

        def lcpu():
            out = {}
            for k, v in zip(keys, vals):
                out[k] = out.get(k, 0.0) + v
            return out

        us_lcpu = timeit(lcpu, repeat=3) * 1e6
        row("grouping", f"FV_groupby_n{n}", us_fv,
            shipped_bytes=res.shipped_bytes, rows=n)
        row("grouping", f"LCPU_groupby_n{n}", us_lcpu, shipped_bytes=0,
            rows=n)
        row("grouping", f"RCPU_groupby_n{n}", us_lcpu,
            shipped_bytes=ft.n_bytes, rows=n)
        node.pool.free_table(ft)
