"""Fig. 9: DISTINCT and GROUP BY+SUM vs LCPU/RCPU dict baselines, plus the
cluster group scale-out sweep (PR 4).

(a) distinct with #distinct == #rows (worst case), (b) group-by with
growing data size, (c) group-by with fixed group count. The FV path is the
hash_group kernel + client-side overflow merge; the baseline is a python
dict (the paper used a fast C++ hash map — CPU numbers are indicative,
shipped bytes exact).

(d) `FV_group_scaleout_{k}nodes`: the same group-aggregate scattered over a
FarCluster of 1/2/4 nodes — throughput, stacked-dispatch count, and exact
shipped bytes per node count, so the group-scaling ceiling ROADMAP used to
describe in prose is a committed number (PR 3 recorded it flatlining at
2 nodes; the segment-reduce aggregation + device-side partial merge are
what this sweep measures)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from benchmarks.common import row, timeit
from repro.core import operators as op
from repro.core.client import (FViewNode, alloc_table_mem, farview_request,
                               open_connection,
                               table_write)
from repro.core.cluster import FarCluster
from repro.core.table import FTable, Column


def _group_scaleout() -> None:
    q = common.quick()
    n = 1 << (13 if q else 19)
    n_clients = 2 if q else 4
    node_counts = (1, 2) if q else (1, 2, 4)
    repeat = 1 if q else 5
    cols = tuple(Column(f"c{i}", "i32" if i == 0 else "f32")
                 for i in range(8))
    pipe = (op.GroupBy("c0", ("c1", "c2"), n_buckets=1024),)
    rng = np.random.default_rng(2)

    rounds = {}
    for k in node_counts:
        cl = FarCluster(k, 256 * 2**20)
        clients = []
        for c in range(n_clients):
            cqp = cl.open_connection()
            ft = FTable(f"g{c}", cols, n_rows=n)
            keys = rng.integers(0, 128, n).astype(np.int32)
            d = {"c0": keys}
            for i in range(1, 8):
                d[f"c{i}"] = rng.normal(size=n).astype(np.float32)
            # range partitions: group-aggregate needs no key co-location
            # (the device merge folds cross-node partials exactly), and
            # exact n/k splits stay on pow2 bucket boundaries — hash's
            # n/k+eps partitions would pad back up to the next bucket
            ct = cl.alloc_table_mem(cqp, ft)
            cl.table_write(cqp, ct, ft.encode(d))
            clients.append((cqp, ct))

        def one_round(cl=cl, clients=clients):
            pends = [cl.submit_request(cqp, ct, pipe)
                     for cqp, ct in clients]
            return [p.wait().finalize() for p in pends]

        rounds[k] = (cl, clients, one_round)
        one_round()                             # warmup: trace + caches

    samples = {k: [] for k in node_counts}
    for _ in range(repeat):                     # interleave the node counts
        for k in node_counts:
            t0 = time.perf_counter()
            rounds[k][2]()
            samples[k].append(time.perf_counter() - t0)
    base = None
    for k in node_counts:
        cl, clients, one = rounds[k]
        d0 = cl.dispatches
        res = one()
        shipped = sum(r.shipped_bytes for r in res)
        sec = sorted(samples[k])[len(samples[k]) // 2]          # p50
        thru = n_clients * n / sec
        base = base or thru
        row("grouping", f"FV_group_scaleout_{k}nodes", sec * 1e6,
            nodes=k, clients=n_clients, rows=n_clients * n,
            dispatches=cl.dispatches - d0, shipped_bytes=shipped,
            mrows_per_s=round(thru / 1e6, 2),
            speedup=round(thru / base, 2))


def run() -> None:
    node = FViewNode(512 * 2**20)
    qp = open_connection(node)
    rng = np.random.default_rng(1)

    # (a) DISTINCT, all-unique worst case + low-cardinality best case
    for n, card, tag in [(1 << 11, 1 << 11, "unique"), (1 << 13, 64, "c64")]:
        ft = FTable("d", (Column("k", "i32"), Column("v")), n_rows=n)
        alloc_table_mem(qp, ft)
        keys = (np.arange(n, dtype=np.int32) if card == n
                else rng.integers(0, card, n).astype(np.int32))
        data = {"k": keys, "v": rng.normal(size=n).astype(np.float32)}
        table_write(qp, ft, ft.encode(data))
        pipe = (op.Distinct(("k",), n_buckets=1 << 12),)
        res = farview_request(qp, ft, pipe)
        us_fv = timeit(lambda: farview_request(qp, ft, pipe), repeat=3) * 1e6
        us_lcpu = timeit(lambda: np.unique(keys), repeat=3) * 1e6
        row("grouping", f"FV_distinct_{tag}", us_fv,
            shipped_bytes=res.shipped_bytes, rows=n)
        row("grouping", f"LCPU_distinct_{tag}", us_lcpu,
            shipped_bytes=0, rows=n)
        row("grouping", f"RCPU_distinct_{tag}", us_lcpu,
            shipped_bytes=ft.n_bytes, rows=n)
        node.pool.free_table(ft)

    # (b)+(c) GROUP BY k SUM(v): data-size sweep at card=256
    for n in (1 << 12, 1 << 13, 1 << 14):
        ft = FTable("g", (Column("k", "i32"), Column("v")), n_rows=n)
        alloc_table_mem(qp, ft)
        keys = rng.integers(0, 256, n).astype(np.int32)
        vals = rng.normal(size=n).astype(np.float32)
        table_write(qp, ft, ft.encode({"k": keys, "v": vals}))
        pipe = (op.GroupBy("k", ("v",), n_buckets=1024),)
        res = farview_request(qp, ft, pipe)
        us_fv = timeit(lambda: farview_request(qp, ft, pipe), repeat=3) * 1e6

        def lcpu():
            out = {}
            for k, v in zip(keys, vals):
                out[k] = out.get(k, 0.0) + v
            return out

        us_lcpu = timeit(lcpu, repeat=3) * 1e6
        row("grouping", f"FV_groupby_n{n}", us_fv,
            shipped_bytes=res.shipped_bytes, rows=n)
        row("grouping", f"LCPU_groupby_n{n}", us_lcpu, shipped_bytes=0,
            rows=n)
        row("grouping", f"RCPU_groupby_n{n}", us_lcpu,
            shipped_bytes=ft.n_bytes, rows=n)
        node.pool.free_table(ft)

    # (d) cluster group scale-out: 1/2/4 nodes
    _group_scaleout()
