"""Fig. 10: regex matching vs string length, FV DFA kernel vs python re
(the RE2 stand-in), ~50% match rate. The paper's claim re-validated
structurally: FV cost depends on string length only, not pattern
complexity — measured by timing a trivial and a complex pattern at the
same length."""
from __future__ import annotations

import re as pyre

import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, timeit
from repro.core.regex import compile_regex
from repro.core.table import string_table
from repro.kernels import ops as kops


def _make_strings(n, width, rng, hit_token=b"err"):
    out = []
    for i in range(n):
        s = bytes(rng.integers(97, 123, size=width - 4).astype(np.uint8))
        if i % 2 == 0:
            pos = int(rng.integers(0, width - 7))
            s = s[:pos] + hit_token + s[pos:]
        out.append(s[:width])
    return out


def run(n: int = 4096) -> None:
    rng = np.random.default_rng(2)
    for width in (16, 32, 64, 128):
        strs = _make_strings(n, width, rng)
        ft, mat, lens = string_table("s", strs, width)
        table, accept = compile_regex("err")
        tj, aj = jnp.asarray(table), jnp.asarray(accept)
        mj, lj = jnp.asarray(mat), jnp.asarray(lens)
        kops.regex_match(mj, lj, tj, aj)       # warm
        us_fv = timeit(
            lambda: np.asarray(kops.regex_match(mj, lj, tj, aj)),
            repeat=3) * 1e6
        pat = pyre.compile(b"err")
        us_re = timeit(lambda: [bool(pat.search(s)) for s in strs],
                       repeat=3) * 1e6
        row("regex", f"FV_w{width}", us_fv, rows=n,
            shipped_bytes=n)       # 1 byte/row decision
        row("regex", f"RE_w{width}", us_re, rows=n, shipped_bytes=0)

    # pattern-complexity independence at fixed width
    width, strs = 64, _make_strings(n, 64, rng)
    ft, mat, lens = string_table("s", strs, width)
    mj, lj = jnp.asarray(mat), jnp.asarray(lens)
    for tag, pattern in [("simple", "err"),
                         ("complex", "e(r|x)+[a-f]*r?")]:
        table, accept = compile_regex(pattern)
        tj, aj = jnp.asarray(table), jnp.asarray(accept)
        kops.regex_match(mj, lj, tj, aj)
        us = timeit(lambda: np.asarray(kops.regex_match(mj, lj, tj, aj)),
                    repeat=3) * 1e6
        row("regex", f"FV_pat_{tag}_S{table.shape[0]}", us, rows=n)
