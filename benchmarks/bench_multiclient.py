"""Fig. 12: six concurrent clients running the distinct query.

FV: six dynamic regions on one node, each running its own pipeline over its
own table. Clients submit asynchronously; the node's scheduler serves one
request per QPair per round (§4.3 round-robin fair share) and coalesces the
round's same-signature requests into ONE stacked executable dispatch, so
the six clients cost one traced program, not six. Completion time = all six
materialized. The fair-share property asserted: per-client times within 2x
of each other."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.core import operators as op
from repro.core.client import (FViewNode, alloc_table_mem, farview_request,
                               open_connection, submit_request, table_write)
from repro.core.table import FTable, Column


def run(n_rows: int = 1 << 13, n_clients: int = 6) -> None:
    node = FViewNode(512 * 2**20, n_regions=n_clients)
    rng = np.random.default_rng(3)
    qps, fts, keysets = [], [], []
    for i in range(n_clients):
        qp = open_connection(node)
        ft = FTable(f"t{i}", (Column("k", "i32"), Column("v")),
                    n_rows=n_rows)
        alloc_table_mem(qp, ft)
        keys = rng.integers(0, 64, n_rows).astype(np.int32)
        table_write(qp, ft, ft.encode(
            {"k": keys, "v": rng.normal(size=n_rows).astype(np.float32)}))
        qps.append(qp)
        fts.append(ft)
        keysets.append(keys)
    pipe = (op.Distinct(("k",), n_buckets=256),)

    def all_clients():
        """Async submit x6 -> one scheduling round -> one stacked dispatch."""
        pend = [submit_request(qp, ft, pipe) for qp, ft in zip(qps, fts)]
        node.flush()
        return [p.result for p in pend]

    all_clients()                              # warm the batched executable
    for qp, ft in zip(qps, fts):
        farview_request(qp, ft, pipe).finalize()   # warm the solo executable

    us_all = timeit(all_clients, repeat=3) * 1e6
    per = []
    for qp, ft in zip(qps, fts):
        per.append(timeit(lambda: farview_request(qp, ft, pipe),
                          repeat=3) * 1e6)

    def lcpu_all():
        for keys in keysets:
            np.unique(keys)

    us_lcpu = timeit(lcpu_all, repeat=3) * 1e6
    row("multiclient", f"FV_{n_clients}clients", us_all,
        fair_ratio=round(max(per) / max(min(per), 1e-9), 2))
    row("multiclient", f"FV_{n_clients}solo_sum", sum(per))
    row("multiclient", f"LCPU_{n_clients}proc", us_lcpu)
    row("multiclient", f"RCPU_{n_clients}proc", us_lcpu,
        shipped_bytes=sum(ft.n_bytes for ft in fts))
