"""Fig. 6: raw read throughput / response time vs transfer size.

FV = pool read through the Farview node (table_read). RNIC analogue = a
direct numpy memcpy of the same bytes (the commercial-NIC-over-PCIe role).
Also derives the modeled network seconds at 100 Gbps for each size — the
paper's RTT floor — so the CPU wall-time and the modeled wire-time are both
visible."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.core.client import (FViewNode, alloc_table_mem, open_connection,
                               table_read, table_write)
from repro.core.table import FTable, Column
from repro.data.pipeline import db_table_columns

NET_BPS = 100e9 / 8           # 100 Gbps


def run() -> None:
    node = FViewNode(512 * 2**20)
    qp = open_connection(node)
    for kb in (1, 4, 16, 64, 256, 1024, 4096):
        n_rows = max(1, kb * 1024 // 32)
        ft = FTable("t", tuple(Column(f"c{i}") for i in range(8)),
                    n_rows=n_rows)
        alloc_table_mem(qp, ft)
        table_write(qp, ft, ft.encode(db_table_columns(n_rows)))
        out = table_read(qp, ft)          # warm
        us = timeit(lambda: np.asarray(table_read(qp, ft))) * 1e6
        src = np.asarray(out)
        us_memcpy = timeit(lambda: src.copy()) * 1e6
        wire_us = ft.n_bytes / NET_BPS * 1e6
        row("rdma", f"FV_read_{kb}kB", us,
            gbps=round(ft.n_bytes * 8 / (us / 1e6) / 1e9, 2),
            wire_us_100g=round(wire_us, 2))
        row("rdma", f"RNIC_memcpy_{kb}kB", us_memcpy,
            gbps=round(ft.n_bytes * 8 / (us_memcpy / 1e6) / 1e9, 2),
            wire_us_100g=round(wire_us, 2))
        node.pool.free_table(ft)
