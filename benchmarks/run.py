"""Benchmark harness: one module per paper table/figure. CSV to stdout.

  bench_rdma        Fig. 6   read throughput / response time
  bench_projection  Fig. 7   projection vs smart addressing
  bench_selection   Fig. 8   selection @ 100/50/25% selectivity
  bench_grouping    Fig. 9   distinct / group-by+sum
  bench_regex       Fig. 10  regex matching
  bench_crypto      Fig. 11  encryption on the read path
  bench_multiclient Fig. 12  6 concurrent clients
  bench_join        (§7 fut.) small-table in-memory join
  bench_resources   Table 1  per-operator resource budget
  bench_far_kv      (LM)     far-KV push-down economics

Wall-times are CPU-indicative (kernels run interpret=True); shipped/read
byte columns are exact and carry the paper's actual claims.
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (bench_crypto, bench_far_kv, bench_grouping,
                        bench_join, bench_multiclient, bench_projection,
                        bench_rdma, bench_regex, bench_resources,
                        bench_selection)
from benchmarks.common import print_csv

ALL = {
    "rdma": bench_rdma.run,
    "projection": bench_projection.run,
    "selection": bench_selection.run,
    "grouping": bench_grouping.run,
    "regex": bench_regex.run,
    "crypto": bench_crypto.run,
    "multiclient": bench_multiclient.run,
    "join": bench_join.run,
    "resources": bench_resources.run,
    "far_kv": bench_far_kv.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=tuple(ALL))
    args = ap.parse_args()
    for name, fn in ALL.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        fn()
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    print_csv()


if __name__ == "__main__":
    main()
