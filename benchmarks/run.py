"""Benchmark harness: one module per paper table/figure. CSV to stdout.

  bench_rdma        Fig. 6   read throughput / response time
  bench_projection  Fig. 7   projection vs smart addressing
  bench_selection   Fig. 8   selection @ 100/50/25% selectivity
  bench_grouping    Fig. 9   distinct / group-by+sum
  bench_regex       Fig. 10  regex matching
  bench_crypto      Fig. 11  encryption on the read path
  bench_multiclient Fig. 12  6 concurrent clients (stacked dispatch)
  bench_multiclient_mixed    mixed-size/kind round: 3 stacked dispatches
                             serve 8 clients (bucketing + string/join stacks)
  bench_join        (§7 fut.) small-table in-memory join
  bench_resources   Table 1  per-operator resource budget
  bench_far_kv      (LM)     far-KV push-down economics
  bench_cluster_scaleout     mixed-workload throughput at 1/2/4 nodes
  bench_rebalance            skew-flip -> drift detect -> live migration
                             -> throughput recovery vs a fresh map
  bench_failover             kill-a-node under mixed load: byte-identical
                             failover dip -> heal -> throughput recovery
  bench_network              FViewServer fan-in: p50/p99 request latency
                             vs connection count + typed overload shedding
  bench_chaos                seeded socket faults through ChaosProxy:
                             clean/soak/degraded phases, chaos tail ratio
                             and hedged gray-failure recovery
  bench_tiering              hot/cold memory tiering: effective-capacity
                             multiplier, cold-scan byte reduction, hot-path
                             no-regression round-trip, client cache hits

FV rows time the fused jitted request path with BLOCKING p50 timing (see
common.timeit); shipped/read byte columns are exact and carry the paper's
actual claims.

`--json PATH` additionally writes `{"meta": ..., "rows": [...]}`: the rows
are structured records (bench, name, us_per_call, plus per-bench fields
like shipped_frac/rows) and the meta block stamps git commit, jax version,
device platform and quick-mode — so BENCH_*.json files form a comparable
trajectory PR over PR, e.g.:

    python -m benchmarks.run --json BENCH_$(date +%Y%m%d_%H%M%S).json

`benchmarks.check_regression` diffs two such files (CI runs it against the
latest committed BENCH_*.json and fails on a >2x p50 regression).
"""
from __future__ import annotations

import argparse
import sys
import time

from benchmarks import (bench_chaos, bench_cluster_scaleout, bench_crypto,
                        bench_failover, bench_far_kv, bench_grouping,
                        bench_join, bench_multiclient,
                        bench_multiclient_mixed, bench_network,
                        bench_projection, bench_rdma, bench_rebalance,
                        bench_regex, bench_resources, bench_selection,
                        bench_tiering, common)
from benchmarks.common import print_csv, write_json

ALL = {
    "rdma": bench_rdma.run,
    "projection": bench_projection.run,
    "selection": bench_selection.run,
    "grouping": bench_grouping.run,
    "regex": bench_regex.run,
    "crypto": bench_crypto.run,
    "multiclient": bench_multiclient.run,
    "multiclient_mixed": bench_multiclient_mixed.run,
    "join": bench_join.run,
    "resources": bench_resources.run,
    "far_kv": bench_far_kv.run,
    "cluster_scaleout": bench_cluster_scaleout.run,
    "rebalance": bench_rebalance.run,
    "failover": bench_failover.run,
    "network": bench_network.run,
    "chaos": bench_chaos.run,
    "tiering": bench_tiering.run,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, choices=tuple(ALL))
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as a JSON record list "
                         "(e.g. BENCH_20260728_120000.json)")
    ap.add_argument("--quick", action="store_true",
                    help="smoke mode (CI): 1 warmup + 1 repeat, reduced "
                         "sizes — indicative timings, exact byte columns")
    args = ap.parse_args()
    if args.quick:
        common.QUICK = True
    for name, fn in ALL.items():
        if args.only and name != args.only:
            continue
        t0 = time.time()
        fn()
        print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
    print_csv()
    if args.json:
        write_json(args.json)
        print(f"# wrote {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
