"""Table 1 analogue: per-operator resource budget.

FPGA LUT/BRAM%% -> TPU resource budget: VMEM working set claimed by each
kernel's BlockSpecs (vs 128 MiB/core on v5e... we report vs 16 MiB
VMEM-per-core class budget), plus flops/bytes per call from the jnp
reference (exact op counts)."""
from __future__ import annotations


from benchmarks.common import row

VMEM_BYTES = 16 * 2**20        # v5e-class per-core VMEM


def _vmem(*shapes_dtypes) -> int:
    total = 0
    for shape, bts in shapes_dtypes:
        n = 1
        for d in shape:
            n *= d
        total += n * bts
    return total


def run() -> None:
    # select_project: (256,128) f32 in + out + 3 param rows + perm matrix
    br, c = 256, 128
    v = _vmem(((br, c), 4), ((br, c), 4), ((3, c), 4), ((br, br), 4))
    row("resources", "select_project", 0, vmem_kb=v // 1024,
        vmem_pct=round(100 * v / VMEM_BYTES, 2),
        flops_per_row=2 * c + 2 * br)     # predicate + perm-matmul row

    # hash_group: block rows + bucket tables (B=1024, V=4)
    b, vcols = 1024, 4
    v = _vmem(((br, 1), 4), ((br, vcols), 4), ((b, 1), 4), ((b, 1), 4),
              ((b, vcols), 4), ((b, vcols), 4), ((b, vcols), 4),
              ((b, br), 4))
    row("resources", "hash_group", 0, vmem_kb=v // 1024,
        vmem_pct=round(100 * v / VMEM_BYTES, 2),
        flops_per_row=2 * b * (2 + vcols))

    # dfa_match: chars (L=64,128) + table (256,S=32) + state one-hots
    l, nstr, s = 64, 128, 32
    v = _vmem(((l, nstr), 4), ((256, s), 4), ((s, nstr), 4),
              ((256, nstr), 4))
    row("resources", "dfa_match", 0, vmem_kb=v // 1024,
        vmem_pct=round(100 * v / VMEM_BYTES, 2),
        flops_per_char=2 * s * 256)

    # ctr_crypt: (256,128) u32 in/out + keystream
    v = _vmem(((256, 128), 4), ((256, 128), 4), ((256, 128), 4))
    row("resources", "ctr_crypt", 0, vmem_kb=v // 1024,
        vmem_pct=round(100 * v / VMEM_BYTES, 2),
        flops_per_word=5 * 20)            # ~5 ops x 20 rounds

    # decode_attention: q (8,128) + kv blocks (256,128)x2 + acc
    g, d, bkv = 8, 128, 256
    v = _vmem(((g, d), 4), ((bkv, d), 4), ((bkv, d), 4), ((g, bkv), 4),
              ((g, d), 4))
    row("resources", "decode_attention", 0, vmem_kb=v // 1024,
        vmem_pct=round(100 * v / VMEM_BYTES, 2),
        flops_per_kv_row=4 * g * d)
