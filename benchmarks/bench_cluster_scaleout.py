"""Cluster scale-out: aggregate throughput vs FViewNode count (PR 3).

A mixed workload — per client one selection, one group-aggregate, one regex
and one join-probe request — is scattered over a FarCluster of 1/2/4 nodes
holding the same logical tables (range-partitioned; the join build
replicated). The timed region is the full scatter-gather verb: submit,
per-node bucket-batched flush (nodes drain in parallel threads), client
merge, finalize.

Throughput = total input rows pushed through operator pipelines per second
of wall time. On this container every "node" shares one CPU, so the
scale-out win comes from overlapping the nodes' dispatch + executable
streams rather than from extra silicon; byte accounting stays exact and
identical across node counts (asserted in tests/test_cluster.py).

Standalone:  python -m benchmarks.bench_cluster_scaleout --json BENCH.json
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import operators as op
from repro.core.cluster import FarCluster
from repro.core.table import Column, FTable, string_table

STRS = [b"error: disk full", b"all fine here", b"ERROR", b"warn: error",
        b"errr", b"the error is late", b"nothing to see", b"ok ok ok"]


def _word_data(rng, n, card):
    d = {"c0": rng.integers(0, card, n).astype(np.int32)}
    for i in range(1, 8):
        d[f"c{i}"] = rng.normal(size=n).astype(np.float32)
    return d


def _setup(k, n_clients, n_word, n_str, str_w):
    """One cluster + per-client tables; returns (cluster, request list)."""
    cols = tuple(Column(f"c{i}", "i32" if i == 0 else "f32")
                 for i in range(8))
    cl = FarCluster(k, 256 * 2**20)
    rng = np.random.default_rng(0)
    requests = []
    cqp0 = cl.open_connection()
    build = FTable("dim", (Column("k", "i32"), Column("v")), n_rows=64)
    cb = cl.alloc_table_mem(cqp0, build, replicate=True)
    cl.table_write(cqp0, cb, build.encode(
        {"k": rng.permutation(128)[:64].astype(np.int32),
         "v": rng.random(64).astype(np.float32)}))
    sel = (op.Select((op.Predicate("c1", "<", 0.2),)),)
    grp = (op.GroupBy("c0", ("c1", "c2"), n_buckets=256),)
    rgx = (op.RegexMatch("error"),)
    joi = (op.JoinSmall(probe_key="c0", build_table="dim",
                        build_key="k", build_cols=("v",)),)
    for c in range(n_clients):
        cqp = cl.open_connection()
        wft = cl.alloc_table_mem(cqp, FTable(f"w{c}", cols, n_rows=n_word))
        cl.table_write(cqp, wft, FTable(f"w{c}", cols, n_rows=n_word)
                       .encode(_word_data(rng, n_word, 64)))
        gft = cl.alloc_table_mem(cqp, FTable(f"g{c}", cols, n_rows=n_word))
        cl.table_write(cqp, gft, FTable(f"g{c}", cols, n_rows=n_word)
                       .encode(_word_data(rng, n_word, 128)))
        strs = [STRS[j] for j in rng.integers(0, len(STRS), n_str)]
        sft, mat, lens = string_table(f"s{c}", strs, str_w)
        cst = cl.alloc_table_mem(
            cqp, FTable(f"s{c}", sft.columns, n_rows=n_str, str_width=str_w))
        requests += [
            (cqp, wft, sel, None, None),
            (cqp, gft, grp, None, None),
            (cqp, cst, rgx, mat, lens),
            (cqp, wft, joi, None, None),
        ]
    return cl, requests


def run() -> None:
    q = common.quick()
    # sizes where compute dominates per-dispatch overhead: a 2-core host
    # shows real overlap only once each node's executable runs for long
    # enough that the nodes' streams actually interleave
    n_word = 1 << (13 if q else 19)
    n_str = 1 << (10 if q else 14)
    n_clients = 2 if q else 4
    node_counts = (1, 2) if q else (1, 2, 4)
    str_w = 32
    repeat = 1 if q else 5
    rows_per_round = n_clients * (3 * n_word + n_str)

    def make_round(cl, requests):
        def one_round():
            pends = [cl.submit_request(cqp, ct, pipe,
                                       strings=mat, lengths=lens)
                     for cqp, ct, pipe, mat, lens in requests]
            return [p.wait() for p in pends]
        return one_round

    # all clusters up front, then INTERLEAVED rounds: sample k=1,2,4,
    # 1,2,4, ... so host-load drift hits every node count equally instead
    # of whichever happened to run last
    rounds, clusters = {}, {}
    for k in node_counts:
        cl, requests = _setup(k, n_clients, n_word, n_str, str_w)
        clusters[k] = cl
        rounds[k] = make_round(cl, requests)
        for res in rounds[k]():                 # warmup: trace + caches
            res.finalize()
    samples = {k: [] for k in node_counts}
    for _ in range(repeat):
        for k in node_counts:
            t0 = time.perf_counter()
            for res in rounds[k]():
                res.finalize()
            samples[k].append(time.perf_counter() - t0)
    base = None
    for k in node_counts:
        sec = sorted(samples[k])[len(samples[k]) // 2]          # p50
        thru = rows_per_round / sec
        base = base or thru
        valid, padded = common.cluster_padding(
            *clusters[k].catalog.values())
        common.row("cluster_scaleout", f"{k}nodes", sec * 1e6,
                   nodes=k, clients=n_clients,
                   rows_per_round=rows_per_round,
                   mrows_per_s=round(thru / 1e6, 2),
                   speedup=round(thru / base, 2),
                   valid_rows=valid, padded_rows=padded)
    _write_amplification()


def _write_amplification() -> None:
    """The price of surviving a node loss (PR 6): `replicas=2` writes
    every partition twice, so ingest pays ~2x the pool bytes and wall
    time of the single-copy layout. Reported side by side so the cost
    of redundancy stays visible next to its failover benefit (see
    bench_failover)."""
    q = common.quick()
    n = 1 << (13 if q else 18)
    cols = tuple(Column(f"c{i}", "i32" if i == 0 else "f32")
                 for i in range(8))
    rng = np.random.default_rng(1)
    words = FTable("t", cols, n_rows=n).encode(_word_data(rng, n, 64))
    for k in (2,) if q else (2, 4):
        bytes_by_rep, sec_by_rep = {}, {}
        for rep in (1, 2):
            cl = FarCluster(k, 128 * 2**20, replicas=rep)
            cqp = cl.open_connection()
            w0 = cl.stats.bytes_written
            t0 = time.perf_counter()
            ct = cl.alloc_table_mem(cqp, FTable("t", cols, n_rows=n))
            cl.table_write(cqp, ct, words)
            sec_by_rep[rep] = time.perf_counter() - t0
            bytes_by_rep[rep] = cl.stats.bytes_written - w0
            replica_bytes = (0 if ct.heat.replica_bytes_written is None
                             else int(ct.heat.replica_bytes_written.sum()))
            common.row("cluster_scaleout", f"write_k{rep}_{k}nodes",
                       sec_by_rep[rep] * 1e6, nodes=k, rows=n, replicas=rep,
                       bytes_written=int(bytes_by_rep[rep]),
                       replica_bytes=replica_bytes,
                       write_amplification=round(
                           bytes_by_rep[rep] / bytes_by_rep[1], 2))
            del cl, cqp, ct


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="PATH")
    ap.add_argument("--quick", action="store_true")
    args = ap.parse_args()
    if args.quick:
        common.QUICK = True
    run()
    common.print_csv()
    if args.json:
        common.write_json(args.json)


if __name__ == "__main__":
    main()
