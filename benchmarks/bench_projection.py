"""Fig. 7: standard projection vs smart addressing across tuple widths.

The crossover: with wide tuples, reading only the projected columns
(smart addressing) beats streaming full rows; with narrow tuples the
sequential full-row read wins. The exact pool-read byte counts expose the
crossover even where CPU timings are noisy."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.core import operators as op
from repro.core.client import (FViewNode, alloc_table_mem, farview_request,
                               open_connection, table_write)
from repro.core.table import FTable, Column


def run(n_rows: int = 1 << 14) -> None:
    node = FViewNode(512 * 2**20)
    qp = open_connection(node)
    rng = np.random.default_rng(0)
    for tuple_bytes in (64, 128, 256, 512):
        n_cols = tuple_bytes // 4
        cols = tuple(Column(f"c{i}") for i in range(n_cols))
        ft = FTable(f"w{tuple_bytes}", cols, n_rows=n_rows)
        alloc_table_mem(qp, ft)
        data = {f"c{i}": rng.normal(size=n_rows).astype(np.float32)
                for i in range(n_cols)}
        table_write(qp, ft, ft.encode(data))
        proj_cols = ("c0", "c1", "c2")       # 3 contiguous columns (paper)

        p_std = (op.Project(proj_cols),)
        p_sa = (op.SmartAddress(proj_cols),)
        r_std = farview_request(qp, ft, p_std)
        r_sa = farview_request(qp, ft, p_sa)
        us_std = timeit(lambda: farview_request(qp, ft, p_std)) * 1e6
        us_sa = timeit(lambda: farview_request(qp, ft, p_sa)) * 1e6
        row("projection", f"FV_t{tuple_bytes}B", us_std,
            pool_read_bytes=r_std.read_bytes, rows=n_rows)
        row("projection", f"FV-SA_t{tuple_bytes}B", us_sa,
            pool_read_bytes=r_sa.read_bytes, rows=n_rows)
        node.pool.free_table(ft)
