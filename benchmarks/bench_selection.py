"""Fig. 8: selection at 100% / 50% / 25% selectivity, FV vs LCPU vs RCPU.

Measures per-query blocking p50 wall time (the FV closure's lazy result is
finalized inside the timed region — completed work, not async dispatch)
and the exact shipped-bytes fraction (the paper's actual claim: bytes over
the wire ∝ selectivity, so FV wins whenever selectivity < 1)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import row, timeit
from repro.core import operators as op
from repro.core.client import (FViewNode, alloc_table_mem, farview_request,
                               open_connection, table_write)
from repro.core.table import FTable, Column
from repro.data.pipeline import db_table_columns


def run(n_rows: int = 1 << 15) -> None:
    node = FViewNode(256 * 2**20)
    qp = open_connection(node)
    cols = tuple(Column(f"c{i}") for i in range(8))
    ft = FTable("sel", cols, n_rows=n_rows)
    alloc_table_mem(qp, ft)
    data = db_table_columns(n_rows)
    words = ft.encode(data)
    table_write(qp, ft, words)
    arr = np.stack([data[f"c{i}"] for i in range(8)], axis=1)

    # thresholds for 100/50/25% on two independent N(0,1) columns
    # P(a<t1)*P(b<t2) with symmetric split per column
    for sel_pct, t in [(100, 1e9), (50, 0.0), (25, -0.6745)]:
        if sel_pct == 100:
            preds = (op.Predicate("c1", "<", t),)
        elif sel_pct == 50:
            preds = (op.Predicate("c1", "<", 0.0),)
        else:
            preds = (op.Predicate("c1", "<", 0.0),
                     op.Predicate("c2", "<", 0.0))
        pipe = (op.Select(preds),)

        res = farview_request(qp, ft, pipe)   # warm pipeline cache
        us_fv = timeit(lambda: farview_request(qp, ft, pipe)) * 1e6

        def lcpu():
            mask = np.ones(n_rows, bool)
            for p in preds:
                mask &= arr[:, int(p.col[1:])] < p.value
            return arr[mask].copy()            # write-back, like the paper

        us_lcpu = timeit(lcpu) * 1e6
        # RCPU = ship whole table, then LCPU processing
        us_rcpu = us_lcpu                      # same compute path
        rcpu_shipped = ft.n_bytes

        row("selection", f"FV_sel{sel_pct}", us_fv,
            shipped_frac=round(res.shipped_bytes / ft.n_bytes, 4),
            rows=n_rows)
        row("selection", f"LCPU_sel{sel_pct}", us_lcpu, shipped_frac=0.0,
            rows=n_rows)
        row("selection", f"RCPU_sel{sel_pct}", us_rcpu, shipped_frac=1.0,
            rows=n_rows)
