"""`RemoteNodeHandle`: the socket transport that duck-types `FViewNode`.

`FarCluster` talks to a node through a narrow surface — `submit` /
`flush` / `settle` / `has_queued` / `open_connection` / `tables` /
`pool` — and this class implements exactly that surface over one TCP
connection speaking `net/wire.py` frames, so the scatter-gather merge,
PR 6 failover and PR 5 rebalancing run UNCHANGED over sockets:

  * `submit` ships the verb immediately as a `SUBMIT` frame (the
    server admits or sheds, and batches admitted verbs into its node's
    scheduler rounds); the returned `RemotePending` mirrors
    `PendingRequest` (`.result` / `.error` / `.wait()`).
  * `flush` sends the `FLUSH` barrier and absorbs `RESULT` / typed
    `ERROR` frames until the server acks — each result rebuilds as an
    ALREADY-FINALIZED `PipelineResult` from wire arrays, which is all
    `offload._merge` reads, so merges are byte-identical to in-process.
  * any socket death (reset, EOF, timeout) becomes
    `NodeDeadError(node_id)` on every in-flight verb — the same typed
    error an in-process killed node raises — so `ClusterPending`
    reroutes to a replica across a REAL connection drop and the health
    monitor marks the node DEAD, exactly as PR 6 specified.

Send failures inside `submit` do NOT raise: they attach the
`NodeDeadError` to the pending (like an in-process dispatch-time
fault), because failover resolves mid-flight in `wait()`, not at
submit. Catalog maintenance (`tables[...]` / `.pop`) on a dead node is
best-effort — the node's catalog died with it; the cluster-side heal
rebuilds elsewhere.
"""
from __future__ import annotations

import itertools
import socket
import threading
import time

import numpy as np

from repro.core import client as fv
from repro.core import operators as op_ir
from repro.core.pipeline import PipelineResult
from repro.core.pool import PoolStats
from repro.distributed.health import CircuitBreaker
from repro.net import wire


class RemoteQPair:
    """Client-side view of a server virtual QPair: same id/counter
    surface as `fv.QPair`; byte counters mirror the server's accounting
    from each RESULT frame and settle through the handle."""

    def __init__(self, node: "RemoteNodeHandle", vqp: int, region: int):
        self.qp_id = vqp
        self.vqp = vqp
        self.node = node
        self.region = region
        self.requests = 0
        self._bytes_shipped = 0
        self._bytes_read_pool = 0

    @property
    def bytes_shipped(self) -> int:
        self.node.settle()
        return self._bytes_shipped

    @property
    def bytes_read_pool(self) -> int:
        self.node.settle()
        return self._bytes_read_pool


class RemotePending:
    """Mirror of `fv.PendingRequest` for a wire-submitted verb."""

    def __init__(self, node: "RemoteNodeHandle", qp: RemoteQPair,
                 req_id: int, ft):
        self.node = node
        self.qp = qp
        self.req_id = req_id
        self.ft = ft
        self.result: PipelineResult | None = None
        self.error: Exception | None = None

    def _attach(self, payload: dict) -> None:
        res = PipelineResult(
            payload["kind"], rows=payload.get("rows"),
            count=payload.get("count"), groups=payload.get("groups"),
            mask=payload.get("mask"),
            shipped_bytes=int(payload.get("shipped", 0)),
            read_bytes=int(payload.get("read", 0)),
            sel_ids=payload.get("sel_ids"))
        self.result = res
        self.qp.requests += 1
        self.qp._bytes_shipped += int(payload.get("shipped", 0))
        self.qp._bytes_read_pool += int(payload.get("read", 0))

    def wait(self) -> PipelineResult:
        if self.result is None and self.error is None:
            try:
                self.node.flush()
            except Exception:
                # another request's failure; ours may have resolved fine
                if self.result is None and self.error is None:
                    raise
        if self.error is not None:
            raise self.error
        return self.result.finalize()


class RemoteCatalog:
    """The node catalog (`name -> FTable`) over REGISTER/UNREGISTER
    frames, with a local mirror for reads. Best-effort on a dead node:
    its catalog is gone anyway, and cluster alias refreshes must not
    wedge a heal on an unreachable server."""

    def __init__(self, node: "RemoteNodeHandle"):
        self._node = node
        self._local: dict = {}

    def __setitem__(self, name: str, ft) -> None:
        self._local[name] = ft
        try:
            self._node._call(wire.REGISTER,
                             {"name": name, "table_id": ft.table_id},
                             op="register")
        except fv.NodeDeadError:
            pass

    def pop(self, name: str, default=None):
        out = self._local.pop(name, default)
        try:
            self._node._call(wire.UNREGISTER, {"name": name},
                             op="unregister")
        except fv.NodeDeadError:
            pass
        return out

    def __getitem__(self, name: str):
        return self._local[name]

    def get(self, name: str, default=None):
        return self._local.get(name, default)

    def __contains__(self, name: str) -> bool:
        return name in self._local

    def __len__(self) -> int:
        return len(self._local)


class RemotePool:
    """The `FarPool` verb surface over ALLOC/FREE/WRITE/READ frames.
    Placement (`table_id`, `pages`) is stamped by the SERVER's pool;
    the client-side FTable handle just records it."""

    def __init__(self, node: "RemoteNodeHandle"):
        self._node = node
        self._last_stats = PoolStats()

    def alloc_table(self, ft):
        resp = self._node._call(wire.ALLOC, {"ft": ft}, op="alloc")
        ft.table_id = int(resp["table_id"])
        ft.pages = tuple(int(p) for p in resp["pages"])
        return ft

    def free_table(self, ft) -> None:
        self._node._call(wire.FREE, {"table_id": ft.table_id}, op="free")

    def write_table(self, ft, words) -> None:
        self._node._call(
            wire.WRITE,
            {"table_id": ft.table_id,
             "data": np.asarray(words, np.float32)}, op="table_write")

    def read_table(self, ft):
        return self._node._call(wire.READ, {"table_id": ft.table_id},
                                op="table_read")["data"]

    def read_rows(self, ft, row_idx):
        return self._node._call(
            wire.READ_ROWS,
            {"table_id": ft.table_id, "idx": np.asarray(row_idx)},
            op="table_read")["data"]

    # ---- tiering (PR 10): the tier lives in the SERVER's pool. The
    # server-side read/submit paths note accesses and bill compressed
    # physical bytes against their own ledgers; over the socket the
    # DECODED rows are what ships, so this hop legitimately bills
    # logical bytes and never sees a tier bit.
    def is_tiered(self, ft) -> bool:
        return False

    def note_access(self, ft) -> bool:
        return False

    def tier_read_bytes(self, ft, col_idx=None) -> int:
        if col_idx is None:
            return ft.n_bytes
        return ft.n_rows * len(col_idx) * 4

    @property
    def stats(self) -> PoolStats:
        try:
            raw = self._node._call(wire.STATS, {}, op="stats")
        except fv.NodeDeadError:
            return self._last_stats      # last observation of a dead node
        self._last_stats = PoolStats(
            bytes_read=int(raw["bytes_read"]),
            bytes_written=int(raw["bytes_written"]),
            bytes_shipped=int(raw["bytes_shipped"]),
            requests=int(raw["requests"]))
        return self._last_stats


class RemoteNodeHandle:
    """One TCP connection to a `FViewServer`, presenting the
    `FViewNode` duck type (see module docstring)."""

    def __init__(self, host: str, port: int, *, node_id: int = 0,
                 timeout_s: float = 120.0,
                 max_payload: int = wire.MAX_PAYLOAD,
                 reconnect: bool = True,
                 reconnect_attempts: int = 3,
                 reconnect_backoff_s: float = 0.05,
                 reconnect_reset_s: float = 0.5):
        self.host = host
        self.port = port
        self.node_id = node_id
        self.timeout_s = float(timeout_s)
        self.max_payload = int(max_payload)
        self.reconnect = bool(reconnect)
        self.reconnect_attempts = int(reconnect_attempts)
        self.reconnect_backoff_s = float(reconnect_backoff_s)
        # gates reconnection so a down server is probed, not hammered:
        # one failed reconnect cycle trips OPEN (fast-fail verbs), and
        # after reset_after_s a single HALF_OPEN probe retries.
        self._breaker = CircuitBreaker(
            1, open_after=1, reset_after_s=float(reconnect_reset_s))
        self._closed = False
        self._ever_connected = False
        # serializes the socket: cluster drain threads, settle-on-read
        # counters and catalog calls may interleave. RLock because
        # settle -> flush -> _recv re-enter through property reads.
        self._lock = threading.RLock()
        self._req_ids = itertools.count(1)
        self._pending: dict[int, RemotePending] = {}    # guarded-by: self._lock
        self._qpairs: dict[int, RemoteQPair] = {}
        self._dead = False
        self._sock: socket.socket | None = None
        self.tables = RemoteCatalog(self)
        self.pool = RemotePool(self)
        self._connect()

    # ------------------------------------------------------------ transport
    def _connect(self) -> None:
        try:
            self._sock = socket.create_connection(
                (self.host, self.port), timeout=self.timeout_s)
            self._sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
        except OSError as e:
            self._dead = True
            raise fv.NodeDeadError(self.node_id, op="connect") from e
        # version handshake: a mismatched server answers with a typed
        # ProtocolError frame instead of mis-decoding every later verb
        self._call(wire.HELLO, {"version": wire.VERSION},
                   op="hello", expect=wire.HELLO_OK)
        self._ever_connected = True

    def _reopen_qpairs(self) -> None:
        """Re-establish virtual QPairs on a freshly reconnected server,
        keeping the client-side `RemoteQPair` objects (and their byte
        counters) that callers hold references to."""
        old = list(self._qpairs.values())
        self._qpairs = {}
        for qp in old:
            resp = self._call(wire.OPEN_QP, {}, op="reconnect")
            qp.vqp = qp.qp_id = int(resp["qp"])
            qp.region = qp.vqp % max(1, int(resp.get("region_count", 1)))
            self._qpairs[qp.vqp] = qp

    def _ensure_conn(self, op: str) -> None:
        """Bounded reconnect-with-backoff behind the breaker: a server
        that was restarted resumes service on the next verb without a
        cluster-level heal; a server that stays down fast-fails while
        the breaker is OPEN and is re-probed once per reset window.
        Only a handle that connected successfully at least once
        reconnects — construction against a bad endpoint stays a
        fast, typed failure."""
        with self._lock:
            if not self._dead and self._sock is not None:
                return
            if (self._closed or not self.reconnect
                    or not self._ever_connected):
                raise fv.NodeDeadError(self.node_id, op=op)
            if not self._breaker.allow(0):
                raise fv.NodeDeadError(self.node_id, op=op)
            delay = self.reconnect_backoff_s
            last: Exception | None = None
            for attempt in range(self.reconnect_attempts):
                try:
                    self._dead = False
                    self._connect()
                    self._reopen_qpairs()
                except (fv.NodeDeadError, wire.ProtocolError, OSError) as e:
                    last = e
                    self._dead = True
                    if attempt + 1 < self.reconnect_attempts:
                        time.sleep(delay)
                        delay *= 2
                    continue
                self._breaker.record_success(0)
                return
            self._breaker.record_failure(0)
            raise fv.NodeDeadError(self.node_id, op=op) from last

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._dead = True
            if self._sock is not None:
                try:
                    self._sock.close()
                except OSError:
                    pass
                self._sock = None

    def _die(self, op: str) -> fv.NodeDeadError:
        """The socket is gone: every in-flight verb fails typed."""
        err = fv.NodeDeadError(self.node_id, op=op)
        self._dead = True
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None
        with self._lock:        # re-entrant: callers already hold it
            for pend in self._pending.values():
                if pend.error is None and pend.result is None:
                    pend.error = err
            self._pending.clear()
        return err

    def _send_frame(self, ftype: int, req_id: int, obj, *,
                    op: str) -> None:
        if self._dead or self._sock is None:
            self._ensure_conn(op)
        try:
            self._sock.sendall(wire.encode_frame(ftype, req_id, obj))
        except (OSError, ValueError) as e:
            raise self._die(op) from e

    def _recv_exact(self, n: int, *, op: str) -> bytes:
        chunks = []
        while n:
            try:
                chunk = self._sock.recv(n)
            except (OSError, ValueError) as e:      # reset / timeout / closed
                raise self._die(op) from e
            if not chunk:                           # orderly EOF mid-frame
                raise self._die(op)
            chunks.append(chunk)
            n -= len(chunk)
        return b"".join(chunks)

    def _recv_frame(self, *, op: str):
        hdr = self._recv_exact(wire.HEADER_SIZE, op=op)
        try:
            ftype, req_id, length = wire.parse_header(
                hdr, max_payload=self.max_payload)
            body = self._recv_exact(length, op=op) if length else b""
            trailer = self._recv_exact(wire.TRAILER_SIZE, op=op)
            # integrity before trust: a corrupted frame fails typed here
            # and POISONS the stream (no resync point exists) — the node
            # reads as dead and failover reroutes, never wrong bytes
            wire.check_crc(hdr, body, trailer)
        except wire.ProtocolError as e:
            raise self._die(op) from e
        return ftype, req_id, (wire.decode_value(body) if length else None)

    def _absorb(self, ftype: int, req_id: int, payload) -> None:
        """Route a response frame to its in-flight verb."""
        with self._lock:        # re-entrant: callers already hold it
            pend = self._pending.pop(req_id, None)
        if pend is None:
            return                      # verb already failed locally
        if ftype == wire.RESULT:
            pend._attach(payload)
        elif ftype == wire.ERROR:
            pend.error = wire.decode_error(payload)
        elif ftype == wire.OVERLOADED:
            pend.error = wire.decode_error(
                {"code": wire.E_OVERLOADED, **(payload or {})})
        else:
            pend.error = wire.ProtocolError(
                f"unexpected {wire.FRAME_NAMES.get(ftype, ftype)!r} "
                f"reply for request {req_id}")

    def _call(self, ftype: int, obj, *, op: str, expect: int = wire.OK):
        """Synchronous request/response; absorbs any interleaved
        SUBMIT responses that arrive first."""
        with self._lock:
            req_id = next(self._req_ids)
            self._send_frame(ftype, req_id, obj, op=op)
            while True:
                rtype, rid, payload = self._recv_frame(op=op)
                if rid == req_id:
                    if rtype == expect:
                        return payload
                    if rtype == wire.ERROR:
                        raise wire.decode_error(payload)
                    if rtype == wire.OVERLOADED:
                        raise wire.decode_error(
                            {"code": wire.E_OVERLOADED, **(payload or {})})
                    raise wire.ProtocolError(
                        f"unexpected {wire.FRAME_NAMES.get(rtype, rtype)!r}"
                        f" reply to {wire.FRAME_NAMES.get(ftype, ftype)}")
                if rid == 0 and rtype == wire.ERROR:
                    # connection-poisoning error (bad frame we sent)
                    raise wire.decode_error(payload)
                self._absorb(rtype, rid, payload)

    # ------------------------------------------------- FViewNode duck type
    def check_fault(self, op: str = "dispatch") -> None:
        """Faults live server-side; a dead server surfaces as socket
        death (`NodeDeadError`) on the next verb instead."""

    @property
    def has_queued(self) -> bool:
        with self._lock:
            return bool(self._pending)

    @property
    def dispatches(self) -> int:
        try:
            return int(self._call(wire.STATS, {}, op="stats")["dispatches"])
        except fv.NodeDeadError:
            return 0

    def open_connection(self) -> RemoteQPair:
        resp = self._call(wire.OPEN_QP, {}, op="open_connection")
        vqp = int(resp["qp"])
        qp = RemoteQPair(self, vqp, region=vqp % max(
            1, int(resp.get("region_count", 1))))
        self._qpairs[vqp] = qp
        return qp

    def close_connection(self, qp: RemoteQPair) -> None:
        self._qpairs.pop(qp.vqp, None)
        with self._lock:
            for rid, pend in list(self._pending.items()):
                if pend.qp is qp:
                    pend.error = fv.FarviewError(
                        f"connection qp{qp.vqp} closed with request "
                        "pending")
                    self._pending.pop(rid, None)
        try:
            self._call(wire.CLOSE_QP, {"qp": qp.vqp}, op="close")
        except fv.NodeDeadError:
            pass                        # the server died first; same outcome

    def submit(self, qp: RemoteQPair, ft, pipeline: tuple, *,
               lengths=None, strings=None, row_ids=None,
               deadline_s: float | None = None) -> RemotePending:
        with self._lock:
            if self._dead or self._sock is None:
                # reconnect BEFORE building the payload: a successful
                # reconnect re-numbers every vqp (`_reopen_qpairs`), and
                # the frame must carry the fresh id
                try:
                    self._ensure_conn("submit")
                except fv.NodeDeadError as e:
                    pend = RemotePending(self, qp, next(self._req_ids), ft)
                    pend.error = e      # resolved by failover in wait()
                    return pend
        if qp.vqp not in self._qpairs:
            raise fv.FarviewError(f"connection qp{qp.vqp} is closed")
        pipeline = op_ir.validate_pipeline(tuple(pipeline))
        payload = {
            "qp": qp.vqp, "table_id": ft.table_id, "pipeline": pipeline,
            "lengths": None if lengths is None
            else np.asarray(lengths, np.int32),
            "strings": None if strings is None
            else np.asarray(strings, np.uint8),
            "row_ids": None if row_ids is None
            else np.asarray(row_ids, np.int32),
            # relative budget (ms): survives unsynchronized clocks; the
            # server re-anchors it on its own monotonic clock on arrival
            "deadline_ms": None if deadline_s is None
            else float(deadline_s) * 1e3}
        with self._lock:
            req_id = next(self._req_ids)
            pend = RemotePending(self, qp, req_id, ft)
            try:
                self._send_frame(wire.SUBMIT, req_id, payload, op="submit")
            except fv.NodeDeadError as e:
                # dispatch-time fault, resolved by failover in wait()
                pend.error = e
                return pend
            self._pending[req_id] = pend
        return pend

    def flush(self) -> None:
        """The FLUSH barrier: every in-flight verb resolves (RESULT or
        typed error) before this returns; the first error re-raises,
        matching `FViewNode.flush` so cluster drains and heartbeats are
        oblivious to the socket."""
        with self._lock:
            if not self._pending:
                return
            if self._dead or self._sock is None:
                try:
                    self._ensure_conn("flush")
                except fv.NodeDeadError:
                    raise self._die("flush") from None
            inflight = list(self._pending.values())
            req_id = next(self._req_ids)
            self._send_frame(wire.FLUSH, req_id, {}, op="flush")
            while True:
                rtype, rid, payload = self._recv_frame(op="flush")
                if rid == req_id:
                    if rtype == wire.OK:
                        break
                    if rtype == wire.ERROR:
                        raise wire.decode_error(payload)
                    raise wire.ProtocolError(f"bad FLUSH ack {rtype}")
                self._absorb(rtype, rid, payload)
            for pend in inflight:
                if pend.result is None and pend.error is None:
                    pend.error = fv.FarviewError(
                        "request was not resolved by the server's flush")
                self._pending.pop(pend.req_id, None)
        first = next((p.error for p in inflight if p.error is not None),
                     None)
        if first is not None:
            raise first

    def settle(self) -> None:
        """Results arrive finalized; settling is just the barrier."""
        try:
            self.flush()
        except Exception:               # noqa: BLE001
            pass        # errors stay on their RemotePendings (like a node)


def remote_cluster(endpoints, **cluster_kw):
    """`FarCluster` over running servers: `endpoints` is a list of
    (host, port); handle i becomes cluster node i. Everything above the
    node interface — partition maps, replicas, failover, rebalancing —
    is untouched."""
    from repro.core.cluster import FarCluster
    handles = [RemoteNodeHandle(host, port, node_id=i)
               for i, (host, port) in enumerate(endpoints)]
    return FarCluster(nodes=handles, **cluster_kw)
