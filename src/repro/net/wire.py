"""Binary wire format for the Farview network tier.

Every message is one length-prefixed frame (wire version 2):

      0      2      3      4             12           16        16+len
      +------+------+------+-------------+------------+=========+-----+
      | magic| ver  | type | request id  | payload len| payload | crc |
      | u16  | u8   | u8   | u64         | u32        | (tagged)| u32 |
      +------+------+------+-------------+------------+=========+-----+

`magic` (0x4656, "FV") and `ver` gate decoding up front: a garbage or
incompatible header raises the typed `ProtocolError` immediately instead
of a server mis-parsing bytes into a hang. `request id` correlates
responses to requests — a client may have thousands of verbs in flight
on one connection and responses return in completion order. `payload
len` is bounded by `MAX_PAYLOAD`, so an adversarial (or corrupt) length
field fails typed instead of OOM-ing the peer. `crc` (version 2, PR 9)
is a CRC32 over header + payload: a frame corrupted IN TRANSIT — the
chaos layer's bit flips, a flaky NIC — fails typed at the receiver
instead of silently delivering wrong bytes or misrouting a response
whose request id was the corrupted field. The magic/version checks
catch garbage; the checksum catches *plausible* garbage.

Deadlines ride SUBMIT payloads as a tagged `deadline_ms` field — the
REMAINING budget in milliseconds, not an absolute timestamp, so it
survives unsynchronized clocks. The server re-anchors it on its own
monotonic clock at admission and sheds expired work before dispatch
with a typed `DEADLINE_EXCEEDED` error frame (`E_DEADLINE`).

The payload is a tagged recursive value encoding (stdlib `struct`, no
pickle — the decoder only constructs types named in an explicit
registry):

    N/T/F  none / true / false          s/b  utf-8 string / raw bytes
    i      int64                        I    big int (two's complement)
    f      float64                      a    ndarray (dtype, shape, raw)
    t/l    tuple / list (count + items) d    dict (count + k,v pairs)
    D      registered dataclass (class name + field tuple)

The `D` registry covers exactly the operator IR (`Project` ... `Pack`),
`Column` and `FTable` — a pipeline travels the wire as the same frozen
dataclasses the scheduler coalesces on, so the server-side dispatch key
(and therefore PR 2 cross-client stacking) is identical to in-process.

Typed errors are first-class frames: `encode_error` maps the exception
class to a stable code and carries `node_id` / `op`, `decode_error`
rebuilds the SAME exception type client-side. That is what lets PR 6
failover (`NodeDeadError` → reroute, `DroppedDispatchError` → same-node
retry) work across a process boundary.
"""
from __future__ import annotations

import dataclasses
import struct
import zlib

import numpy as np

from repro.core import operators as op_ir
from repro.core.client import (DeadlineExceededError, FarviewError,
                               NodeDeadError)
from repro.core.table import Column, FTable
from repro.distributed.health import (DroppedDispatchError, OverloadedError,
                                      ReplicaUnavailableError)

MAGIC = 0x4656              # "FV"
VERSION = 2                 # v2: CRC32 trailer over header + payload
HEADER = struct.Struct(">HBBQI")
HEADER_SIZE = HEADER.size   # 16 bytes
TRAILER = struct.Struct(">I")
TRAILER_SIZE = TRAILER.size  # 4-byte CRC32 after the payload
MAX_PAYLOAD = 256 * 2**20   # a frame past this is a protocol error, not an OOM

# ------------------------------------------------------------------ frame types
HELLO = 0x01        # client -> server: {"version": int}
HELLO_OK = 0x02     # server -> client: {"version", "node_id", "n_regions"}
OPEN_QP = 0x10      # -> {} ; reply OK {"qp": vqp_id}
CLOSE_QP = 0x11     # -> {"qp"} ; reply OK {}
ALLOC = 0x12        # -> {"ft": FTable} ; reply OK {"table_id", "pages"}
FREE = 0x13         # -> {"table_id"} ; reply OK {}
REGISTER = 0x14     # -> {"name", "table_id"} ; reply OK {}  (catalog alias)
UNREGISTER = 0x15   # -> {"name"} ; reply OK {}
WRITE = 0x16        # -> {"table_id", "data": ndarray} ; reply OK {}
READ = 0x17         # -> {"table_id"} ; reply OK {"data"}
READ_ROWS = 0x18    # -> {"table_id", "idx"} ; reply OK {"data"}
SUBMIT = 0x20       # -> {"qp","table_id","pipeline",...} ; RESULT/ERROR later
FLUSH = 0x21        # -> {} ; reply OK {} once prior submits resolved
STATS = 0x22        # -> {} ; reply OK {pool counters, dispatches, queue depth}
OK = 0x40           # generic success reply (payload per request type)
RESULT = 0x41       # resolved SUBMIT: finalized PipelineResult payload
ERROR = 0x42        # typed failure: see encode_error / decode_error
OVERLOADED = 0x43   # admission shed: {"node_id", "detail"} — back off

FRAME_NAMES = {
    HELLO: "HELLO", HELLO_OK: "HELLO_OK", OPEN_QP: "OPEN_QP",
    CLOSE_QP: "CLOSE_QP", ALLOC: "ALLOC", FREE: "FREE",
    REGISTER: "REGISTER", UNREGISTER: "UNREGISTER", WRITE: "WRITE",
    READ: "READ", READ_ROWS: "READ_ROWS", SUBMIT: "SUBMIT",
    FLUSH: "FLUSH", STATS: "STATS", OK: "OK", RESULT: "RESULT",
    ERROR: "ERROR", OVERLOADED: "OVERLOADED",
}


class ProtocolError(FarviewError):
    """The byte stream is not a valid Farview frame (bad magic, wrong
    version, oversized length, truncated or malformed payload). The
    connection that produced it is poisoned — the peer drops it rather
    than guessing at a resync point — but other connections are
    unaffected and nothing hangs."""


# ------------------------------------------------------------- value encoding
# The `D` tag decodes ONLY classes in this registry (never arbitrary
# names): the operator IR the scheduler keys on, plus the table schema
# handles. All are plain dataclasses of primitives/tuples.
DATACLASS_REGISTRY = {
    cls.__name__: cls
    for cls in (op_ir.Project, op_ir.SmartAddress, op_ir.Predicate,
                op_ir.Select, op_ir.RegexMatch, op_ir.JoinSmall,
                op_ir.Distinct, op_ir.GroupBy, op_ir.Crypt, op_ir.Pack,
                Column, FTable)
}

_I64 = struct.Struct(">q")
_F64 = struct.Struct(">d")
_U32 = struct.Struct(">I")


def _enc(obj, out: list) -> None:
    if obj is None:
        out.append(b"N")
    elif obj is True:
        out.append(b"T")
    elif obj is False:
        out.append(b"F")
    elif isinstance(obj, (np.integer, np.floating, np.bool_)):
        _enc(obj.item(), out)
    elif isinstance(obj, int):
        try:
            out.append(b"i" + _I64.pack(obj))
        except struct.error:        # past 64 bits: length-prefixed big int
            raw = obj.to_bytes((obj.bit_length() + 8) // 8, "big",
                               signed=True)
            out.append(b"I" + _U32.pack(len(raw)) + raw)
    elif isinstance(obj, float):
        out.append(b"f" + _F64.pack(obj))
    elif isinstance(obj, str):
        raw = obj.encode("utf-8")
        out.append(b"s" + _U32.pack(len(raw)) + raw)
    elif isinstance(obj, (bytes, bytearray, memoryview)):
        raw = bytes(obj)
        out.append(b"b" + _U32.pack(len(raw)) + raw)
    elif isinstance(obj, np.ndarray):
        # ascontiguousarray promotes 0-d to 1-d; only call it when needed
        arr = obj if obj.flags.c_contiguous else np.ascontiguousarray(obj)
        dt = arr.dtype.str.encode("ascii")
        out.append(b"a" + _U32.pack(len(dt)) + dt
                   + bytes([arr.ndim]))
        for dim in arr.shape:
            out.append(_U32.pack(dim))
        raw = arr.tobytes()
        out.append(_U32.pack(len(raw)) + raw)
    elif dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        name = type(obj).__name__
        if name not in DATACLASS_REGISTRY:
            raise TypeError(f"dataclass {name!r} is not wire-registered")
        fields = tuple(getattr(obj, f.name)
                       for f in dataclasses.fields(obj))
        raw = name.encode("ascii")
        out.append(b"D" + _U32.pack(len(raw)) + raw)
        _enc(fields, out)
    elif isinstance(obj, tuple):
        out.append(b"t" + _U32.pack(len(obj)))
        for item in obj:
            _enc(item, out)
    elif isinstance(obj, list):
        out.append(b"l" + _U32.pack(len(obj)))
        for item in obj:
            _enc(item, out)
    elif isinstance(obj, dict):
        out.append(b"d" + _U32.pack(len(obj)))
        for k, v in obj.items():
            _enc(k, out)
            _enc(v, out)
    else:
        # device arrays (jax) expose __array__; anything else is a bug
        arr = np.asarray(obj)
        if arr.dtype == object:
            raise TypeError(f"cannot wire-encode {type(obj).__name__}")
        _enc(arr, out)


def encode_value(obj) -> bytes:
    out: list = []
    _enc(obj, out)
    return b"".join(out)


class _Cursor:
    """Bounds-checked reader: every short read is a typed ProtocolError."""

    __slots__ = ("buf", "pos")

    def __init__(self, buf: bytes):
        self.buf = buf
        self.pos = 0

    def take(self, n: int) -> bytes:
        if n < 0 or self.pos + n > len(self.buf):
            raise ProtocolError(
                f"truncated payload: wanted {n} bytes at offset {self.pos}, "
                f"have {len(self.buf) - self.pos}")
        out = self.buf[self.pos:self.pos + n]
        self.pos += n
        return out

    def u32(self) -> int:
        return _U32.unpack(self.take(4))[0]


def _dec(cur: _Cursor):
    tag = cur.take(1)
    if tag == b"N":
        return None
    if tag == b"T":
        return True
    if tag == b"F":
        return False
    if tag == b"i":
        return _I64.unpack(cur.take(8))[0]
    if tag == b"I":
        return int.from_bytes(cur.take(cur.u32()), "big", signed=True)
    if tag == b"f":
        return _F64.unpack(cur.take(8))[0]
    if tag == b"s":
        try:
            return cur.take(cur.u32()).decode("utf-8")
        except UnicodeDecodeError as e:
            raise ProtocolError(f"malformed utf-8 string: {e}") from e
    if tag == b"b":
        return cur.take(cur.u32())
    if tag == b"a":
        try:
            dtype = np.dtype(cur.take(cur.u32()).decode("ascii"))
        except (TypeError, UnicodeDecodeError) as e:
            raise ProtocolError(f"bad ndarray dtype: {e}") from e
        ndim = cur.take(1)[0]
        shape = tuple(cur.u32() for _ in range(ndim))
        raw = cur.take(cur.u32())
        n_items = int(np.prod(shape, dtype=np.int64)) if shape else 1
        if dtype.itemsize * n_items != len(raw):
            raise ProtocolError(
                f"ndarray payload is {len(raw)} bytes, shape {shape} "
                f"of {dtype} needs {dtype.itemsize * n_items}")
        # copy out of the frame buffer so the array owns its memory
        return np.frombuffer(raw, dtype=dtype).reshape(shape).copy()
    if tag == b"D":
        name = cur.take(cur.u32()).decode("ascii", errors="replace")
        cls = DATACLASS_REGISTRY.get(name)
        if cls is None:
            raise ProtocolError(f"unknown wire dataclass {name!r}")
        fields = _dec(cur)
        if (not isinstance(fields, tuple)
                or len(fields) != len(dataclasses.fields(cls))):
            raise ProtocolError(f"bad field tuple for {name!r}")
        try:
            return cls(*fields)
        except (TypeError, ValueError) as e:
            raise ProtocolError(f"cannot rebuild {name!r}: {e}") from e
    if tag == b"t":
        return tuple(_dec(cur) for _ in range(cur.u32()))
    if tag == b"l":
        return [_dec(cur) for _ in range(cur.u32())]
    if tag == b"d":
        return {_dec(cur): _dec(cur) for _ in range(cur.u32())}
    raise ProtocolError(f"unknown value tag {tag!r}")


def decode_value(buf: bytes):
    cur = _Cursor(bytes(buf))
    try:
        obj = _dec(cur)
    except struct.error as e:       # short struct unpack inside a tag
        raise ProtocolError(f"malformed payload: {e}") from e
    if cur.pos != len(cur.buf):
        raise ProtocolError(
            f"{len(cur.buf) - cur.pos} trailing bytes after payload")
    return obj


# ------------------------------------------------------------------- framing
def encode_frame(ftype: int, req_id: int, obj=None) -> bytes:
    payload = b"" if obj is None else encode_value(obj)
    if len(payload) > MAX_PAYLOAD:
        raise ProtocolError(
            f"payload of {len(payload)} bytes exceeds MAX_PAYLOAD")
    hdr = HEADER.pack(MAGIC, VERSION, ftype, req_id, len(payload))
    # CRC over header AND payload: a corrupted request id (misrouted
    # response) is as wrong as a corrupted byte in an ndarray
    return hdr + payload + TRAILER.pack(zlib.crc32(payload, zlib.crc32(hdr)))


def parse_header(hdr: bytes, *, max_payload: int = MAX_PAYLOAD):
    """-> (ftype, req_id, payload_len); typed errors for garbage."""
    if len(hdr) != HEADER_SIZE:
        raise ProtocolError(
            f"truncated header: {len(hdr)} of {HEADER_SIZE} bytes")
    magic, ver, ftype, req_id, length = HEADER.unpack(hdr)
    if magic != MAGIC:
        raise ProtocolError(f"bad magic 0x{magic:04x} (want 0x{MAGIC:04x})")
    if ver != VERSION:
        raise ProtocolError(f"unsupported wire version {ver} (speak "
                            f"{VERSION})")
    if ftype not in FRAME_NAMES:
        raise ProtocolError(f"unknown frame type 0x{ftype:02x}")
    if length > max_payload:
        raise ProtocolError(
            f"frame of {length} bytes exceeds the {max_payload}-byte bound")
    return ftype, req_id, length


def check_crc(hdr: bytes, payload: bytes, trailer: bytes) -> None:
    """Verify a received frame's CRC32 trailer; typed error on mismatch.
    Stream readers call this with the three byte ranges they just read —
    the only defense against bytes that are plausible but WRONG (a bit
    flip inside an ndarray payload parses fine and merges wrong)."""
    if len(trailer) != TRAILER_SIZE:
        raise ProtocolError(
            f"truncated crc trailer: {len(trailer)} of {TRAILER_SIZE} bytes")
    want = TRAILER.unpack(trailer)[0]
    got = zlib.crc32(payload, zlib.crc32(hdr))
    if got != want:
        raise ProtocolError(
            f"frame checksum mismatch (crc32 {got:#010x} != {want:#010x}): "
            "corrupted in transit")


def decode_frame(buf: bytes, *, max_payload: int = MAX_PAYLOAD):
    """Parse one COMPLETE frame from `buf` -> (ftype, req_id, payload obj).

    Test/bench convenience; the server and client read header + payload +
    crc trailer separately off their streams via `parse_header` +
    `check_crc` + `decode_value`."""
    ftype, req_id, length = parse_header(buf[:HEADER_SIZE],
                                         max_payload=max_payload)
    body = buf[HEADER_SIZE:HEADER_SIZE + length]
    trailer = buf[HEADER_SIZE + length:]
    if len(body) != length or len(trailer) != TRAILER_SIZE:
        raise ProtocolError(
            f"frame body is {len(buf) - HEADER_SIZE} bytes, header "
            f"promised {length} (+{TRAILER_SIZE} crc)")
    check_crc(buf[:HEADER_SIZE], body, trailer)
    return ftype, req_id, decode_value(body) if length else None


# -------------------------------------------------------------- typed errors
E_GENERIC = 1       # FarviewError (or any unclassified server failure)
E_NODE_DEAD = 2
E_DROPPED = 3
E_REPLICA = 4
E_OVERLOADED = 5
E_PROTOCOL = 6
E_MEMORY = 7        # pool out of pages — the client's alloc raises MemoryError
E_DEADLINE = 8      # budget spent before dispatch: the typed
#                     DEADLINE_EXCEEDED shed (never a health strike)

_ERROR_CODES = (
    # order matters: first isinstance match wins, subclasses before bases
    (E_NODE_DEAD, NodeDeadError),
    (E_DROPPED, DroppedDispatchError),
    (E_REPLICA, ReplicaUnavailableError),
    (E_OVERLOADED, OverloadedError),
    (E_DEADLINE, DeadlineExceededError),
    (E_PROTOCOL, ProtocolError),
    (E_GENERIC, FarviewError),
    (E_MEMORY, MemoryError),
)


def encode_error(exc: BaseException, *, node_id: int | None = None) -> dict:
    code = E_GENERIC
    for c, cls in _ERROR_CODES:
        if isinstance(exc, cls):
            code = c
            break
    return {"code": code, "msg": str(exc),
            "node_id": getattr(exc, "node_id", node_id),
            "op": getattr(exc, "op", None),
            "detail": getattr(exc, "detail", None)}


def decode_error(payload: dict) -> Exception:
    code = payload.get("code", E_GENERIC)
    msg = payload.get("msg", "remote error")
    node_id = payload.get("node_id")
    if code == E_NODE_DEAD:
        return NodeDeadError(int(node_id or 0),
                             op=payload.get("op") or "dispatch")
    if code == E_DROPPED:
        return DroppedDispatchError(int(node_id or 0))
    if code == E_REPLICA:
        return ReplicaUnavailableError(msg)
    if code == E_OVERLOADED:
        return OverloadedError(int(node_id or 0),
                               detail=payload.get("detail") or msg)
    if code == E_DEADLINE:
        return DeadlineExceededError(
            None if node_id is None else int(node_id),
            op=payload.get("op") or "dispatch",
            detail=payload.get("detail") or "deadline budget exhausted")
    if code == E_PROTOCOL:
        return ProtocolError(msg)
    if code == E_MEMORY:
        return MemoryError(msg)
    return FarviewError(msg)
