"""Network tier (PR 8): the node as a real server process.

Farview is *network-attached* memory — the paper's claim is a smart NIC
serving many small compute nodes at line rate. Everything below
`core/` models that with in-process objects; this package puts a socket
in the middle without changing a single verb's semantics:

  * `wire`   — the compact binary frame format (length-prefixed,
               versioned header, request-id correlation) plus a tagged
               value codec for pipelines, page payloads, results and
               TYPED errors (`NodeDeadError` / `DroppedDispatchError` /
               `OverloadedError` reconstruct cross-process, so PR 6
               failover works over a real connection drop).
  * `server` — `FViewServer`, an asyncio front-end multiplexing
               thousands of client connections into ONE bucket-batched
               `FViewNode` scheduler, with admission control and
               per-tenant fair-share backpressure.
  * `client` — `RemoteNodeHandle`, a synchronous socket transport that
               duck-types `FViewNode`, so `FarCluster(nodes=[...])`
               runs scatter-gather, failover and rebalancing unchanged
               over sockets — byte-identical to in-process.
  * `chaos`  — `ChaosProxy`, a seeded socket-level fault injector
               (delays, mid-frame resets, bit flips, one-way
               partitions, duplicated frames) that the chaos soak
               (`tests/test_chaos.py`, `benchmarks/bench_chaos.py`)
               runs whole clusters through.

See docs/network.md for the frame diagram and time/failure model, and
docs/chaos.md for the fault vocabulary and soak methodology.
"""
from repro.net.chaos import ChaosProxy, FaultSchedule, proxied_endpoints
from repro.net.client import RemoteNodeHandle, remote_cluster
from repro.net.server import FViewServer, ServerLifecycleError
from repro.net.wire import ProtocolError

__all__ = ["FViewServer", "RemoteNodeHandle", "remote_cluster",
           "ProtocolError", "ServerLifecycleError",
           "ChaosProxy", "FaultSchedule", "proxied_endpoints"]
