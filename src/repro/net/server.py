"""`FViewServer`: one smart memory node behind a real TCP socket.

The asyncio front-end multiplexes thousands of client connections into
the ONE in-process `FViewNode` scheduler that PR 2 built:

  * every connection's `OPEN_QP` gets a *virtual* QPair, mapped
    round-robin onto a small fixed set of real QPairs (one per dynamic
    region, the paper's 6-ish) opened at server start — so connection
    count scales far past region count while the scheduler still sees
    its normal per-region fair-share arbitration;
  * `SUBMIT` frames are ADMITTED (or shed — below) into per-tenant
    queues; a background drain task collects a short batching window,
    interleaves tenants round-robin, and pushes the whole batch through
    `node.submit` + ONE `node.flush()` on a single worker thread. All
    same-(signature, layout, bucket) requests from different
    connections therefore land in the same scheduling round and
    coalesce into one stacked executable — PR 2's cross-client
    batching, preserved byte-for-byte across the socket;
  * results are finalized on the worker thread and shipped back as
    typed `RESULT` / `ERROR` frames correlated by request id, in
    completion order.

Backpressure is admission control, not TCP: a bounded global queue
depth plus a per-tenant fair share (`depth // active_tenants`). A
request past either bound is answered immediately with a typed
`OVERLOADED` frame (`OverloadedError` client-side) instead of queueing
toward a pool OOM or an unbounded p99 — the shed is explicit, cheap,
and never touches the scheduler. Accepted requests always complete.

Everything that can block — pool verbs, `node.flush()`, jit compiles,
`finalize()` — runs on a single `ThreadPoolExecutor` worker, keeping
the event loop free to accept, shed and answer (farlint FL006 enforces
this: no blocking calls inside `async def` under net/).

Run standalone:  python -m repro.net.server --port 0 --log server.log
(prints ``LISTENING <port>`` on stdout once bound — the CI server-smoke
lane and the subprocess test harness both key on that line).
"""
from __future__ import annotations

import argparse
import asyncio
import itertools
import sys
import threading
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass

import numpy as np

from repro.core import client as fv
from repro.net import wire


class ServerLifecycleError(fv.FarviewError):
    """A server start/stop step timed out or failed: the thread never
    came up, boot raised, or shutdown leaked the thread. Typed and LOUD —
    the old behavior (fall through a `ready.wait` / `thread.join`
    timeout and keep going) turned a wedged server into a mystery
    failure three tests later."""


def _result_payload(res) -> dict:
    """Flatten a FINALIZED PipelineResult into wire values. The client
    rebuilds an already-finalized result from these — `offload._merge`
    reads only kind/count/rows/sel_ids/mask/groups/shipped/read, so the
    rebuilt partial merges byte-identically to an in-process one."""
    out = {"kind": res.kind, "count": res._count,
           "shipped": int(res._shipped or 0),
           "read": int(res.read_bytes or 0)}
    if res.rows is not None:
        out["rows"] = np.asarray(res.rows)
    if res._ids is not None:
        out["sel_ids"] = np.asarray(res._ids)
    if res.mask is not None:
        out["mask"] = np.asarray(res.mask)
    if res._groups is not None:
        out["groups"] = {
            k: (np.asarray(v) if isinstance(v, (np.ndarray, list))
                or hasattr(v, "__array__") else v)
            for k, v in res._groups.items()}
    return out


@dataclass
class _Submit:
    """One admitted SUBMIT, from frame to RESULT/ERROR reply."""
    conn: "_Conn"
    req_id: int
    vqp: int
    real_qp: object
    ft: object
    pipeline: tuple
    lengths: object = None
    strings: object = None
    row_ids: object = None
    pend: object = None             # PendingRequest once submitted
    payload: dict | None = None     # RESULT payload once finalized
    error: Exception | None = None
    done: asyncio.Future = None     # resolved after the reply frame
    deadline: float | None = None   # time.monotonic() expiry from the
    #                                 frame's deadline_ms budget; checked
    #                                 again right before dispatch


class _Conn:
    """Per-connection state: virtual QPairs, admission queue, in-flight
    request ledger (for FLUSH barriers and disconnect cleanup)."""

    def __init__(self, conn_id: int, reader, writer):
        self.conn_id = conn_id
        self.reader = reader
        self.writer = writer
        self.wlock = asyncio.Lock()     # one frame at a time per socket
        self.vqps: dict[int, object] = {}   # virtual qp -> real QPair
        self.queue: deque[_Submit] = deque()    # admitted, not yet drained
        self.entries: dict[int, _Submit] = {}   # req_id -> in-flight
        self.closed = False


class FViewServer:
    """Asyncio server wrapping one `FViewNode` (see module docstring)."""

    def __init__(self, node: "fv.FViewNode | None" = None, *,
                 host: str = "127.0.0.1", port: int = 0,
                 capacity_bytes: int = 64 * 2**20, n_regions: int = 6,
                 interpret: bool | None = None, node_id: int = 0,
                 max_queue_depth: int = 1024, max_conns: int = 4096,
                 flush_interval_s: float = 0.002,
                 max_payload: int = wire.MAX_PAYLOAD,
                 io_timeout_s: float = 60.0,
                 idle_timeout_s: float = 3600.0,
                 log_path: str | None = None):
        self.node = node if node is not None else fv.FViewNode(
            capacity_bytes, n_regions=n_regions, interpret=interpret,
            node_id=node_id)
        self.host = host
        self.port = port                # real port known after start()
        self.max_queue_depth = int(max_queue_depth)
        self.max_conns = int(max_conns)
        self.flush_interval_s = float(flush_interval_s)
        self.max_payload = int(max_payload)
        # every await on the socket is BOUNDED (farlint FL007): a peer
        # that stalls mid-frame is reaped after io_timeout_s, an idle
        # connection (between requests) after idle_timeout_s
        self.io_timeout_s = float(io_timeout_s)
        self.idle_timeout_s = float(idle_timeout_s)
        self._log_file = open(log_path, "a") if log_path else None
        self._conn_ids = itertools.count()
        self._vqp_ids = itertools.count()
        self._conns: set[_Conn] = set()
        self._real_qps: list = []
        self._inflight_total = 0
        self._shed_total = 0
        self._deadline_shed_total = 0
        self._closing = False
        self._server: asyncio.AbstractServer | None = None
        self._loop: asyncio.AbstractEventLoop | None = None
        self._wake: asyncio.Event | None = None
        self._flush_urgent = False
        self._drain_task: asyncio.Task | None = None
        self._stopped: asyncio.Event | None = None
        # ONE worker: every node/pool/jit touch is serialized here, so
        # the FViewNode needs no locking and the loop never blocks
        self._exec = ThreadPoolExecutor(
            max_workers=1, thread_name_prefix=f"fview-node{self.node.node_id}")
        self._tables: dict[int, object] = {}    # table_id -> server FTable

    # -------------------------------------------------------------- logging
    def log(self, msg: str) -> None:
        line = f"[{time.strftime('%H:%M:%S')}] node{self.node.node_id} {msg}"
        out = self._log_file or sys.stderr
        print(line, file=out, flush=True)

    # ------------------------------------------------------------ lifecycle
    async def start(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._wake = asyncio.Event()
        self._stopped = asyncio.Event()
        for _ in range(len(self.node.regions)):
            self._real_qps.append(self.node.open_connection())
        self._server = await asyncio.start_server(
            self._serve_conn, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._drain_task = asyncio.ensure_future(self._drain_loop())
        self.log(f"listening on {self.host}:{self.port} "
                 f"(regions={len(self._real_qps)}, "
                 f"depth={self.max_queue_depth})")

    async def run_forever(self) -> None:
        await self.start()
        print(f"LISTENING {self.port}", flush=True)
        await self._stopped.wait()

    def shutdown(self, *, abort: bool = False) -> None:
        """Thread-safe stop. `abort=True` hard-drops every live socket
        (transport.abort — a RST, not a FIN), which is how the failover
        tests simulate a dying server across a REAL connection drop."""
        if self._loop is None:
            return
        try:
            self._loop.call_soon_threadsafe(self._do_shutdown, abort)
        except RuntimeError:
            pass                        # loop already closed

    def _do_shutdown(self, abort: bool) -> None:
        if self._closing:
            return
        self._closing = True
        self.log(f"shutdown (abort={abort})")
        if self._server is not None:
            self._server.close()
        for conn in list(self._conns):
            conn.closed = True
            if abort:
                conn.writer.transport.abort()
            else:
                conn.writer.close()
        if self._drain_task is not None:
            self._drain_task.cancel()
        self._exec.shutdown(wait=False)
        self._stopped.set()

    # Thread-hosted mode: tests and benches run servers inside the test
    # process; CI's server-smoke lane runs them as real subprocesses.
    @classmethod
    def start_in_thread(cls, *, start_timeout_s: float = 60.0,
                        **kwargs) -> "FViewServer":
        srv = cls(**kwargs)
        ready = threading.Event()
        boot_err: list[BaseException] = []

        def _run() -> None:
            loop = asyncio.new_event_loop()
            asyncio.set_event_loop(loop)

            async def _main() -> None:
                try:
                    await srv.start()
                except BaseException as e:  # noqa: BLE001 - reported below
                    boot_err.append(e)
                    ready.set()
                    return
                ready.set()
                await srv._stopped.wait()
                # reap the per-connection tasks the shutdown just woke
                # (the FL007 wait_for wrappers add a loop iteration to
                # their wakeup chain), so the loop closes with nothing
                # pending — asyncio.run does this for the __main__ path
                pending = [t for t in asyncio.all_tasks()
                           if t is not asyncio.current_task()]
                for t in pending:
                    t.cancel()
                await asyncio.gather(*pending, return_exceptions=True)

            try:
                loop.run_until_complete(_main())
            finally:
                loop.close()

        srv._thread = threading.Thread(target=_run, daemon=True)
        srv._thread.start()
        # both failure modes are TYPED (ServerLifecycleError), never a
        # silent fall-through into verbs against a server that isn't up
        if not ready.wait(timeout=start_timeout_s):
            raise ServerLifecycleError(
                f"FViewServer did not come up within {start_timeout_s:.0f}s "
                "(event loop thread never signalled ready)")
        if boot_err:
            raise ServerLifecycleError(
                f"FViewServer failed to start: {boot_err[0]}") from boot_err[0]
        return srv

    def stop_thread(self, *, abort: bool = False,
                    join_timeout_s: float = 30.0) -> None:
        self.shutdown(abort=abort)
        thread = getattr(self, "_thread", None)
        leaked = False
        if thread is not None:
            thread.join(timeout=join_timeout_s)
            leaked = thread.is_alive()
            if leaked:
                self.log(f"stop_thread: server thread still alive "
                         f"{join_timeout_s:.0f}s after shutdown (leaked)")
        if self._log_file is not None:
            self._log_file.close()
            self._log_file = None
        if leaked:
            raise ServerLifecycleError(
                f"server thread (node {self.node.node_id}, port "
                f"{self.port}) did not exit within {join_timeout_s:.0f}s "
                "of shutdown — thread leaked")

    # ------------------------------------------------------------ admission
    def _active_tenants(self) -> int:
        return sum(1 for c in self._conns if c.queue or c.entries)

    def _admit(self, conn: _Conn) -> str | None:
        """None to admit, else the shed reason (typed OVERLOADED)."""
        if self._inflight_total >= self.max_queue_depth:
            return (f"queue depth {self._inflight_total} at the "
                    f"{self.max_queue_depth} bound")
        share = max(1, self.max_queue_depth
                    // max(1, self._active_tenants()))
        mine = len(conn.queue) + len(conn.entries)
        if mine >= share:
            return (f"tenant at fair share ({mine} in flight, "
                    f"share {share})")
        return None

    # ------------------------------------------------------------- the drain
    async def _drain_loop(self) -> None:
        while not self._closing:
            await self._wake.wait()
            self._wake.clear()
            if self.flush_interval_s and not self._flush_urgent:
                # batching window: let concurrent submits pile into ONE
                # scheduler round (cross-client coalescing)
                await asyncio.sleep(self.flush_interval_s)
            self._flush_urgent = False
            batch = self._take_batch()
            if not batch:
                continue
            try:
                await self._loop.run_in_executor(
                    self._exec, self._run_batch, batch)
            except Exception as e:      # noqa: BLE001 - worker died
                for ent in batch:
                    ent.error = ent.error or e
            for ent in batch:
                await self._finish_entry(ent)

    def _take_batch(self) -> list:
        """Round-robin interleave of every tenant's admitted queue, so
        one chatty connection cannot monopolize a scheduler round."""
        batch: list[_Submit] = []
        ready = [c for c in self._conns if c.queue]
        while ready:
            still = []
            for conn in ready:
                batch.append(conn.queue.popleft())
                if conn.queue:
                    still.append(conn)
            ready = still
        return batch

    def _run_batch(self, batch: list) -> None:
        """Worker-thread half: submit everything, ONE flush, finalize."""
        for ent in batch:
            if ent.error is not None:
                continue
            if (ent.deadline is not None
                    and time.monotonic() >= ent.deadline):
                # budget spent while queued behind the batching window:
                # shed BEFORE dispatch — an expired request never
                # half-runs (and never costs a scheduler round)
                self._deadline_shed_total += 1
                ent.error = fv.DeadlineExceededError(
                    self.node.node_id, op="dispatch",
                    detail="budget spent in the server queue")
                continue
            try:
                ent.pend = self.node.submit(
                    ent.real_qp, ent.ft, ent.pipeline, lengths=ent.lengths,
                    strings=ent.strings, row_ids=ent.row_ids,
                    deadline_s=None if ent.deadline is None
                    else ent.deadline - time.monotonic())
            except Exception as e:      # noqa: BLE001 - typed reply below
                ent.error = e
        try:
            self.node.flush()
        except Exception:               # noqa: BLE001
            pass        # per-request errors live on their PendingRequests
        for ent in batch:
            if ent.error is not None or ent.pend is None:
                continue
            if ent.pend.error is not None:
                ent.error = ent.pend.error
            elif ent.pend.result is None:
                ent.error = fv.FarviewError("request was not dispatched")
            else:
                try:
                    ent.payload = _result_payload(ent.pend.result.finalize())
                except Exception as e:  # noqa: BLE001
                    ent.error = e

    async def _finish_entry(self, ent: _Submit) -> None:
        conn = ent.conn
        conn.entries.pop(ent.req_id, None)
        self._inflight_total -= 1
        if not conn.closed:
            try:
                if ent.error is not None:
                    await self._send(conn, wire.ERROR, ent.req_id,
                                     wire.encode_error(
                                         ent.error,
                                         node_id=self.node.node_id))
                else:
                    await self._send(conn, wire.RESULT, ent.req_id,
                                     ent.payload)
            except (ConnectionError, RuntimeError):
                conn.closed = True
        if ent.done is not None and not ent.done.done():
            ent.done.set_result(None)

    # ----------------------------------------------------------- connection
    async def _send(self, conn: _Conn, ftype: int, req_id: int,
                    obj=None) -> None:
        data = wire.encode_frame(ftype, req_id, obj)
        async with conn.wlock:
            conn.writer.write(data)
            try:
                # bounded (FL007): a peer that stops reading must not pin
                # this coroutine (and the conn's write lock) forever
                await asyncio.wait_for(conn.writer.drain(),
                                       self.io_timeout_s)
            except asyncio.TimeoutError:
                # to every caller a stalled peer IS a dead transport
                raise ConnectionError(
                    f"conn{conn.conn_id}: send stalled past "
                    f"{self.io_timeout_s:.0f}s io timeout") from None

    async def _serve_conn(self, reader, writer) -> None:
        conn = _Conn(next(self._conn_ids), reader, writer)
        if self._closing or len(self._conns) >= self.max_conns:
            try:
                await self._send(conn, wire.OVERLOADED, 0,
                                 {"node_id": self.node.node_id,
                                  "detail": f"at {self.max_conns} "
                                            "connections"})
            except (ConnectionError, RuntimeError):
                pass
            writer.close()
            return
        self._conns.add(conn)
        try:
            while not self._closing:
                try:
                    # idle bound between requests, io bound mid-frame:
                    # every read is inside wait_for (farlint FL007)
                    hdr = await asyncio.wait_for(
                        reader.readexactly(wire.HEADER_SIZE),
                        self.idle_timeout_s)
                    ftype, req_id, length = wire.parse_header(
                        hdr, max_payload=self.max_payload)
                    body = (await asyncio.wait_for(
                        reader.readexactly(length), self.io_timeout_s)
                        if length else b"")
                    trailer = await asyncio.wait_for(
                        reader.readexactly(wire.TRAILER_SIZE),
                        self.io_timeout_s)
                    wire.check_crc(hdr, body, trailer)
                    payload = wire.decode_value(body) if length else None
                except (asyncio.IncompleteReadError, ConnectionError):
                    break               # peer went away mid-frame / EOF
                except asyncio.TimeoutError:
                    self.log(f"conn{conn.conn_id} reaped: socket idle/"
                             "stalled past its timeout")
                    break
                except wire.ProtocolError as e:
                    # poisoned stream: answer typed, then drop THIS conn
                    self.log(f"conn{conn.conn_id} protocol error: {e}")
                    try:
                        await self._send(conn, wire.ERROR, 0,
                                         wire.encode_error(
                                             e, node_id=self.node.node_id))
                    except (ConnectionError, RuntimeError):
                        pass
                    break
                try:
                    await self._handle(conn, ftype, req_id, payload)
                # FarviewError IS a RuntimeError: match it first so typed
                # app errors reply instead of tripping the transport guard
                except fv.FarviewError as e:
                    try:
                        await self._send(conn, wire.ERROR, req_id,
                                         wire.encode_error(
                                             e, node_id=self.node.node_id))
                    except (ConnectionError, RuntimeError):
                        break
                except (ConnectionError, RuntimeError):
                    break               # transport died under the handler
                except Exception as e:  # noqa: BLE001 - reply, don't die
                    try:
                        await self._send(conn, wire.ERROR, req_id,
                                         wire.encode_error(
                                             e, node_id=self.node.node_id))
                    except (ConnectionError, RuntimeError):
                        break
        finally:
            self._drop_conn(conn)

    def _drop_conn(self, conn: _Conn) -> None:
        conn.closed = True
        self._conns.discard(conn)
        # admitted-but-undrained entries: nobody is listening anymore
        while conn.queue:
            ent = conn.queue.popleft()
            conn.entries.pop(ent.req_id, None)
            self._inflight_total -= 1
            if ent.done is not None and not ent.done.done():
                ent.done.set_result(None)
        try:
            conn.writer.close()
        except RuntimeError:
            pass

    # -------------------------------------------------------------- handlers
    async def _handle(self, conn: _Conn, ftype: int, req_id: int,
                      payload) -> None:
        if ftype == wire.HELLO:
            want = (payload or {}).get("version")
            if want != wire.VERSION:
                raise wire.ProtocolError(
                    f"client speaks wire version {want}, server "
                    f"{wire.VERSION}")
            await self._send(conn, wire.HELLO_OK, req_id,
                             {"version": wire.VERSION,
                              "node_id": self.node.node_id,
                              "n_regions": len(self._real_qps)})
        elif ftype == wire.OPEN_QP:
            vqp = next(self._vqp_ids)
            conn.vqps[vqp] = self._real_qps[vqp % len(self._real_qps)]
            await self._send(conn, wire.OK, req_id, {"qp": vqp})
        elif ftype == wire.CLOSE_QP:
            vqp = payload["qp"]
            conn.vqps.pop(vqp, None)
            still = deque()
            for ent in conn.queue:      # cancel the vqp's queued verbs
                if ent.vqp == vqp:
                    ent.error = fv.FarviewError(
                        f"connection qp{vqp} closed with request pending")
                    await self._finish_entry(ent)
                else:
                    still.append(ent)
            conn.queue = still
            await self._send(conn, wire.OK, req_id, {})
        elif ftype == wire.SUBMIT:
            await self._handle_submit(conn, req_id, payload)
        elif ftype == wire.FLUSH:
            # barrier over THIS connection's in-flight verbs: later
            # submits ride later drains and do not extend the wait
            waiters = [ent.done for ent in conn.entries.values()]
            self._flush_urgent = True
            self._wake.set()
            if waiters:
                await asyncio.wait(waiters)
            await self._send(conn, wire.OK, req_id, {})
        elif ftype == wire.STATS:
            stats = await self._loop.run_in_executor(
                self._exec, self._stats_payload)
            await self._send(conn, wire.OK, req_id, stats)
        elif ftype in (wire.ALLOC, wire.FREE, wire.REGISTER,
                       wire.UNREGISTER, wire.WRITE, wire.READ,
                       wire.READ_ROWS):
            reply = await self._loop.run_in_executor(
                self._exec, self._pool_verb, ftype, payload)
            await self._send(conn, wire.OK, req_id, reply)
        else:
            raise wire.ProtocolError(
                f"frame {wire.FRAME_NAMES.get(ftype, ftype)!r} is not a "
                "client request")

    async def _handle_submit(self, conn: _Conn, req_id: int,
                             payload) -> None:
        reason = self._admit(conn)
        if reason is not None:
            self._shed_total += 1
            await self._send(conn, wire.OVERLOADED, req_id,
                             {"node_id": self.node.node_id,
                              "detail": reason})
            return
        # deadline budget (PR 9): the frame carries the REMAINING budget
        # in ms; re-anchor it on this host's monotonic clock. A request
        # that arrives already expired is shed right here — typed
        # DEADLINE_EXCEEDED, zero scheduler work
        deadline_ms = payload.get("deadline_ms")
        deadline = None
        if deadline_ms is not None:
            if float(deadline_ms) <= 0:
                self._deadline_shed_total += 1
                await self._send(
                    conn, wire.ERROR, req_id,
                    wire.encode_error(fv.DeadlineExceededError(
                        self.node.node_id, op="admission",
                        detail="budget already spent on arrival"),
                        node_id=self.node.node_id))
                return
            deadline = time.monotonic() + float(deadline_ms) / 1e3
        vqp = payload["qp"]
        real_qp = conn.vqps.get(vqp)
        if real_qp is None:
            raise fv.FarviewError(f"connection qp{vqp} is closed")
        ft = self._tables.get(payload["table_id"])
        if ft is None:
            raise fv.FarviewError(
                f"unknown table_id {payload['table_id']} (not allocated "
                "on this node)")
        row_ids = payload.get("row_ids")
        ent = _Submit(
            conn=conn, req_id=req_id, vqp=vqp, real_qp=real_qp, ft=ft,
            pipeline=tuple(payload["pipeline"]),
            lengths=payload.get("lengths"),
            strings=payload.get("strings"),
            row_ids=None if row_ids is None
            else np.asarray(row_ids, np.int32),
            done=self._loop.create_future(),
            deadline=deadline)
        conn.entries[req_id] = ent
        conn.queue.append(ent)
        self._inflight_total += 1
        self._wake.set()

    # ------------------------------------------- pool verbs (worker thread)
    def _stats_payload(self) -> dict:
        stats = self.node.pool.stats
        return {"bytes_read": stats.bytes_read,
                "bytes_written": stats.bytes_written,
                "bytes_shipped": stats.bytes_shipped,
                "requests": stats.requests,
                "dispatches": self.node.dispatches,
                "inflight": self._inflight_total,
                "shed": self._shed_total,
                "deadline_shed": self._deadline_shed_total,
                "conns": len(self._conns)}

    def _pool_verb(self, ftype: int, payload):
        """ALLOC / FREE / catalog / raw reads+writes, serialized with the
        drains on the single worker thread (the node is lock-free)."""
        node = self.node
        if ftype == wire.ALLOC:
            ft = payload["ft"]
            node.pool.alloc_table(ft)
            self._tables[ft.table_id] = ft
            return {"table_id": ft.table_id, "pages": list(ft.pages)}
        if ftype == wire.FREE:
            ft = self._tables.pop(payload["table_id"], None)
            if ft is not None:
                node.pool.free_table(ft)
            return {}
        if ftype == wire.REGISTER:
            ft = self._tables.get(payload["table_id"])
            if ft is None:
                raise fv.FarviewError(
                    f"REGISTER {payload['name']!r}: unknown table_id "
                    f"{payload['table_id']}")
            node.tables[payload["name"]] = ft
            return {}
        if ftype == wire.UNREGISTER:
            node.tables.pop(payload["name"], None)
            return {}
        ft = self._tables.get(payload["table_id"])
        if ft is None:
            raise fv.FarviewError(
                f"unknown table_id {payload['table_id']}")
        if ftype == wire.WRITE:
            node.check_fault("table_write")
            node.pool.write_table(ft, payload["data"])
            stats = node.pool.stats
            stats.bytes_written += int(
                np.asarray(payload["data"]).size) * 4
            return {}
        if ftype == wire.READ:
            node.check_fault("table_read")
            return {"data": np.asarray(node.pool.read_table(ft))}
        if ftype == wire.READ_ROWS:
            node.check_fault("table_read")
            idx = np.asarray(payload["idx"])
            return {"data": np.asarray(node.pool.read_rows(ft, idx))}
        raise wire.ProtocolError(f"unhandled pool verb {ftype}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(
        description="serve one FViewNode over TCP (docs/network.md)")
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, default=0,
                    help="0 picks a free port (printed as LISTENING <p>)")
    ap.add_argument("--capacity-mb", type=int, default=64)
    ap.add_argument("--regions", type=int, default=6)
    ap.add_argument("--node-id", type=int, default=0)
    ap.add_argument("--queue-depth", type=int, default=1024)
    ap.add_argument("--flush-interval-ms", type=float, default=2.0)
    ap.add_argument("--log", default=None, help="append server log here")
    args = ap.parse_args(argv)
    server = FViewServer(
        host=args.host, port=args.port,
        capacity_bytes=args.capacity_mb * 2**20, n_regions=args.regions,
        node_id=args.node_id, max_queue_depth=args.queue_depth,
        flush_interval_s=args.flush_interval_ms / 1e3, log_path=args.log)
    asyncio.run(server.run_forever())


if __name__ == "__main__":
    main()
