"""`ChaosProxy`: seeded socket-level fault injection for the network tier.

`distributed.health.FaultInjector` injects faults at the VERB layer — a
node raises `NodeDeadError` before it serves. That never exercises the
transport itself: a real deployment fails in the middle of the byte
stream — frames cut short, bits flipped in flight, one direction of a
route black-holed, a switch replaying a packet. This module injects
exactly those, by sitting a tiny asyncio TCP proxy between a
`RemoteNodeHandle` and its `FViewServer` and applying a composable,
SEEDED `FaultSchedule` to the forwarded bytes:

    delay_s / jitter_s       fixed + uniformly-jittered per-frame delay
                             (the degraded-but-alive node hedging reacts
                             to; jitter is drawn from the seeded rng)
    drop_after_bytes         forward N bytes, then black-hole the
                             direction: the peer stalls MID-FRAME and is
                             reaped by its io timeout (farlint FL007's
                             whole reason to exist)
    reset_after_bytes        forward N bytes, then hard-abort (RST) both
                             sides — the mid-frame connection reset
    corrupt_prob             per-frame probability of flipping one byte;
                             the CRC32 trailer (wire VERSION 2) catches
                             it, the stream is poisoned typed, and
                             failover reroutes — never wrong result bytes
    duplicate_prob           per-frame probability of forwarding a frame
                             TWICE (a replayed packet); request-id
                             correlation makes the dup a no-op on both
                             peers
    partition_c2s / _s2c     one-way partition: every byte in that
                             direction silently dropped

Every fault draws from `random.Random(seed)`, so a chaos soak replays
bit-identically from its `--seed` — a CI failure is a repro, not a
ghost. Every injected fault is appended to `fault_log` (and
`save_fault_log` writes it as JSON lines — the CI chaos lane uploads it
as the failure artifact).

The proxy is frame-AWARE (it splits the stream on the 16-byte wire
header to corrupt / duplicate / delay whole frames) but never decodes
payloads; byte-count faults (`drop_after_bytes` / `reset_after_bytes`)
deliberately cut inside frames. Bytes that do not parse as frames (a
garbage client) pass through opaquely.

The zero-wrong-bytes contract under all of this is what
`tests/test_chaos.py` asserts and `benchmarks/bench_chaos.py` measures.
"""
from __future__ import annotations

import asyncio
import json
import random
import struct
import threading
import time
from dataclasses import dataclass, replace as dc_replace

from repro.net import wire
from repro.net.server import ServerLifecycleError

_CHUNK = 1 << 16


@dataclass(frozen=True)
class FaultSchedule:
    """One composable fault plan (see module docstring). Immutable so a
    live `set_schedule` swap is atomic under the GIL — pumps read the
    current schedule once per frame."""
    delay_s: float = 0.0
    jitter_s: float = 0.0
    drop_after_bytes: int | None = None
    reset_after_bytes: int | None = None
    corrupt_prob: float = 0.0
    duplicate_prob: float = 0.0
    partition_c2s: bool = False
    partition_s2c: bool = False

    def but(self, **kw) -> "FaultSchedule":
        """A copy with some fields replaced (schedule composition)."""
        return dc_replace(self, **kw)


CLEAN = FaultSchedule()


class _Reset(Exception):
    """Internal: the schedule demanded a mid-frame connection reset."""


class ChaosProxy:
    """A seeded chaos TCP proxy in front of one upstream server.

    Listens on its own (host, port); every accepted client gets one
    upstream connection and two pump tasks (client->server and
    server->client), each applying the CURRENT `FaultSchedule` per
    forwarded frame. `set_schedule` swaps the plan live — a soak moves
    between clean / degraded / partitioned phases without reconnecting.
    """

    def __init__(self, upstream_host: str, upstream_port: int, *,
                 host: str = "127.0.0.1", port: int = 0,
                 seed: int | None = 0,
                 schedule: FaultSchedule | None = None):
        self.upstream_host = upstream_host
        self.upstream_port = int(upstream_port)
        self.host = host
        self.port = int(port)           # real port known after start()
        self.seed = seed
        self.schedule = schedule if schedule is not None else CLEAN
        self.fault_log: list[dict] = []     # appended on the loop thread
        self._rng = random.Random(seed)
        self._t0 = time.monotonic()
        self._server: asyncio.AbstractServer | None = None
        self._loop: asyncio.AbstractEventLoop | None = None
        self._stopped: asyncio.Event | None = None
        self._conn_ids = iter(range(1 << 30))
        self._transports: set = set()
        self._closing = False

    # ------------------------------------------------------------- schedule
    def set_schedule(self, schedule: FaultSchedule) -> None:
        """Swap the fault plan; the next forwarded frame sees it."""
        self.schedule = schedule

    def _log(self, conn_id: int, direction: str, kind: str,
             detail) -> None:
        self.fault_log.append({
            "t": round(time.monotonic() - self._t0, 6),
            "conn": conn_id, "dir": direction, "kind": kind,
            "detail": detail})

    def save_fault_log(self, path: str) -> None:
        """JSON-lines dump — the CI chaos lane's failure artifact."""
        with open(path, "w") as f:
            for ev in self.fault_log:
                f.write(json.dumps(ev) + "\n")

    # ------------------------------------------------------------ lifecycle
    async def start(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._stopped = asyncio.Event()
        self._t0 = time.monotonic()
        self._server = await asyncio.start_server(
            self._serve_conn, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]

    def shutdown(self) -> None:
        if self._loop is None:
            return
        try:
            self._loop.call_soon_threadsafe(self._do_shutdown)
        except RuntimeError:
            pass                        # loop already closed

    def _do_shutdown(self) -> None:
        if self._closing:
            return
        self._closing = True
        if self._server is not None:
            self._server.close()
        for tr in list(self._transports):
            tr.abort()
        self._stopped.set()

    def drop_all(self) -> None:
        """Hard-abort every live proxied connection (both sides) without
        stopping the proxy — the route flaps, the endpoints survive."""
        def _drop() -> None:
            for tr in list(self._transports):
                self._log(-1, "both", "drop_all", None)
                tr.abort()
            self._transports.clear()
        if self._loop is not None:
            self._loop.call_soon_threadsafe(_drop)

    @classmethod
    def start_in_thread(cls, upstream_host: str, upstream_port: int, *,
                        start_timeout_s: float = 30.0,
                        **kwargs) -> "ChaosProxy":
        """Run the proxy's event loop on a daemon thread (mirrors
        `FViewServer.start_in_thread`, same TYPED lifecycle errors)."""
        proxy = cls(upstream_host, upstream_port, **kwargs)
        ready = threading.Event()
        boot_err: list[BaseException] = []

        def _run() -> None:
            loop = asyncio.new_event_loop()
            asyncio.set_event_loop(loop)

            async def _main() -> None:
                try:
                    await proxy.start()
                except BaseException as e:  # noqa: BLE001 - reported below
                    boot_err.append(e)
                    ready.set()
                    return
                ready.set()
                await proxy._stopped.wait()
                # reap the per-connection tasks the abort just unblocked,
                # so the loop closes with nothing pending
                pending = [t for t in asyncio.all_tasks()
                           if t is not asyncio.current_task()]
                for t in pending:
                    t.cancel()
                await asyncio.gather(*pending, return_exceptions=True)

            try:
                loop.run_until_complete(_main())
            finally:
                loop.close()

        proxy._thread = threading.Thread(target=_run, daemon=True)
        proxy._thread.start()
        if not ready.wait(timeout=start_timeout_s):
            raise ServerLifecycleError(
                f"ChaosProxy did not come up within {start_timeout_s:.0f}s")
        if boot_err:
            raise ServerLifecycleError(
                f"ChaosProxy failed to start: {boot_err[0]}") from boot_err[0]
        return proxy

    def stop_thread(self, *, join_timeout_s: float = 30.0) -> None:
        self.shutdown()
        thread = getattr(self, "_thread", None)
        if thread is not None:
            thread.join(timeout=join_timeout_s)
            if thread.is_alive():
                raise ServerLifecycleError(
                    f"ChaosProxy thread (port {self.port}) did not exit "
                    f"within {join_timeout_s:.0f}s of shutdown")

    # ------------------------------------------------------------ the pumps
    async def _serve_conn(self, reader, writer) -> None:
        conn_id = next(self._conn_ids)
        try:
            up_reader, up_writer = await asyncio.wait_for(
                asyncio.open_connection(self.upstream_host,
                                        self.upstream_port), 30.0)
        except (OSError, asyncio.TimeoutError):
            writer.transport.abort()
            return
        self._transports.add(writer.transport)
        self._transports.add(up_writer.transport)
        state = {"c2s": 0, "s2c": 0}    # bytes forwarded per direction
        pumps = [
            asyncio.ensure_future(self._pump(
                conn_id, "c2s", reader, up_writer, writer, state)),
            asyncio.ensure_future(self._pump(
                conn_id, "s2c", up_reader, writer, up_writer, state)),
        ]
        try:
            await asyncio.wait(pumps, return_when=asyncio.FIRST_COMPLETED)
        finally:
            for p in pumps:
                p.cancel()
            for p in pumps:
                try:
                    await p
                except (asyncio.CancelledError, Exception):  # noqa: BLE001
                    pass
            self._transports.discard(writer.transport)
            self._transports.discard(up_writer.transport)
            for w in (writer, up_writer):
                try:
                    w.transport.abort()
                except RuntimeError:
                    pass

    def _split_frames(self, buf: bytes) -> tuple[list, bytes]:
        """Split complete wire frames off the front of `buf`. Bytes that
        do not look like a frame (bad magic, short header) are passed
        through as ONE opaque blob — the proxy must forward garbage as
        faithfully as it forwards frames."""
        out: list = []
        while len(buf) >= wire.HEADER_SIZE:
            try:
                magic, _, _, _, length = wire.HEADER.unpack(
                    buf[:wire.HEADER_SIZE])
            except struct.error:        # pragma: no cover - size-guarded
                break
            if magic != wire.MAGIC:
                out.append(buf)         # opaque: forward, don't frame
                return out, b""
            total = wire.HEADER_SIZE + length + wire.TRAILER_SIZE
            if len(buf) < total:
                break
            out.append(buf[:total])
            buf = buf[total:]
        return out, buf

    async def _pump(self, conn_id: int, direction: str, reader, writer,
                    peer_writer, state) -> None:
        buf = b""
        try:
            while True:
                # a pump waits as long as its endpoints do: the server's
                # idle reaper / the client's socket timeout bound the
                # conn's lifetime, and shutdown() aborts the transport
                chunk = await reader.read(_CHUNK)  # farlint: ok FL007 -- lifetime bounded by the proxied endpoints' own timeouts
                if not chunk:
                    break               # EOF: tear the pair down
                sch = self.schedule
                if ((direction == "c2s" and sch.partition_c2s)
                        or (direction == "s2c" and sch.partition_s2c)):
                    self._log(conn_id, direction, "partition", len(chunk))
                    continue            # one-way black hole
                buf += chunk
                frames, buf = self._split_frames(buf)
                for frame in frames:
                    await self._forward(conn_id, direction, writer,
                                        frame, state)
        except _Reset:
            writer.transport.abort()
            peer_writer.transport.abort()
        except (ConnectionError, OSError, asyncio.IncompleteReadError):
            pass
        # fall out: the _serve_conn finally tears both sides down

    async def _forward(self, conn_id: int, direction: str, writer,
                       frame: bytes, state) -> None:
        sch = self.schedule
        if sch.corrupt_prob and self._rng.random() < sch.corrupt_prob:
            i = self._rng.randrange(len(frame))
            flip = self._rng.randrange(1, 256)
            frame = frame[:i] + bytes([frame[i] ^ flip]) + frame[i + 1:]
            self._log(conn_id, direction, "corrupt",
                      {"offset": i, "xor": flip})
        delay = sch.delay_s
        if sch.jitter_s:
            delay += self._rng.uniform(0.0, sch.jitter_s)
        if delay > 0:
            self._log(conn_id, direction, "delay", round(delay, 6))
            await asyncio.sleep(delay)
        copies = 1
        if sch.duplicate_prob and self._rng.random() < sch.duplicate_prob:
            copies = 2
            self._log(conn_id, direction, "duplicate", len(frame))
        for _ in range(copies):
            await self._write(conn_id, direction, writer, frame, state)

    async def _write(self, conn_id: int, direction: str, writer,
                     data: bytes, state) -> None:
        sch = self.schedule
        sent = state[direction]
        if sch.reset_after_bytes is not None:
            left = sch.reset_after_bytes - sent
            if left <= len(data):
                # forward the first `left` bytes, then RST: the peer sees
                # a connection die MID-FRAME
                if left > 0:
                    writer.write(data[:left])
                    state[direction] = sent + left
                    await asyncio.wait_for(writer.drain(), 60.0)
                self._log(conn_id, direction, "reset",
                          {"after_bytes": state[direction]})
                raise _Reset
        if sch.drop_after_bytes is not None:
            left = sch.drop_after_bytes - sent
            if left <= 0:
                self._log(conn_id, direction, "blackhole", len(data))
                return                  # stream stalls; io timeouts reap it
            if left < len(data):
                self._log(conn_id, direction, "blackhole",
                          {"cut_at": left, "dropped": len(data) - left})
                data = data[:left]
        writer.write(data)
        state[direction] = sent + len(data)
        await asyncio.wait_for(writer.drain(), 60.0)


def proxied_endpoints(servers, *, seed: int = 0,
                      schedule: FaultSchedule | None = None) -> tuple:
    """Start one `ChaosProxy` per server; returns `(proxies, endpoints)`
    where endpoints are the (host, port) pairs clients should dial.
    Proxy i derives its rng from `seed + i` so a multi-node soak is
    deterministic but the nodes' fault points are decorrelated."""
    proxies = [ChaosProxy.start_in_thread(
        "127.0.0.1", s.port if hasattr(s, "port") else int(s),
        seed=None if seed is None else seed + i, schedule=schedule)
        for i, s in enumerate(servers)]
    return proxies, [("127.0.0.1", p.port) for p in proxies]
