"""FL006 async-blocking: the network tier's event loop never blocks.

`net/server.py` multiplexes thousands of connections on ONE asyncio
loop; a single blocking call inside an `async def` — a `time.sleep`, a
synchronous socket op, a `concurrent.futures` `.result()`, a jax
`.block_until_ready()` — stalls every connection at once, which is
exactly the fan-in latency collapse the bench_network p99 guard exists
to catch. The architectural rule (docs/network.md): anything that can
block runs on the server's worker executor; `async def` bodies only
await.

This pass enforces the rule for every module under `src/repro/net/`:

  flagged inside an `async def` body
      time.sleep(...)           (asyncio.sleep is the async form)
      socket.socket(...) / socket.create_connection(...)
      .recv() .recv_into() .recvfrom() .sendall() .accept() .connect()
      .result()                 (blocking future join)
      .block_until_ready()      (blocks on the device)

  not flagged
      the same calls in plain `def` functions (the sync client
      transport and the worker-thread batch runner live there);
      nested `def`/`lambda` bodies inside an `async def` (they are
      thunks handed to `run_in_executor`, not loop code);
      functions whose name contains `finalize` or carrying a
      `# farlint: finalize-boundary` marker (same escape hatch as
      FL002 — a deliberate sync point, reviewed by name).

Suppressions use the shared convention: `# farlint: ok FL006 -- why`.
"""
from __future__ import annotations

import ast

from repro.analyze.core import Finding, SourceFile

#: scope: the asyncio network tier only (suffix-on-directory match)
SCOPE_PARTS = ("repro", "net")

_BLOCKING_CALLS = {"time.sleep", "socket.socket",
                   "socket.create_connection"}
_BLOCKING_METHODS = {"recv", "recv_into", "recvfrom", "sendall", "accept",
                     "connect", "result", "block_until_ready"}


def in_scope(rel: str) -> bool:
    parts = rel.replace("\\", "/").split("/")
    return any(tuple(parts[i:i + 2]) == SCOPE_PARTS
               for i in range(len(parts) - 2))


def _time_sleep_aliases(tree: ast.Module) -> set[str]:
    """Bare names that mean `time.sleep` (`from time import sleep [as s]`)."""
    out = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module == "time":
            for a in node.names:
                if a.name == "sleep":
                    out.add(a.asname or a.name)
    return out


def _async_defs(tree: ast.Module) -> list[ast.AsyncFunctionDef]:
    return [n for n in ast.walk(tree)
            if isinstance(n, ast.AsyncFunctionDef)]


def _body_calls(fn: ast.AsyncFunctionDef) -> list[ast.Call]:
    """Call nodes lexically in `fn`'s own body — nested defs and lambdas
    are executor/thunk territory and excluded."""
    out: list[ast.Call] = []
    stack: list[ast.AST] = list(fn.body)
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        if isinstance(node, ast.Call):
            out.append(node)
        stack.extend(ast.iter_child_nodes(node))
    return out


def check(sf: SourceFile) -> list[Finding]:
    if not in_scope(sf.rel):
        return []
    sleep_aliases = _time_sleep_aliases(sf.tree)
    findings: list[Finding] = []
    for fn in _async_defs(sf.tree):
        if "finalize" in fn.name.lower() or sf.boundary_marker(fn.lineno):
            continue
        for call in _body_calls(fn):
            func = call.func
            try:
                text = ast.unparse(func)
            except Exception:       # pragma: no cover
                text = ""
            what = None
            if text in _BLOCKING_CALLS:
                what = f"`{text}(...)`"
            elif (isinstance(func, ast.Name)
                  and func.id in sleep_aliases):
                what = f"`{func.id}(...)` (time.sleep)"
            elif (isinstance(func, ast.Attribute)
                  and func.attr in _BLOCKING_METHODS):
                what = f"`.{func.attr}(...)`"
            if what is not None:
                findings.append(Finding(
                    "FL006", sf.rel, call.lineno,
                    f"blocking call {what} inside `async def {fn.name}` "
                    f"stalls the server event loop; await the async form "
                    f"or move it to the worker executor "
                    f"(run_in_executor)"))
    return findings
