"""farlint command line: `farlint [paths...] [--baseline FILE]`.

Exit codes: 0 clean (no new findings), 1 new findings (or malformed
suppressions), 2 bad invocation. `--update-baseline` rewrites the
baseline to grandfather everything currently found — a deliberate act
recorded in the diff, not something CI ever does.
"""
from __future__ import annotations

import argparse
import os
import sys

from repro.analyze.core import (
    RULES,
    analyze_paths,
    apply_baseline,
    load_baseline,
    save_baseline,
)

DEFAULT_PATHS = ("src", "benchmarks", "tests")


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="farlint",
        description="repo-specific static analysis: lock discipline, "
                    "host-sync on the fused dispatch path, jit retrace "
                    "hazards (docs/analysis.md)")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files or directories (default: src benchmarks "
                         "tests, those that exist)")
    ap.add_argument("--baseline", metavar="FILE", default=None,
                    help="JSON baseline of grandfathered findings; only "
                         "NEW findings fail the run")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite --baseline to cover all current findings")
    ap.add_argument("--root", default=None,
                    help="directory findings are reported relative to "
                         "(default: cwd)")
    ap.add_argument("--rules", action="store_true",
                    help="list rules and exit")
    args = ap.parse_args(argv)

    if args.rules:
        for rid, (alias, desc) in sorted(RULES.items()):
            print(f"{rid} ({alias}): {desc}")
        return 0

    root = os.path.abspath(args.root or os.getcwd())
    paths = args.paths or [p for p in DEFAULT_PATHS
                           if os.path.isdir(os.path.join(root, p))]
    if not paths:
        print("farlint: nothing to analyze", file=sys.stderr)
        return 2
    if args.update_baseline and not args.baseline:
        print("farlint: --update-baseline requires --baseline",
              file=sys.stderr)
        return 2

    findings = analyze_paths(paths, root)

    if args.update_baseline:
        save_baseline(args.baseline, findings)
        print(f"farlint: baseline updated with {len(findings)} finding(s) "
              f"-> {args.baseline}")
        return 0

    entries = load_baseline(args.baseline) if args.baseline else []
    res = apply_baseline(findings, entries)

    for f in res.new:
        print(f.render())
    for e in res.stale:
        print(f"stale baseline entry ({e.get('rule')} {e.get('path')}): "
              f"the finding it covered is gone — remove it or run "
              f"--update-baseline")
    n_new, n_old, n_stale = len(res.new), len(res.grandfathered), \
        len(res.stale)
    summary = f"farlint: {n_new} new finding(s)"
    if n_old:
        summary += f", {n_old} baselined"
    if n_stale:
        summary += f", {n_stale} stale baseline entr(y/ies)"
    print(summary)
    return 1 if res.new else 0


if __name__ == "__main__":    # pragma: no cover - exercised via subprocess
    sys.exit(main())
