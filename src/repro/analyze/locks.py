"""FL001 lock-discipline: guarded attributes are only touched under their lock.

Convention: the assignment that *introduces* a shared attribute carries a
`# guarded-by: <lock-expr>` comment (same line, or alone on the line
above). Three declaration sites are recognized:

  * `self.attr = ...` inside `__init__` / `__post_init__`  -> instance
    attribute of the enclosing class, lock usually `self._lock`;
  * a class-body field (dataclass `attr: T = ...`)          -> same;
  * a module-level `NAME = ...`                             -> module
    global, lock names another module global (e.g. `_CACHE_LOCK`).

The pass then walks every function in the SAME module and flags any load
or store of a guarded attribute that is not lexically inside a
`with <lock>:` block. `self.` in the lock expression is rebound to the
actual receiver (`pending.heat` under `with pending._lock:` is fine).
`__init__`/`__post_init__` of the declaring class are exempt — single
threaded by construction. The check is module-local and lexical by
design: aliasing the lock (`lk = self._lock; with lk:`) or reaching into
another module's guarded state is not tracked, and the convention in
this repo is simply not to do either (docs/analysis.md).
"""
from __future__ import annotations

import ast
from dataclasses import dataclass

from repro.analyze.core import Finding, SourceFile

_INIT_NAMES = ("__init__", "__post_init__")


@dataclass(frozen=True)
class GuardDecl:
    cls: str | None     # declaring class; None for module globals
    attr: str           # attribute or global name
    lock: str           # lock expression as written, e.g. "self._lock"
    line: int


def _decl_comment(sf: SourceFile, node: ast.stmt) -> str | None:
    return sf.guard_comment(node.lineno)


def collect_decls(sf: SourceFile) -> list[GuardDecl]:
    decls: list[GuardDecl] = []

    def name_targets(node):
        if isinstance(node, ast.Assign):
            return [t for t in node.targets]
        if isinstance(node, (ast.AnnAssign, ast.AugAssign)):
            return [node.target]
        return []

    for top in sf.tree.body:
        if isinstance(top, (ast.Assign, ast.AnnAssign)):
            lock = _decl_comment(sf, top)
            if lock:
                for t in name_targets(top):
                    if isinstance(t, ast.Name):
                        decls.append(GuardDecl(None, t.id, lock, top.lineno))
        elif isinstance(top, ast.ClassDef):
            for stmt in top.body:
                if isinstance(stmt, (ast.Assign, ast.AnnAssign)):
                    lock = _decl_comment(sf, stmt)
                    if lock:
                        for t in name_targets(stmt):
                            if isinstance(t, ast.Name):
                                decls.append(GuardDecl(
                                    top.name, t.id, lock, stmt.lineno))
                elif (isinstance(stmt, (ast.FunctionDef,
                                        ast.AsyncFunctionDef))
                      and stmt.name in _INIT_NAMES):
                    for sub in ast.walk(stmt):
                        if not isinstance(sub, (ast.Assign, ast.AnnAssign)):
                            continue
                        lock = _decl_comment(sf, sub)
                        if not lock:
                            continue
                        for t in name_targets(sub):
                            if (isinstance(t, ast.Attribute)
                                    and isinstance(t.value, ast.Name)
                                    and t.value.id == "self"):
                                decls.append(GuardDecl(
                                    top.name, t.attr, lock, sub.lineno))
    return decls


def _required_lock(decl: GuardDecl, receiver: str) -> str:
    """Rebind a `self.`-relative lock expression to the receiver used at
    the access site (`self._lock` + receiver `pending` -> `pending._lock`)."""
    if decl.lock.startswith("self.") and receiver != "self":
        return receiver + decl.lock[len("self"):]
    return decl.lock


class _Checker(ast.NodeVisitor):
    def __init__(self, sf: SourceFile, decls: list[GuardDecl]):
        self.sf = sf
        self.attr_decls: dict[str, list[GuardDecl]] = {}
        self.global_decls: dict[str, GuardDecl] = {}
        for d in decls:
            if d.cls is None:
                self.global_decls[d.attr] = d
            else:
                self.attr_decls.setdefault(d.attr, []).append(d)
        self.findings: list[Finding] = []
        self._class_stack: list[str] = []
        self._func_stack: list[str] = []
        self._with_stack: list[str] = []

    # -- scope tracking -----------------------------------------------------
    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._class_stack.append(node.name)
        self.generic_visit(node)
        self._class_stack.pop()

    def _visit_func(self, node) -> None:
        self._func_stack.append(node.name)
        self.generic_visit(node)
        self._func_stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def visit_With(self, node: ast.With) -> None:
        held = []
        for item in node.items:
            try:
                held.append(ast.unparse(item.context_expr))
            except Exception:   # pragma: no cover - unparse is total on py310
                pass
            if item.optional_vars is not None:
                self.generic_visit(item.optional_vars)
        for item in node.items:
            self.generic_visit(item.context_expr)
        self._with_stack.extend(held)
        for stmt in node.body:
            self.visit(stmt)
        del self._with_stack[len(self._with_stack) - len(held):]

    visit_AsyncWith = visit_With

    # -- access checks ------------------------------------------------------
    def _in_init_of(self, cls: str) -> bool:
        return (bool(self._class_stack)
                and self._class_stack[-1] == cls
                and bool(self._func_stack)
                and self._func_stack[-1] in _INIT_NAMES)

    def _flag(self, node: ast.AST, what: str, lock: str) -> None:
        self.findings.append(Finding(
            "FL001", self.sf.rel, node.lineno,
            f"`{what}` is guarded-by `{lock}` but accessed outside "
            f"`with {lock}:`"))

    def visit_Attribute(self, node: ast.Attribute) -> None:
        decls = self.attr_decls.get(node.attr)
        if decls and self._func_stack:
            try:
                receiver = ast.unparse(node.value)
            except Exception:   # pragma: no cover
                receiver = ""
            # `self.X` only matches a decl of the class we're lexically
            # inside; any other receiver matches an unambiguous decl
            if receiver == "self":
                decl = next(
                    (d for d in decls if self._class_stack
                     and d.cls == self._class_stack[-1]), None)
            else:
                decl = decls[0] if len(decls) == 1 else None
            if decl is not None and not (
                    receiver == "self" and self._in_init_of(decl.cls)):
                required = _required_lock(decl, receiver)
                if required not in self._with_stack:
                    self._flag(node, f"{receiver}.{node.attr}", required)
        self.generic_visit(node)

    def visit_Name(self, node: ast.Name) -> None:
        decl = self.global_decls.get(node.id)
        if decl is not None and self._func_stack:
            if decl.lock not in self._with_stack:
                self._flag(node, node.id, decl.lock)
        self.generic_visit(node)


def check(sf: SourceFile) -> list[Finding]:
    decls = collect_decls(sf)
    if not decls:
        return []
    checker = _Checker(sf, decls)
    checker.visit(sf.tree)
    return checker.findings
