"""farlint: repo-specific static analysis (lock discipline, host-sync,
retrace hazards). See docs/analysis.md. Stdlib-only — importable without
jax, which is how the CI lint job runs it."""
from repro.analyze.core import (
    Finding,
    RULES,
    SourceFile,
    analyze_paths,
    analyze_source,
    apply_baseline,
    load_baseline,
    rule_id,
    save_baseline,
)

__all__ = [
    "Finding", "RULES", "SourceFile", "analyze_paths", "analyze_source",
    "apply_baseline", "load_baseline", "rule_id", "save_baseline",
]
