"""FL007 await-bound: every network await carries a timeout.

PR 9's time-and-failure model (docs/network.md) makes a hard promise:
nothing in the network tier waits forever. A one-way partition — the
peer's packets simply stop — does not error; an unbounded
`await reader.readexactly(...)` just hangs, the connection is never
reaped, and the deadline/hedge machinery upstream never gets its turn.
`ChaosProxy`'s `partition_s2c` fault exists precisely to manufacture
this condition; this pass makes the fix structural.

The rule, for every module under `src/repro/net/`: an `await` whose
awaited expression IS one of the stall-prone stream calls

    reader.read() / .readexactly() / .readline() / .readuntil()
    writer.drain()
    asyncio.open_connection(...)

must be wrapped in `asyncio.wait_for(...)` (then the *wait_for* is the
awaited expression and the inner call is just its argument — which is
how `server.py` bounds every read with `io_timeout_s` /
`idle_timeout_s`).

Not flagged:

  * `await asyncio.wait_for(reader.readexactly(n), t)` — the bound is
    the point;
  * the same calls NOT directly under `await` (handed to `wait_for`,
    `gather`, or stored as a task — someone else owns the bound);
  * code outside `src/repro/net/` (the sync client transport uses
    socket timeouts, not awaits).

Deliberately-unbounded awaits (a proxy pump whose lifetime is bounded
by its endpoints' timeouts) use the shared escape hatch:
`# farlint: ok FL007 -- why`.
"""
from __future__ import annotations

import ast

from repro.analyze.core import Finding, SourceFile

#: scope: the asyncio network tier only (same rule as FL006)
SCOPE_PARTS = ("repro", "net")

_STREAM_METHODS = {"read", "readexactly", "readline", "readuntil",
                   "drain"}
_ASYNCIO_CALLS = {"asyncio.open_connection", "open_connection"}


def in_scope(rel: str) -> bool:
    parts = rel.replace("\\", "/").split("/")
    return any(tuple(parts[i:i + 2]) == SCOPE_PARTS
               for i in range(len(parts) - 2))


def _awaits(tree: ast.Module) -> list[ast.Await]:
    return [n for n in ast.walk(tree) if isinstance(n, ast.Await)]


def check(sf: SourceFile) -> list[Finding]:
    if not in_scope(sf.rel):
        return []
    findings: list[Finding] = []
    for aw in _awaits(sf.tree):
        call = aw.value
        if not isinstance(call, ast.Call):
            continue
        func = call.func
        try:
            text = ast.unparse(func)
        except Exception:       # pragma: no cover
            text = ""
        what = None
        if text in _ASYNCIO_CALLS:
            what = f"`{text}(...)`"
        elif (isinstance(func, ast.Attribute)
              and func.attr in _STREAM_METHODS):
            what = f"`.{func.attr}(...)`"
        if what is not None:
            findings.append(Finding(
                "FL007", sf.rel, aw.lineno,
                f"unbounded await of {what}: a partitioned peer hangs "
                f"this coroutine forever; wrap it in "
                f"`asyncio.wait_for(..., timeout)` so the connection "
                f"is reaped and deadlines/hedges stay live"))
    return findings
