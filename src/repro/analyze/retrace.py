"""FL003/FL004/FL005 retrace hazards around jax.jit sites.

Every retrace bug this repo has shipped falls in one of three classes,
each its own rule:

  FL003 static-args   `static_argnames` naming a parameter the jitted
                      function doesn't have (silently ignored -> retrace
                      per call), or a call site passing an unhashable
                      value (list/dict/set/array literal) for a static
                      arg (TypeError at trace time).
  FL004 jit-closure   `jax.jit` over a closure or bound method whose
                      captured state is mutated — jit snapshots nothing;
                      mutations after the first trace either never take
                      effect or take effect inconsistently across cached
                      executables.
  FL005 cache-key     a compile-cache key tuple built inside a function
                      that omits one of the function's parameters — the
                      PR 2 `interpret=None` bug class, where two configs
                      that compile differently share one cache slot.
                      Checked only for dicts whose name contains
                      "cache" (the repo convention for compile caches).

Recognized jit spellings: `jax.jit(f, ...)` / `@jax.jit` /
`@functools.partial(jax.jit, ...)` / `@partial(jax.jit, ...)`. Sites
whose wrapped callable is itself a call result (factories like
`jax.jit(make_step(...))`) can't be resolved statically and are skipped.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field

from repro.analyze.core import Finding, SourceFile

_MUTATING_METHODS = {"append", "extend", "add", "update", "pop", "clear",
                     "insert", "remove", "setdefault", "popitem",
                     "appendleft", "discard"}
_UNHASHABLE_CTORS = {"list", "dict", "set", "bytearray",
                     "np.array", "np.asarray", "numpy.array",
                     "numpy.asarray", "jnp.array", "jnp.asarray"}


def _unparse(node: ast.AST) -> str:
    try:
        return ast.unparse(node)
    except Exception:   # pragma: no cover - unparse is total on py310
        return ""


def _is_jax_jit(node: ast.expr) -> bool:
    return _unparse(node) in ("jax.jit", "jit")


def _jit_call_parts(call: ast.Call):
    """For `jax.jit(f, ...)` or `functools.partial(jax.jit, ...)` return
    (wrapped_expr_or_None, keywords). For partial the wrapped callable is
    applied later (decorator), so wrapped is None there."""
    if _is_jax_jit(call.func):
        wrapped = call.args[0] if call.args else None
        return wrapped, call.keywords
    if (_unparse(call.func) in ("functools.partial", "partial")
            and call.args and _is_jax_jit(call.args[0])):
        return None, call.keywords
    return False, None


def _static_argnames(keywords) -> tuple[str, ...] | None:
    """The literal static_argnames tuple, or None when absent/dynamic."""
    for kw in keywords or ():
        if kw.arg != "static_argnames":
            continue
        v = kw.value
        if isinstance(v, ast.Constant) and isinstance(v.value, str):
            return (v.value,)
        if isinstance(v, (ast.Tuple, ast.List)):
            names = []
            for e in v.elts:
                if not (isinstance(e, ast.Constant)
                        and isinstance(e.value, str)):
                    return None
                names.append(e.value)
            return tuple(names)
        return None
    return None


def _param_names(fn: ast.FunctionDef) -> list[str]:
    a = fn.args
    return ([p.arg for p in a.posonlyargs] + [p.arg for p in a.args]
            + [p.arg for p in a.kwonlyargs])


def _is_unhashable_expr(node: ast.expr) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set, ast.ListComp,
                         ast.DictComp, ast.SetComp, ast.GeneratorExp)):
        return True
    if isinstance(node, ast.Call):
        return _unparse(node.func) in _UNHASHABLE_CTORS
    return False


@dataclass
class _JitSite:
    line: int
    statics: tuple[str, ...] | None
    fn: ast.FunctionDef | None      # resolved wrapped function, if any
    call_names: list[str] = field(default_factory=list)  # how it's invoked


def _mutated_names(fn: ast.FunctionDef,
                   stop_at: ast.FunctionDef | None = None,
                   after_line: int = 0) -> set[str]:
    """Names the function mutates: rebinding, augmented assignment,
    stores through subscript/attribute, or mutating method calls.
    Nested function bodies are included (closures can mutate too),
    except `stop_at` (the jitted def itself). Only mutations lexically
    after `after_line` count — binding a value *before* the jitted def
    is initialization the trace will see, not a stale capture."""
    out: set[str] = set()

    def root_name(e: ast.expr) -> str | None:
        while isinstance(e, (ast.Subscript, ast.Attribute, ast.Starred)):
            e = e.value
        return e.id if isinstance(e, ast.Name) else None

    def walk(node):
        for child in ast.iter_child_nodes(node):
            if child is stop_at:
                continue
            if (isinstance(child, ast.stmt)
                    and getattr(child, "end_lineno", child.lineno)
                    < after_line):
                continue    # ends before the jitted def: initialization
            if isinstance(child, ast.Assign):
                for t in child.targets:
                    if isinstance(t, (ast.Subscript, ast.Attribute)):
                        n = root_name(t)
                        if n:
                            out.add(n)
                    elif isinstance(t, ast.Name):
                        out.add(t.id)
            elif isinstance(child, ast.AugAssign):
                n = root_name(child.target)
                if n:
                    out.add(n)
            elif isinstance(child, ast.Call):
                f = child.func
                if (isinstance(f, ast.Attribute)
                        and f.attr in _MUTATING_METHODS):
                    n = root_name(f.value)
                    if n:
                        out.add(n)
            walk(child)

    walk(fn)
    return out


def _free_loads(fn: ast.FunctionDef) -> set[str]:
    """Names loaded in `fn` that it neither binds nor receives as params."""
    bound = set(_param_names(fn))
    if fn.args.vararg:
        bound.add(fn.args.vararg.arg)
    if fn.args.kwarg:
        bound.add(fn.args.kwarg.arg)
    loads: set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Name):
            if isinstance(node.ctx, ast.Load):
                loads.add(node.id)
            else:
                bound.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if node is not fn:
                bound.add(node.name)
        elif isinstance(node, ast.comprehension):
            for sub in ast.walk(node.target):
                if isinstance(sub, ast.Name):
                    bound.add(sub.id)
    return loads - bound


class _Pass(ast.NodeVisitor):
    def __init__(self, sf: SourceFile):
        self.sf = sf
        self.findings: list[Finding] = []
        self._func_stack: list[ast.FunctionDef] = []
        self._class_stack: list[ast.ClassDef] = []
        self.sites: list[_JitSite] = []

    # -- helpers ------------------------------------------------------------
    def _flag(self, rule: str, line: int, msg: str) -> None:
        self.findings.append(Finding(rule, self.sf.rel, line, msg))

    def _resolve_callable(self, expr: ast.expr) -> ast.FunctionDef | None:
        """Find the def a `jax.jit(X)` wraps: a bare name in an enclosing
        scope, or `self.method` of the enclosing class."""
        if isinstance(expr, ast.Name):
            scopes: list[list[ast.stmt]] = [self.sf.tree.body]
            scopes += [f.body for f in self._func_stack]
            for body in reversed(scopes):
                for stmt in body:
                    if (isinstance(stmt, (ast.FunctionDef,
                                          ast.AsyncFunctionDef))
                            and stmt.name == expr.id):
                        return stmt
        elif (isinstance(expr, ast.Attribute)
              and isinstance(expr.value, ast.Name)
              and expr.value.id == "self" and self._class_stack):
            for stmt in self._class_stack[-1].body:
                if (isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef))
                        and stmt.name == expr.attr):
                    return stmt
        return None

    # -- collection ---------------------------------------------------------
    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._class_stack.append(node)
        self.generic_visit(node)
        self._class_stack.pop()

    def _visit_func(self, node) -> None:
        self._handle_decorators(node)
        self._func_stack.append(node)
        self.generic_visit(node)
        self._func_stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def _handle_decorators(self, fn) -> None:
        for dec in fn.decorator_list:
            statics: tuple[str, ...] | None = None
            jitted = False
            if _is_jax_jit(dec):
                jitted = True
            elif isinstance(dec, ast.Call):
                wrapped, keywords = _jit_call_parts(dec)
                if wrapped is False and keywords is None:
                    continue
                jitted = True
                statics = _static_argnames(keywords)
            if not jitted:
                continue
            site = _JitSite(dec.lineno, statics, fn)
            site.call_names = [fn.name, f"self.{fn.name}"]
            self.sites.append(site)
            self._check_closure(site, fn)

    def visit_Assign(self, node: ast.Assign) -> None:
        if isinstance(node.value, ast.Call):
            wrapped, keywords = _jit_call_parts(node.value)
            if wrapped is not False:
                statics = _static_argnames(keywords)
                fn = (self._resolve_callable(wrapped)
                      if wrapped is not None else None)
                site = _JitSite(node.lineno, statics, fn)
                for t in node.targets:
                    name = _unparse(t)
                    if name:
                        site.call_names.append(name)
                self.sites.append(site)
                if fn is not None:
                    self._check_closure(site, fn)
                if (isinstance(wrapped, ast.Attribute)
                        and isinstance(wrapped.value, ast.Name)
                        and wrapped.value.id == "self"):
                    self._flag(
                        "FL004", node.lineno,
                        f"jax.jit over bound method "
                        f"`self.{wrapped.attr}` captures mutable instance "
                        f"state; keep captured attributes write-once or "
                        f"suppress with a justification")
        self.generic_visit(node)

    # -- FL004: mutable closure capture --------------------------------------
    def _check_closure(self, site: _JitSite, fn: ast.FunctionDef) -> None:
        if not self._func_stack:
            return      # module/class level: captures are module globals
        enclosing = self._func_stack[-1]
        free = _free_loads(fn)
        mutated = _mutated_names(enclosing, stop_at=fn,
                                 after_line=fn.lineno)
        for name in sorted(free & mutated):
            self._flag(
                "FL004", site.line,
                f"jitted `{fn.name}` closes over `{name}`, which the "
                f"enclosing `{enclosing.name}` mutates — jit will not see "
                f"the mutation (stale capture)")

    # -- FL005: cache-key completeness ---------------------------------------
    def _check_cache_keys(self) -> None:
        for fn in [n for n in ast.walk(self.sf.tree)
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
            params = [p for p in _param_names(fn) if p not in ("self", "cls")]
            if not params:
                continue
            # key-tuple assignments: k = (a, b, ...)
            key_vars: dict[str, ast.Assign] = {}
            for node in ast.walk(fn):
                if (isinstance(node, ast.Assign)
                        and isinstance(node.value, ast.Tuple)
                        and len(node.targets) == 1
                        and isinstance(node.targets[0], ast.Name)):
                    key_vars[node.targets[0].id] = node
            if not key_vars:
                continue
            used_on_cache = self._cache_keyed_vars(fn, set(key_vars))
            for name in sorted(used_on_cache):
                assign = key_vars[name]
                contributing = {n.id for n in ast.walk(assign.value)
                                if isinstance(n, ast.Name)}
                # one level of local indirection: x = norm(param); (x, ...)
                for node in ast.walk(fn):
                    if (isinstance(node, ast.Assign)
                            and len(node.targets) == 1
                            and isinstance(node.targets[0], ast.Name)
                            and node.targets[0].id in contributing):
                        contributing |= {n.id for n in ast.walk(node.value)
                                         if isinstance(n, ast.Name)}
                missing = [p for p in params if p not in contributing]
                if missing:
                    self._flag(
                        "FL005", assign.lineno,
                        f"cache key `{name}` in `{fn.name}` omits "
                        f"parameter(s) {', '.join(repr(m) for m in missing)}"
                        f" — configs differing only there will collide")

    @staticmethod
    def _cache_keyed_vars(fn: ast.FunctionDef,
                          candidates: set[str]) -> set[str]:
        """Key variables actually used to index a *cache* dict
        (`k in CACHE`, `CACHE[k]`, `CACHE.get(k)`)."""
        used: set[str] = set()

        def is_cache_name(e: ast.expr) -> bool:
            return "cache" in _unparse(e).lower()

        for node in ast.walk(fn):
            if isinstance(node, ast.Compare):
                if (len(node.ops) == 1
                        and isinstance(node.ops[0], (ast.In, ast.NotIn))
                        and isinstance(node.left, ast.Name)
                        and node.left.id in candidates
                        and is_cache_name(node.comparators[0])):
                    used.add(node.left.id)
            elif isinstance(node, ast.Subscript):
                if (isinstance(node.slice, ast.Name)
                        and node.slice.id in candidates
                        and is_cache_name(node.value)):
                    used.add(node.slice.id)
            elif isinstance(node, ast.Call):
                f = node.func
                if (isinstance(f, ast.Attribute)
                        and f.attr in ("get", "setdefault", "pop")
                        and is_cache_name(f.value)):
                    for a in node.args[:1]:
                        if isinstance(a, ast.Name) and a.id in candidates:
                            used.add(a.id)
        return used

    # -- FL003 after collection ----------------------------------------------
    def _check_sites(self) -> None:
        for site in self.sites:
            if site.statics and site.fn is not None:
                params = set(_param_names(site.fn))
                for s in site.statics:
                    if s not in params:
                        self._flag(
                            "FL003", site.line,
                            f"static_argnames entry '{s}' is not a "
                            f"parameter of `{site.fn.name}` "
                            f"({', '.join(sorted(params)) or 'no params'})"
                            f" — jax silently ignores it")
            if not site.statics or not site.call_names:
                continue
            pos_params = ([p.arg for p in site.fn.args.posonlyargs]
                          + [p.arg for p in site.fn.args.args]
                          if site.fn is not None else [])
            names = set(site.call_names)
            for node in ast.walk(self.sf.tree):
                if not isinstance(node, ast.Call):
                    continue
                if _unparse(node.func) not in names:
                    continue
                for kw in node.keywords:
                    if kw.arg in site.statics and \
                            _is_unhashable_expr(kw.value):
                        self._flag(
                            "FL003", node.lineno,
                            f"call passes unhashable "
                            f"`{_unparse(kw.value)[:40]}` for static arg "
                            f"'{kw.arg}' — TypeError at trace time")
                for i, a in enumerate(node.args):
                    if i < len(pos_params) \
                            and pos_params[i] in site.statics \
                            and _is_unhashable_expr(a):
                        self._flag(
                            "FL003", node.lineno,
                            f"call passes unhashable "
                            f"`{_unparse(a)[:40]}` for static arg "
                            f"'{pos_params[i]}' — TypeError at trace time")


def check(sf: SourceFile) -> list[Finding]:
    p = _Pass(sf)
    p.visit(sf.tree)
    p._check_sites()
    p._check_cache_keys()
    return p.findings
