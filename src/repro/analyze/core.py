"""farlint core: findings, suppressions, baseline — the pass framework.

farlint is the repo-specific static-analysis suite (see docs/analysis.md).
It exists because the invariants this codebase lives by — "shared cluster
state is only touched under its lock", "the fused dispatch path never
syncs to the host before `finalize()`", "jit static args are hashable and
every compile-affecting input is in the cache key" — are exactly the kind
reviewers enforce until the day they don't. Each invariant is a pass over
the AST (`locks`, `hostsync`, `retrace`); this module is the machinery
they share:

  * `SourceFile` — parsed module + token-accurate comment map (the
    `# guarded-by:` / `# farlint:` conventions live in comments, which
    `ast` alone drops);
  * suppressions — `# farlint: ok <rule> -- <justification>` on the
    flagged line (or alone on the line above) waives a finding; the
    justification is REQUIRED, and a suppression without one is itself a
    finding (FL000) so "shut it up" can never masquerade as "reviewed";
  * baseline — `baseline.json` grandfathers pre-existing findings by
    content fingerprint (rule + path + source text + occurrence, NOT line
    number, so unrelated edits don't invalidate it); CI fails only on
    NEW findings, and entries whose code is gone are reported stale.

Stdlib-only on purpose: the CI lint job runs farlint without installing
jax, and `tools/analyze` bootstraps `src/` onto `sys.path` itself.
"""
from __future__ import annotations

import ast
import hashlib
import io
import json
import os
import re
import tokenize
from dataclasses import dataclass, field

# ---------------------------------------------------------------------- rules
#: rule id -> (alias, one-line description). The alias is what suppression
#: comments and docs use; both forms are accepted everywhere a rule is named.
RULES: dict[str, tuple[str, str]] = {
    "FL000": ("bad-suppression",
              "a `# farlint: ok` suppression is missing its rule list or "
              "`-- <justification>` string"),
    "FL001": ("lock-discipline",
              "an attribute declared `# guarded-by: <lock>` is read or "
              "written outside a `with <lock>:` block (and outside "
              "__init__)"),
    "FL002": ("host-sync",
              "a host synchronization (np.asarray / jax.device_get / "
              ".block_until_ready / int()/float()/.tolist() on a device "
              "value) inside a fused-dispatch-path function that is not a "
              "finalize boundary"),
    "FL003": ("static-args",
              "a jax.jit static_argnames entry that is not a parameter of "
              "the jitted function, or a call site passing an unhashable "
              "value for a static arg"),
    "FL004": ("jit-closure",
              "jax.jit over a closure or bound method that captures "
              "mutable state (retrace / stale-capture hazard)"),
    "FL005": ("cache-key",
              "a compile/cache key tuple that omits one of the enclosing "
              "function's parameters (the interpret=None bug class)"),
    "FL006": ("async-blocking",
              "a blocking call (time.sleep, a synchronous socket op, "
              ".result(), .block_until_ready()) inside an `async def` "
              "body under src/repro/net/ — it stalls the server event "
              "loop; await the async form or use run_in_executor"),
    "FL007": ("await-bound",
              "an unbounded `await reader.read*/writer.drain()/"
              "asyncio.open_connection()` under src/repro/net/ — a "
              "partitioned peer hangs it forever; wrap the call in "
              "`asyncio.wait_for(..., timeout)`"),
}

_ALIAS_TO_ID = {alias: rid for rid, (alias, _) in RULES.items()}


def rule_id(name: str) -> str | None:
    """Normalize 'FL002' or 'host-sync' to the rule id (None if unknown)."""
    name = name.strip()
    if name in RULES:
        return name
    return _ALIAS_TO_ID.get(name)


# ------------------------------------------------------------------- findings
@dataclass(frozen=True)
class Finding:
    rule: str                   # "FL001"
    path: str                   # repo-relative, '/'-separated
    line: int                   # 1-based
    message: str
    fingerprint: str = ""       # content hash: survives line drift

    @property
    def alias(self) -> str:
        return RULES.get(self.rule, ("?", ""))[0]

    def render(self) -> str:
        return f"{self.path}:{self.line}: {self.rule}({self.alias}) " \
               f"{self.message}"


def _with_fingerprints(findings: list[Finding],
                       lines_of: dict[str, list[str]]) -> list[Finding]:
    """Stamp each finding with a stable content fingerprint.

    The hash covers (rule, path, stripped source line text, occurrence
    index among same-text findings of the same rule) — NOT the line
    number, so a baseline survives edits elsewhere in the file but a
    second new violation on an identical-looking line still counts as
    new."""
    seen: dict[tuple, int] = {}
    out = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule)):
        lines = lines_of.get(f.path, [])
        text = (lines[f.line - 1].strip()
                if 0 < f.line <= len(lines) else "")
        key = (f.rule, f.path, text)
        occ = seen.get(key, 0)
        seen[key] = occ + 1
        digest = hashlib.sha1(
            "|".join((f.rule, f.path, text, str(occ))).encode()).hexdigest()
        out.append(Finding(f.rule, f.path, f.line, f.message, digest[:16]))
    return out


# ------------------------------------------------------------ comment parsing
_SUPPRESS_RE = re.compile(
    r"farlint:\s*ok\b\s*(?P<rules>[\w,\s-]*?)\s*"
    r"(?:--\s*(?P<why>.*\S))?\s*$")
_GUARD_RE = re.compile(r"guarded-by:\s*(?P<lock>\S+)")
_BOUNDARY_RE = re.compile(r"farlint:\s*finalize-boundary\b")


@dataclass
class Suppression:
    line: int                   # the comment's own line
    rules: frozenset            # normalized rule ids
    justification: str

    def covers(self, finding_line: int) -> bool:
        # on the flagged line, or alone on the line immediately above it
        return finding_line in (self.line, self.line + 1)


class SourceFile:
    """One parsed module: AST + comments + the farlint annotations."""

    def __init__(self, text: str, path: str, rel: str | None = None):
        self.text = text
        self.path = path
        self.rel = (rel or path).replace(os.sep, "/")
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=path)
        self.comments: dict[int, str] = {}
        self.code_lines: set[int] = set()   # lines holding non-comment code
        try:
            for tok in tokenize.generate_tokens(io.StringIO(text).readline):
                if tok.type == tokenize.COMMENT:
                    self.comments[tok.start[0]] = tok.string.lstrip("#")
                elif tok.type not in (tokenize.NL, tokenize.NEWLINE,
                                      tokenize.INDENT, tokenize.DEDENT,
                                      tokenize.ENCODING,
                                      tokenize.ENDMARKER):
                    self.code_lines.add(tok.start[0])
        except tokenize.TokenError:     # pragma: no cover - ast parsed OK
            pass
        self.suppressions, self.bad_suppressions = self._parse_suppressions()

    # -- conventions --------------------------------------------------------
    def _parse_suppressions(self) -> tuple[list[Suppression], list[Finding]]:
        sups, bad = [], []
        for line, comment in self.comments.items():
            if "farlint:" not in comment or "ok" not in comment.split(
                    "farlint:", 1)[1][:4]:
                continue
            m = _SUPPRESS_RE.search(comment)
            why = (m.group("why") or "").strip() if m else ""
            names = [n for n in re.split(r"[,\s]+",
                                         (m.group("rules") if m else "") or
                                         "") if n]
            ids = [rule_id(n) for n in names]
            if not m or not why or not ids or None in ids:
                bad.append(Finding(
                    "FL000", self.rel, line,
                    "suppression must name known rule(s) and give a "
                    "justification: `# farlint: ok <rule> -- <why>`"))
                continue
            sups.append(Suppression(line, frozenset(ids), why))
        return sups, bad

    def guard_comment(self, lineno: int) -> str | None:
        """The `# guarded-by: <lock>` annotation for a statement at
        `lineno`: on the line itself, or alone on the line above."""
        for ln in (lineno, lineno - 1):
            c = self.comments.get(ln)
            if c is None:
                continue
            if ln != lineno and ln in self.code_lines:
                continue        # the line above holds code: not ours
            m = _GUARD_RE.search(c)
            if m:
                return m.group("lock")
        return None

    def boundary_marker(self, lineno: int) -> bool:
        """True when `# farlint: finalize-boundary` marks this def line
        (on it, or alone immediately above)."""
        for ln in (lineno, lineno - 1):
            c = self.comments.get(ln)
            if c is None or (ln != lineno and ln in self.code_lines):
                continue
            if _BOUNDARY_RE.search(c):
                return True
        return False

    def suppressed(self, finding: Finding) -> bool:
        return any(finding.rule in s.rules and s.covers(finding.line)
                   for s in self.suppressions)


# ---------------------------------------------------------------------- engine
def _passes():
    # imported here so `core` stays importable from the passes themselves
    from repro.analyze import (asyncblock, awaitbound, hostsync, locks,
                               retrace)
    return (locks.check, hostsync.check, retrace.check, asyncblock.check,
            awaitbound.check)


def analyze_source(text: str, path: str,
                   rel: str | None = None) -> list[Finding]:
    """Run every pass over one module; returns unsuppressed findings
    (plus FL000 for malformed suppressions), fingerprinted."""
    try:
        sf = SourceFile(text, path, rel)
    except SyntaxError as e:
        rel = (rel or path).replace(os.sep, "/")
        return _with_fingerprints(
            [Finding("FL000", rel, e.lineno or 1,
                     f"file does not parse: {e.msg}")], {rel: []})
    findings = list(sf.bad_suppressions)
    for check in _passes():
        findings.extend(f for f in check(sf) if not sf.suppressed(f))
    return _with_fingerprints(findings, {sf.rel: sf.lines})


def iter_py_files(paths: list[str], root: str) -> list[str]:
    out = []
    for p in paths:
        full = p if os.path.isabs(p) else os.path.join(root, p)
        if os.path.isfile(full) and full.endswith(".py"):
            out.append(full)
            continue
        for dirpath, dirnames, filenames in os.walk(full):
            dirnames[:] = [d for d in dirnames
                           if d not in ("__pycache__", ".git")]
            out.extend(os.path.join(dirpath, f)
                       for f in filenames if f.endswith(".py"))
    return sorted(out)


def analyze_paths(paths: list[str], root: str | None = None) -> list[Finding]:
    """Analyze every .py file under `paths` (files or directories),
    reporting paths relative to `root` (default: cwd)."""
    root = os.path.abspath(root or os.getcwd())
    findings: list[Finding] = []
    for path in iter_py_files(paths, root):
        rel = os.path.relpath(path, root)
        with open(path, encoding="utf-8") as f:
            text = f.read()
        findings.extend(analyze_source(text, path, rel))
    return findings


# --------------------------------------------------------------------- baseline
@dataclass
class BaselineResult:
    new: list[Finding] = field(default_factory=list)
    grandfathered: list[Finding] = field(default_factory=list)
    stale: list[dict] = field(default_factory=list)   # entries with no match


def load_baseline(path: str) -> list[dict]:
    if not os.path.exists(path):
        return []
    with open(path, encoding="utf-8") as f:
        data = json.load(f)
    return list(data.get("findings", []))


def save_baseline(path: str, findings: list[Finding]) -> None:
    data = {
        "comment": "farlint baseline: grandfathered findings (see "
                   "docs/analysis.md). Regenerate with "
                   "`python -m tools.analyze --update-baseline`.",
        "findings": [
            {"rule": f.rule, "path": f.path, "line": f.line,
             "message": f.message, "fingerprint": f.fingerprint}
            for f in sorted(findings,
                            key=lambda f: (f.path, f.line, f.rule))],
    }
    with open(path, "w", encoding="utf-8") as f:
        json.dump(data, f, indent=2)
        f.write("\n")


def apply_baseline(findings: list[Finding],
                   entries: list[dict]) -> BaselineResult:
    """Split findings into new vs grandfathered; report stale entries.

    Matching is by fingerprint (content-addressed). Each baseline entry
    absorbs at most one finding, so a *second* identical violation is
    still new. FL000 (malformed suppression) is never grandfathered —
    a broken justification must be fixed, not baselined."""
    res = BaselineResult()
    unused = {e.get("fingerprint"): e for e in entries}
    for f in findings:
        if f.rule != "FL000" and f.fingerprint in unused:
            res.grandfathered.append(f)
            del unused[f.fingerprint]
        else:
            res.new.append(f)
    res.stale = list(unused.values())
    return res
