"""FL002 host-sync: the fused dispatch path stays lazy until finalize().

PR 1's contract: a `farview_request` produces lazy device values and the
ONLY host synchronization happens in `finalize()`. This pass machine
checks it for the modules on that path — `core/pipeline.py`,
`core/offload.py`, and everything under `kernels/` — using a small
intraprocedural taint analysis so that legitimate host-side metadata
math (shapes, page counts, bucket sizes) is not flagged.

Taint model, per function:

  sources      calls into jax/jnp/lax, `self._jit*` executables, bare
               names defined at module level in the same file (kernel
               entry points calling each other), and dotted calls whose
               root was imported from a `repro.` module — all return
               device values;
  propagation  through subscripts, attributes, arithmetic, tuple/list
               packing and unpacking, loops, and plain assignment;
  sanitizers   `.shape` / `.ndim` / `.dtype` / `.size` and Python
               literals are host metadata — untainted;
  sinks        `np.asarray` / `np.array` / `int()` / `float()` /
               `bool()` / `.tolist()` / `.item()` on a tainted value,
               plus `jax.device_get(...)` and `.block_until_ready()`
               unconditionally (those two exist only to sync).

A function is exempt when it is a *finalize boundary*: its name
contains `finalize`, it carries a `# farlint: finalize-boundary`
comment on/above its `def`, or it is reachable ONLY from exempt
functions in the same module (computed to a fixpoint over the
module-local call graph) — the boundary covers its private helpers.
Sink results are returned untainted so one violation reports once, not
as a cascade.
"""
from __future__ import annotations

import ast

from repro.analyze.core import Finding, SourceFile

#: modules on the fused dispatch path (suffix match on the repo-relative
#: '/'-separated path, plus any file under a `kernels/` directory)
SCOPE_SUFFIXES = ("core/pipeline.py", "core/offload.py")

_SANITIZING_ATTRS = {"shape", "ndim", "dtype", "size", "nbytes"}
_SINK_CALLS = {"np.asarray", "np.array", "numpy.asarray", "numpy.array"}
_SINK_CASTS = {"int", "float", "bool"}
_SINK_METHODS = {"tolist", "item"}
_ALWAYS_SINK_METHODS = {"block_until_ready"}
_ALWAYS_SINK_CALLS = {"jax.device_get"}


def in_scope(rel: str) -> bool:
    rel = rel.replace("\\", "/")
    if rel.endswith(SCOPE_SUFFIXES):
        return True
    parts = rel.split("/")
    return "kernels" in parts[:-1]


# -------------------------------------------------------------- module survey
def _module_defs(tree: ast.Module) -> list[ast.FunctionDef]:
    """Every function def in the module, including methods and nested."""
    return [n for n in ast.walk(tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]


def _repro_aliases(tree: ast.Module) -> set[str]:
    """Names bound by imports of repro modules (treated as device-value
    producers when called through, e.g. `kops.group_aggregate(...)`)."""
    out = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name.startswith("repro"):
                    out.add((a.asname or a.name).split(".")[0])
        elif isinstance(node, ast.ImportFrom):
            if node.module and (node.module.startswith("repro")
                                or node.level > 0):
                for a in node.names:
                    out.add(a.asname or a.name.split(".")[0])
    return out


def _called_names(fn: ast.FunctionDef) -> set[str]:
    """Bare / self-relative callee names, for the module-local call graph."""
    out = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name):
                out.add(f.id)
            elif (isinstance(f, ast.Attribute)
                  and isinstance(f.value, ast.Name)
                  and f.value.id == "self"):
                out.add(f.attr)
    return out


def _boundary_set(sf: SourceFile,
                  defs: list[ast.FunctionDef]) -> set[ast.FunctionDef]:
    """Finalize-boundary functions, closed over private-helper reachability."""
    by_name: dict[str, list[ast.FunctionDef]] = {}
    for fn in defs:
        by_name.setdefault(fn.name, []).append(fn)
    exempt = {fn for fn in defs
              if "finalize" in fn.name.lower()
              or sf.boundary_marker(fn.lineno)}
    # lexical nesting inherits the boundary: a def inside an exempt def
    # is part of that boundary's implementation
    for fn in list(exempt):
        for sub in ast.walk(fn):
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                exempt.add(sub)
    callers: dict[ast.FunctionDef, set[ast.FunctionDef]] = {
        fn: set() for fn in defs}
    for fn in defs:
        for name in _called_names(fn):
            for callee in by_name.get(name, ()):
                if callee is not fn:
                    callers[callee].add(fn)
    changed = True
    while changed:
        changed = False
        for fn in defs:
            if fn in exempt or not callers[fn]:
                continue
            if callers[fn] <= exempt:
                exempt.add(fn)
                changed = True
    return exempt


# ------------------------------------------------------------- taint analysis
class _FnTaint:
    """Two-pass monotone taint walk over one function body."""

    def __init__(self, sf: SourceFile, fn: ast.FunctionDef,
                 module_fn_names: set[str], repro_aliases: set[str]):
        self.sf = sf
        self.fn = fn
        self.module_fn_names = module_fn_names
        self.repro_aliases = repro_aliases
        self.env: dict[str, bool] = {}
        self.findings: list[Finding] = []
        self.reporting = False

    def run(self) -> list[Finding]:
        body = self.fn.body
        self.reporting = False
        self._visit_block(body)     # pass 1: reach the taint fixpoint
        self._visit_block(body)
        self.reporting = True
        self._visit_block(body)     # pass 2: report sinks once
        return self.findings

    # -- statements ---------------------------------------------------------
    def _visit_block(self, stmts) -> None:
        for stmt in stmts:
            self._visit_stmt(stmt)

    def _visit_stmt(self, stmt: ast.stmt) -> None:
        if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
            # nested defs are analyzed as their own functions; their body
            # still shares our env read-only via closures — walk it for
            # taint of assigned outer names only, which we approximate by
            # skipping (nested defs on this path are pipeline stage fns).
            return
        if isinstance(stmt, ast.Assign):
            t = self._taint(stmt.value)
            for target in stmt.targets:
                self._bind(target, t, stmt.value)
        elif isinstance(stmt, ast.AnnAssign):
            if stmt.value is not None:
                self._bind(stmt.target, self._taint(stmt.value), stmt.value)
        elif isinstance(stmt, ast.AugAssign):
            t = self._taint(stmt.value) or self._taint(stmt.target)
            self._bind(stmt.target, t, stmt.value)
        elif isinstance(stmt, (ast.For, ast.AsyncFor)):
            self._bind(stmt.target, self._taint(stmt.iter), stmt.iter)
            self._visit_block(stmt.body)
            self._visit_block(stmt.orelse)
        elif isinstance(stmt, (ast.While, ast.If)):
            self._taint(stmt.test)
            self._visit_block(stmt.body)
            self._visit_block(stmt.orelse)
        elif isinstance(stmt, (ast.With, ast.AsyncWith)):
            for item in stmt.items:
                t = self._taint(item.context_expr)
                if item.optional_vars is not None:
                    self._bind(item.optional_vars, t, item.context_expr)
            self._visit_block(stmt.body)
        elif isinstance(stmt, ast.Try):
            self._visit_block(stmt.body)
            for handler in stmt.handlers:
                self._visit_block(handler.body)
            self._visit_block(stmt.orelse)
            self._visit_block(stmt.finalbody)
        elif isinstance(stmt, ast.Return):
            if stmt.value is not None:
                self._taint(stmt.value)
        elif isinstance(stmt, ast.Expr):
            self._taint(stmt.value)
        elif isinstance(stmt, (ast.Assert, ast.Raise, ast.Delete)):
            for child in ast.iter_child_nodes(stmt):
                if isinstance(child, ast.expr):
                    self._taint(child)
        # Pass/Break/Continue/Import/Global/Nonlocal: nothing to do

    def _bind(self, target: ast.expr, tainted: bool, value: ast.expr) -> None:
        if isinstance(target, ast.Name):
            if tainted:
                self.env[target.id] = True
        elif isinstance(target, (ast.Tuple, ast.List)):
            # unpacking a tainted aggregate taints every element
            for elt in target.elts:
                self._bind(elt, tainted, value)
        elif isinstance(target, ast.Starred):
            self._bind(target.value, tainted, value)
        # stores into attributes/subscripts don't create new local names

    # -- expressions --------------------------------------------------------
    def _flag(self, node: ast.AST, what: str) -> None:
        if self.reporting:
            self.findings.append(Finding(
                "FL002", self.sf.rel, node.lineno,
                f"{what} inside `{self.fn.name}` on the fused dispatch "
                f"path; move it behind a finalize boundary (see "
                f"docs/analysis.md)"))

    def _is_source_call(self, func: ast.expr, text: str) -> bool:
        root = text.split(".", 1)[0]
        if root in ("jnp", "lax") or text.startswith("jax."):
            return True
        if text.startswith("self._jit"):
            return True
        if isinstance(func, ast.Name):
            return func.id in self.module_fn_names
        if "." in text and root in self.repro_aliases:
            return True
        return False

    def _taint(self, node: ast.expr) -> bool:
        if isinstance(node, ast.Name):
            return self.env.get(node.id, False)
        if isinstance(node, ast.Constant):
            return False
        if isinstance(node, ast.Attribute):
            base = self._taint(node.value)
            if node.attr in _SANITIZING_ATTRS:
                return False
            return base
        if isinstance(node, ast.Subscript):
            self._taint(node.slice)
            return self._taint(node.value)
        if isinstance(node, ast.Call):
            return self._taint_call(node)
        if isinstance(node, ast.BinOp):
            left = self._taint(node.left)
            return self._taint(node.right) or left
        if isinstance(node, ast.UnaryOp):
            return self._taint(node.operand)
        if isinstance(node, ast.BoolOp):
            vals = [self._taint(v) for v in node.values]
            return any(vals)
        if isinstance(node, ast.Compare):
            t = self._taint(node.left)
            for comp in node.comparators:
                t = self._taint(comp) or t
            return t
        if isinstance(node, ast.IfExp):
            self._taint(node.test)
            body = self._taint(node.body)
            return self._taint(node.orelse) or body
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            vals = [self._taint(e) for e in node.elts]
            return any(vals)
        if isinstance(node, ast.Dict):
            vals = [self._taint(v) for v in node.values if v is not None]
            vals += [self._taint(k) for k in node.keys if k is not None]
            return any(vals)
        if isinstance(node, ast.Starred):
            return self._taint(node.value)
        if isinstance(node, (ast.ListComp, ast.SetComp, ast.GeneratorExp,
                             ast.DictComp)):
            t = False
            for gen in node.generators:
                gt = self._taint(gen.iter)
                self._bind(gen.target, gt, gen.iter)
                t = t or gt
                for cond in gen.ifs:
                    self._taint(cond)
            if isinstance(node, ast.DictComp):
                t = self._taint(node.key) or t
                t = self._taint(node.value) or t
            else:
                t = self._taint(node.elt) or t
            return t
        if isinstance(node, ast.JoinedStr):
            for v in node.values:
                if isinstance(v, ast.FormattedValue):
                    self._taint(v.value)
            return False
        if isinstance(node, ast.Lambda):
            return False
        if isinstance(node, (ast.Slice,)):
            for part in (node.lower, node.upper, node.step):
                if part is not None:
                    self._taint(part)
            return False
        if isinstance(node, ast.NamedExpr):
            t = self._taint(node.value)
            self._bind(node.target, t, node.value)
            return t
        return False

    def _taint_call(self, node: ast.Call) -> bool:
        func = node.func
        try:
            text = ast.unparse(func)
        except Exception:   # pragma: no cover
            text = ""
        arg_taints = [self._taint(a) for a in node.args]
        arg_taints += [self._taint(kw.value) for kw in node.keywords]
        any_tainted = any(arg_taints)

        # unconditional sinks
        if text in _ALWAYS_SINK_CALLS:
            self._flag(node, f"`{text}(...)` (device->host transfer)")
            return False
        if (isinstance(func, ast.Attribute)
                and func.attr in _ALWAYS_SINK_METHODS):
            self._flag(node, f"`.{func.attr}()` (blocks on the device)")
            return False

        # tainted-only sinks
        if text in _SINK_CALLS:
            if any_tainted:
                self._flag(node, f"`{text}(...)` on a device value")
            return False
        if text in _SINK_CASTS:
            if any_tainted:
                self._flag(node, f"`{text}(...)` on a device value "
                                 f"(implicit sync)")
            return False
        if (isinstance(func, ast.Attribute)
                and func.attr in _SINK_METHODS
                and self._taint(func.value)):
            self._flag(node, f"`.{func.attr}()` on a device value")
            return False

        # sources
        if self._is_source_call(func, text):
            return True
        # method call on a tainted receiver stays tainted (x.sum(), .at[].set)
        if isinstance(func, ast.Attribute) and self._taint(func.value):
            return True
        return any_tainted


def check(sf: SourceFile) -> list[Finding]:
    if not in_scope(sf.rel):
        return []
    defs = _module_defs(sf.tree)
    exempt = _boundary_set(sf, defs)
    module_fn_names = {fn.name for fn in defs}
    aliases = _repro_aliases(sf.tree)
    findings: list[Finding] = []
    for fn in defs:
        if fn in exempt:
            continue
        findings.extend(_FnTaint(sf, fn, module_fn_names, aliases).run())
    return findings
