"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

On this CPU container it trains reduced configs end-to-end (the quickstart /
examples path). On a real fleet the SAME driver runs the full config: the
mesh comes from make_production_mesh(), shardings from distributed/sharding,
and the loop from runtime/train_loop (restore-on-start, preemption hook,
async checkpoints). XLA latency-hiding flags for real TPU runs:

    LIBTPU_INIT_ARGS="--xla_tpu_enable_async_collective_fusion=true
      --xla_tpu_enable_async_collective_fusion_fuse_all_gather=true
      --xla_tpu_overlap_compute_collective_tc=true
      --xla_enable_async_all_gather=true"
"""
from __future__ import annotations

import argparse

import jax
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--smoke", action="store_true", default=True,
                    help="use the reduced same-family config (CPU)")
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compression", default="none",
                    choices=("none", "int8_ef"))
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_train")
    ap.add_argument("--checkpoint-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.configs.base import TrainConfig, smoke_config
    from repro.data.pipeline import TokenPipeline
    from repro.models.lm import LM
    from repro.runtime.train_loop import TrainLoop

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(1, args.steps // 10),
                       checkpoint_dir=args.checkpoint_dir,
                       checkpoint_every=args.checkpoint_every,
                       grad_compression=args.grad_compression,
                       seed=args.seed)
    lm = LM(cfg)
    pipe = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch, seed=args.seed)
    loop = TrainLoop(lm, tcfg, pipe, microbatches=args.microbatches)
    print(f"training {args.arch} ({'smoke' if args.smoke else 'full'}) "
          f"for {args.steps} steps on {jax.device_count()} device(s)")
    stats = loop.run(args.steps)
    losses = stats.losses
    k = max(1, len(losses) // 10)
    print(f"steps={stats.steps_done} restarts={stats.restarts} "
          f"nan_events={stats.nan_events}")
    print(f"loss: first10={np.mean(losses[:k]):.4f} "
          f"last10={np.mean(losses[-k:]):.4f}")


if __name__ == "__main__":
    main()
