"""Roofline table generator: launch_results/*.json -> markdown tables.

    PYTHONPATH=src python -m repro.launch.roofline [--mesh pod]

Per (arch x shape) cell on the single-pod mesh (per the assignment: the
roofline table is single-pod; the multi-pod pass only proves the "pod"
axis shards):

  compute    = scaled_flops_per_device / 197e12         [s]
  memory     = scaled_hbm_bytes_per_device / 819e9      [s]
  collective = scaled_coll_bytes_per_device / 50e9      [s]
  dominant   = argmax of the three
  MODEL_FLOPS / HLO_FLOPS  (useful-compute ratio; catches remat waste)
  roofline fraction = compute / max(all three) — the headline score.

All inputs are trip-count-scaled per-device numbers from hlo_analysis (raw
cost_analysis counts while bodies once; see EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.configs import ARCHS, SHAPES
from repro.launch.dryrun import RESULT_DIR


def load_cells(result_dir: str, mesh: str, tag: str = "") -> dict:
    cells = {}
    for path in glob.glob(os.path.join(result_dir, "*.json")):
        rec = json.load(open(path))
        if not isinstance(rec, dict):      # side-car files (comparisons)
            continue
        if rec.get("mesh") != mesh or rec.get("tag", "") != (tag or ""):
            continue
        if rec.get("kv_mode", "far") != "far":
            continue
        cells[(rec["arch"], rec["shape"])] = rec
    return cells


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.3g}us"
    if x < 1:
        return f"{x*1e3:.3g}ms"
    return f"{x:.3g}s"


def one_sentence(rec: dict) -> str:
    """What would move the dominant term down (per-cell heuristic note)."""
    r = rec["roofline"]
    dom = r["dominant"]
    shape = rec["shape"]
    arch = rec["arch"]
    if dom == "memory":
        if "decode" in shape or "long" in shape:
            return ("decode reads the whole KV/state working set per token; "
                    "fuse attention (Pallas) and quantize the cache to cut "
                    "bytes")
        return ("f32 attention-score / scan-state tensors round-trip HBM; "
                "fused (flash) attention kernels and bf16 intermediates cut "
                "the traffic")
    if dom == "collective":
        if rec.get("params_total", 0) > 1e10 or "moe" in arch:
            return ("expert all-to-all + grad all-reduce dominate; overlap "
                    "a2a with expert compute and reduce-scatter grads in "
                    "bf16")
        return ("grad all-reduce dominates; reduce-scatter + int8 "
                "compression on the DP axis")
    return ("MXU-bound: raise arithmetic intensity per chip (bigger "
            "per-device batch) or accept — this is the roofline")


def markdown_table(cells: dict) -> str:
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "MODEL/HLO flops | roofline frac |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCHS:
        for shape in SHAPES:
            rec = cells.get((arch, shape))
            if rec is None:
                lines.append(f"| {arch} | {shape} | — | — | — | — | — | "
                             f"(missing) |")
                continue
            if rec["status"] == "skipped":
                lines.append(f"| {arch} | {shape} | — | — | — | — | — | "
                             f"skipped: full attn @500k |")
                continue
            r = rec["roofline"]
            lines.append(
                f"| {arch} | {shape} | {fmt_s(r['t_compute_s'])} | "
                f"{fmt_s(r['t_memory_s'])} | {fmt_s(r['t_collective_s'])} | "
                f"{r['dominant']} | {r['useful_flops_ratio']:.2f} | "
                f"{r['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def notes_table(cells: dict) -> str:
    lines = ["| arch x shape | dominant | what moves it down |",
             "|---|---|---|"]
    for (arch, shape), rec in sorted(cells.items()):
        if rec["status"] != "ok":
            continue
        r = rec["roofline"]
        lines.append(f"| {arch} x {shape} | {r['dominant']} | "
                     f"{one_sentence(rec)} |")
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mesh", default="pod", choices=("pod", "multipod"))
    ap.add_argument("--tag", default="")
    ap.add_argument("--dir", default=RESULT_DIR)
    ap.add_argument("--notes", action="store_true")
    args = ap.parse_args()
    cells = load_cells(args.dir, args.mesh, args.tag)
    print(markdown_table(cells))
    if args.notes:
        print()
        print(notes_table(cells))


if __name__ == "__main__":
    main()
