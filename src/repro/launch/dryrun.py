import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder CPU devices stand in for 2 pods x 256 v5e chips.
For each cell we:

  1. build the production mesh (16,16) ("data","model") or (2,16,16)
     ("pod","data","model"),
  2. derive param/opt/batch/cache PartitionSpecs (distributed/sharding.py),
  3. jit the exact step function the runtime executes (runtime/steps.py)
     against ShapeDtypeStruct stand-ins (no allocation),
  4. .lower().compile() — sharding mismatches, compile-time OOM, and
     unsupported collectives all fail HERE,
  5. record memory_analysis(), cost_analysis(), and the collective-op bytes
     parsed from the compiled HLO into launch_results/<cell>.json —
     the §Roofline analysis reads these.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --mesh multipod  # 512-chip
"""
import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (ARCHS, SHAPES, cell_is_applicable, get_config,
                           input_specs)
from repro.configs.base import ModelConfig, Shape, TrainConfig
from repro.distributed import sharding as S
from repro.launch.hlo_analysis import analyze as hlo_analyze
from repro.launch.mesh import HW, make_production_mesh
from repro.models.lm import LM
from repro.runtime import steps as R

RESULT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "launch_results")

# HLO collective ops whose operand bytes constitute the collective roofline
# term (paper: "bytes over the network"; here: bytes over ICI/DCN links).
# Compiled HLO references operands by %name, so operand bytes are derived
# from the op's RESULT shape + op kind + replica-group size:
#   all-reduce / all-to-all / collective-permute: operand == result
#   all-gather:      operand = result / group_size
#   reduce-scatter:  operand = result * group_size
_COLL_LINE_RE = re.compile(
    r"=\s+(?:\()?([a-z0-9]+)\[([0-9,]*)\][^=]*?"
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+?)\}")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)           # [n_groups,group_size]<=[...]
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_LIST_RE.search(line)      # {{0, 1, ...}, ...}
    if m:
        return max(1, len(m.group(1).split(",")))
    return 1


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by each collective op class (operand sizes).

    The compiled module is the post-SPMD per-device program, so shapes are
    per-partition: summing operand bytes gives bytes each chip injects into
    the interconnect per step. `link_bytes` additionally models ring-
    algorithm link traffic: all-reduce moves 2x(g-1)/g of the operand,
    all-gather/reduce-scatter (g-1)/g of the full tensor, a2a/permute 1x.
    """
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    link = 0.0
    n_ops = 0
    for line in hlo_text.splitlines():
        m = _COLL_LINE_RE.search(line)
        if not m:
            continue
        dt, dims, op = m.groups()
        res = _shape_bytes(dt, dims)
        g = _group_size(line)
        if op == "all-gather":
            operand = res // max(g, 1)
            link += res * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            operand = res * g
            link += operand * (g - 1) / max(g, 1)
        elif op == "all-reduce":
            operand = res
            link += 2.0 * res * (g - 1) / max(g, 1)
        else:  # all-to-all, collective-permute
            operand = res
            link += res
        out[op] += operand
        n_ops += 1
    out["total"] = sum(v for k, v in out.items())
    out["link_bytes"] = int(link)
    out["n_ops"] = n_ops
    return out


def _mem_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _cost_dict(compiled) -> dict:
    from repro.jax_compat import cost_analysis
    try:
        ca = cost_analysis(compiled)
    except Exception:
        return {}
    keep = ("flops", "bytes accessed", "transcendentals", "optimal_seconds")
    return {k: float(v) for k, v in ca.items() if k in keep}


# ---------------------------------------------------------------------------
# model-FLOPs estimate (6 * N_active * D) for the useful-compute ratio
# ---------------------------------------------------------------------------
def exact_param_counts(cfg: ModelConfig) -> tuple[int, int]:
    """(total, active) param counts measured from the real init tree.

    Total comes from jax.eval_shape over LM.init (no allocation). Active
    subtracts the un-routed expert weights for MoE: per token only top_k of
    n_experts expert FFNs run.
    """
    lm = LM(cfg)
    shapes = jax.eval_shape(lm.init, jax.random.PRNGKey(0))
    total = sum(int(np.prod(s.shape)) for s in jax.tree.leaves(shapes))
    active = total
    if cfg.n_experts:
        expert_w = 3 * cfg.d_model * cfg.d_expert * cfg.n_experts
        active_w = 3 * cfg.d_model * cfg.d_expert * cfg.top_k
        active = total - cfg.n_layers * (expert_w - active_w)
    return total, active


def model_flops(cfg: ModelConfig, shape: Shape, n_total: int,
                n_active: int) -> float:
    """MODEL_FLOPS = 6*N_active*D (train) / 2*N_active*D (fwd-only).

    Embedding-table params don't do matmul FLOPs on the input side, but the
    unembedding does; we follow the standard convention and count all
    non-embedding params + the unembed projection.
    """
    emb = cfg.vocab * cfg.d_model          # input embedding (lookup, no FLOPs)
    n_eff = max(n_active - emb, 1)
    if shape.kind == "train":
        return 6.0 * n_eff * shape.seq_len * shape.global_batch
    if shape.kind == "prefill":
        return 2.0 * n_eff * shape.seq_len * shape.global_batch
    return 2.0 * n_eff * shape.global_batch  # decode: 1 new token


# ---------------------------------------------------------------------------
# cell runners
# ---------------------------------------------------------------------------
def build_step(cfg: ModelConfig, shape: Shape, mesh, *, kv_mode: str = "far",
               microbatches: int = 1, remat: bool | None = None):
    """Returns (jitted_fn, arg ShapeDtypeStructs tuple)."""
    if remat is not None:
        cfg = cfg.replace(remat=remat)
    dp = S.batch_axes(mesh, shape.global_batch)
    act = S.activation_spec(mesh, shape.global_batch)
    lm = LM(cfg, mesh=mesh, dp_axes=dp,
            act_spec=NamedSharding(mesh, act),
            ce_act_spec=NamedSharding(mesh, act))
    key = jax.random.PRNGKey(0)
    pshapes = jax.eval_shape(lm.init, key)
    pspecs = S.param_specs(pshapes, mesh, cfg)
    psharding = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs)
    bspecs = S.batch_specs(cfg, shape, mesh)
    bshard = {k: NamedSharding(mesh, v) for k, v in bspecs.items()}
    ispecs = input_specs(cfg, shape)
    ispecs = {k: jax.ShapeDtypeStruct(v.shape, v.dtype,
                                      sharding=bshard[k])
              for k, v in ispecs.items()}
    pargs = jax.tree.map(
        lambda sd, sh: jax.ShapeDtypeStruct(sd.shape, sd.dtype, sharding=sh),
        pshapes, psharding)

    if shape.kind == "train":
        tcfg = TrainConfig(microbatch=microbatches)
        step = R.make_train_step(lm, tcfg, microbatches=microbatches)
        oshapes = jax.eval_shape(lambda p: R.init_train_state(lm, tcfg, p),
                                 pshapes)
        ospecs = {"adam": {"m": pspecs, "v": pspecs, "step": P()}}
        oshard = jax.tree.map(lambda s: NamedSharding(mesh, s), ospecs)
        oargs = jax.tree.map(
            lambda sd, sh: jax.ShapeDtypeStruct(sd.shape, sd.dtype,
                                                sharding=sh),
            oshapes, oshard)
        jitted = jax.jit(step,
                         in_shardings=(psharding, oshard, bshard),
                         donate_argnums=(0, 1))
        return jitted, (pargs, oargs, ispecs)

    if shape.kind == "prefill":
        step = R.make_prefill_step(lm, max_seq=shape.seq_len)
        jitted = jax.jit(step, in_shardings=(psharding, bshard))
        return jitted, (pargs, ispecs)

    # decode
    step = R.make_serve_step(lm, mode=kv_mode)
    cshapes = jax.eval_shape(
        lambda: lm.init_cache(shape.global_batch, shape.seq_len,
                              jnp.bfloat16))
    cspecs = S.cache_specs(cshapes, mesh, shape.global_batch)
    cshard = jax.tree.map(lambda s: NamedSharding(mesh, s), cspecs)
    cargs = jax.tree.map(
        lambda sd, sh: jax.ShapeDtypeStruct(sd.shape, sd.dtype, sharding=sh),
        cshapes, cshard)
    scal = jax.ShapeDtypeStruct((), jnp.int32)
    jitted = jax.jit(step,
                     in_shardings=(psharding, cshard, bshard, None, None),
                     donate_argnums=(1,))
    return jitted, (pargs, cargs, ispecs, scal, scal)


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             kv_mode: str = "far", tag: str = "") -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                 "kv_mode": kv_mode, "tag": tag}
    ok, why = cell_is_applicable(cfg, shape)
    if not ok:
        rec["status"] = "skipped"
        rec["reason"] = why
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    jitted, args = build_step(cfg, shape, mesh, kv_mode=kv_mode)
    lowered = jitted.lower(*args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    hlo = compiled.as_text()
    coll = collective_bytes(hlo)          # raw, body-once (reference)
    cost = _cost_dict(compiled)           # raw cost_analysis (reference)
    mem = _mem_dict(compiled)
    # trip-count-scaled per-device analysis (the real roofline input):
    # cost_analysis counts while bodies once; this scales by trip count.
    scaled = hlo_analyze(hlo)

    total_p, act_p = exact_param_counts(cfg)
    rec.update(
        status="ok", n_chips=n_chips,
        lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
        cost=cost, memory=mem, collectives=coll, scaled=scaled,
        params_total=total_p, params_active=act_p,
        model_flops=model_flops(cfg, shape, total_p, act_p),
        hlo_bytes=len(hlo),
    )
    return rec


def roofline_terms(rec: dict) -> dict:
    """The three §Roofline terms (seconds) from one cell record."""
    if rec.get("status") != "ok":
        return {}
    sc = rec["scaled"]                      # trip-count-scaled, per device
    flops = sc["flops"]
    bytes_acc = sc["hbm_bytes"]
    coll = sc["collective_bytes"]
    t_compute = flops / HW["peak_flops_bf16"]
    t_memory = bytes_acc / HW["hbm_bw"]
    t_coll = coll / HW["ici_bw_per_link"]
    dom = max((t_compute, "compute"), (t_memory, "memory"),
              (t_coll, "collective"))[1]
    useful = rec["model_flops"] / max(flops * rec["n_chips"], 1.0)
    bound = max(t_compute, t_memory, t_coll)
    return {"t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dom,
            "useful_flops_ratio": useful,
            "roofline_fraction": t_compute / max(bound, 1e-30)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCHS + (None,))
    ap.add_argument("--shape", default=None, choices=tuple(SHAPES) + (None,))
    ap.add_argument("--mesh", default="both",
                    choices=("pod", "multipod", "both"))
    ap.add_argument("--kv-mode", default="far",
                    choices=("far", "naive", "local"))
    ap.add_argument("--tag", default="")
    ap.add_argument("--force", action="store_true",
                    help="re-run cells that already have a result file")
    ap.add_argument("--out-dir", default=RESULT_DIR)
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = (["pod", "multipod"] if args.mesh == "both" else [args.mesh])

    n_ok = n_skip = n_fail = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape_name in shapes:
                name = f"{arch}_{shape_name}_{mesh_kind}"
                if args.kv_mode != "far":
                    name += f"_{args.kv_mode}"
                if args.tag:
                    name += f"_{args.tag}"
                path = os.path.join(args.out_dir, name + ".json")
                if os.path.exists(path) and not args.force:
                    print(f"[cached ] {name}")
                    continue
                t0 = time.time()
                try:
                    rec = run_cell(arch, shape_name, mesh_kind,
                                   kv_mode=args.kv_mode, tag=args.tag)
                    rec["roofline"] = roofline_terms(rec)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_kind, "status": "error",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-4000:]}
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                dt = time.time() - t0
                st = rec["status"]
                n_ok += st == "ok"
                n_skip += st == "skipped"
                n_fail += st == "error"
                extra = ""
                if st == "ok":
                    r = rec["roofline"]
                    extra = (f" dom={r['dominant']}"
                             f" tc={r['t_compute_s']:.3g}s"
                             f" tm={r['t_memory_s']:.3g}s"
                             f" tx={r['t_collective_s']:.3g}s")
                elif st == "error":
                    extra = " " + rec["error"][:120]
                print(f"[{st:7s}] {name} ({dt:.0f}s){extra}", flush=True)
    print(f"done: {n_ok} ok, {n_skip} skipped, {n_fail} failed")


if __name__ == "__main__":
    main()
