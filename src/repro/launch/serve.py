"""Serving driver: ``python -m repro.launch.serve --arch <id> [...]``.

Prefill + decode loop against the disaggregated KV pool. --kv-mode picks the
paper's evaluation triad: far (FV push-down), naive (RCPU fetch), local
(LCPU heads-TP). Reports tokens/s and the modeled per-layer network bytes
for the chosen mode (the Fig. 8 economics applied to serving).

With --listen, the --pool-nodes count stops being a model: that many
`FViewServer` sockets are spun up and a `FarCluster` of
`RemoteNodeHandle`s (repro.net) runs a real verb round over them,
reporting MEASURED shipped/read bytes next to the modeled number.
--connect HOST:PORT[,...] does the same against already-running servers
(`python -m repro.net.server`); the endpoint count overrides
--pool-nodes. See docs/network.md.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--kv-mode", default="local",
                    choices=("far", "naive", "local"))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--pool-nodes", type=int, default=16,
                    help="modeled Farview node count the KV pool is "
                         "sharded over (the tp term of the Fig. 8 "
                         "economics; mirrors FarCluster scale-out)")
    ap.add_argument("--listen", action="store_true",
                    help="self-host --pool-nodes FViewServer sockets and "
                         "route the pool round through FarCluster + "
                         "RemoteNodeHandle (real bytes, not modeled)")
    ap.add_argument("--connect", default=None,
                    metavar="HOST:PORT[,HOST:PORT...]",
                    help="running FViewServer endpoints to use as the "
                         "pool; the endpoint count overrides --pool-nodes")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.pool_nodes < 1:
        ap.error("--pool-nodes must be >= 1")
    if args.listen and args.connect:
        ap.error("--listen and --connect are mutually exclusive")

    from repro.configs import get_config
    from repro.configs.base import smoke_config
    from repro.core.far_kv import shipped_bytes_per_layer
    from repro.models import frontends as F
    from repro.models.lm import LM
    from repro.runtime.steps import make_serve_step

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = smoke_config(cfg)
    key = jax.random.PRNGKey(args.seed)
    lm = LM(cfg)
    params = lm.init(key)
    B = args.batch

    # prompt
    if cfg.embed_input:
        batch = {"embeds": F.audio_frame_embeddings(
            cfg, B, args.prompt_len, dtype=jnp.float32)}
    else:
        batch = {"tokens": jax.random.randint(
            key, (B, args.prompt_len), 0, cfg.vocab)}
    if cfg.n_image_tokens:
        batch["image_embeds"] = F.image_patch_embeddings(
            cfg, B, dtype=jnp.float32)

    serve = jax.jit(make_serve_step(lm, mode=args.kv_mode))
    cache = lm.init_cache(B, args.max_seq, jnp.float32)

    # teacher-forced "prefill" via decode steps (keeps the driver simple and
    # exercises the cache write path; lm.prefill is the batched alternative)
    pos = 0
    tok = (batch["tokens"][:, :1] if "tokens" in batch
           else jnp.zeros((B, 1), jnp.int32))
    t0 = time.perf_counter()
    for t in range(args.prompt_len):
        inp = ({"tokens": batch["tokens"][:, t:t + 1]}
               if "tokens" in batch else
               {"embeds": batch["embeds"][:, t:t + 1]})
        tok, cache = serve(params, cache, inp, jnp.int32(pos),
                           jnp.int32(pos))
        pos += 1
    gen = []
    for _ in range(args.gen_len):
        inp = ({"tokens": tok[:, None]} if not cfg.embed_input else
               {"embeds": jnp.zeros((B, 1, cfg.d_model), jnp.float32)})
        tok, cache = serve(params, cache, inp, jnp.int32(pos),
                           jnp.int32(pos))
        gen.append(np.asarray(tok))
        pos += 1
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    total_tokens = B * (args.prompt_len + args.gen_len)
    print(f"served {total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s, mode={args.kv_mode})")
    nodes = args.pool_nodes
    if args.connect:
        nodes = len(args.connect.split(","))
    ship = shipped_bytes_per_layer(
        args.kv_mode, batch=B, hq=cfg.n_heads, hkv=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, seq_len=args.max_seq,
        tp=nodes)
    print(f"modeled network bytes/layer/step @{nodes} pool nodes: {ship} "
          f"({max(1, ship // nodes)}/node)")

    if args.listen or args.connect:
        _network_pool_round(args, nodes)


def _network_pool_round(args, nodes: int) -> None:
    """The real thing behind the model: a FarCluster of RemoteNodeHandles
    over FViewServer sockets runs one selection round on a KV-shaped
    table and reports MEASURED wire bytes (docs/network.md)."""
    from repro.core import operators as op
    from repro.core.table import Column, FTable
    from repro.net import remote_cluster

    servers = []
    if args.connect:
        endpoints = []
        for spec in args.connect.split(","):
            host, _, port = spec.strip().rpartition(":")
            endpoints.append((host or "127.0.0.1", int(port)))
    else:
        from repro.net.server import FViewServer
        servers = [FViewServer.start_in_thread(node_id=i)
                   for i in range(nodes)]
        endpoints = [(s.host, s.port) for s in servers]

    try:
        cl = remote_cluster(endpoints)
        cqp = cl.open_connection()
        n = 4096
        cols = (Column("pos", "i32"), Column("k0"), Column("k1"),
                Column("v0"), Column("v1"))
        rng = np.random.default_rng(args.seed)
        ft = FTable("kv_blocks", cols, n_rows=n)
        words = ft.encode({
            "pos": np.arange(n, dtype=np.int32),
            **{c.name: rng.standard_normal(n).astype(np.float32)
               for c in cols[1:]}})
        ct = cl.alloc_table_mem(cqp, ft)
        cl.table_write(cqp, ct, words)
        pipe = (op.Select((op.Predicate("k0", ">", 1.0),)),)
        res = cl.farview_request(cqp, ct, pipe).finalize()
        print(f"real pool round over {len(endpoints)} FViewServer "
              f"socket(s): {res.count}/{n} rows matched, "
              f"shipped {res.shipped_bytes} B, read {res.read_bytes} B "
              f"({max(1, res.shipped_bytes // len(endpoints))} B/node)")
        cl.free_table_mem(cqp, ct)
    finally:
        for s in servers:
            s.stop_thread()


if __name__ == "__main__":
    main()
