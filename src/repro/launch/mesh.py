"""Production meshes. Importing this module never touches jax device state.

Single pod:  (16, 16)    axes ("data", "model")   = 256 chips (v5e pod)
Multi-pod:   (2, 16, 16) axes ("pod", "data", "model") = 512 chips

The "model" axis carries tensor parallelism, expert parallelism, and the
Farview disaggregated-pool striping (far-KV sequence shards). The "data"
(+"pod") axes carry batch data parallelism and ZeRO/FSDP parameter sharding.
Cross-pod traffic (DCN) only ever sees data-parallel gradient reductions —
which is what the int8+error-feedback compressor (distributed/compress.py)
targets.
"""
from __future__ import annotations

from repro.jax_compat import make_mesh, set_mesh  # noqa: F401  (re-export)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for CPU tests (device count must already allow it)."""
    return make_mesh(shape, axes)


# TPU v5e hardware constants used by the roofline analysis (per chip).
HW = {
    "peak_flops_bf16": 197e12,     # FLOP/s
    "hbm_bw": 819e9,               # B/s
    "ici_bw_per_link": 50e9,       # B/s per link (~2 links usable per axis)
    "dcn_bw": 25e9,                # B/s per host across pods (approximate)
    "hbm_bytes": 16 * 2**30,       # 16 GiB HBM per chip
}
