"""Trip-count-aware FLOP / HBM-byte / collective-byte analysis of compiled HLO.

Why this exists: `compiled.cost_analysis()` counts each `while` body ONCE,
regardless of trip count (verified empirically: a lax.scan of 10 matmuls
reports the flops of 1). Every model here scans over layer groups, KV chunks,
and CE chunks, so raw cost_analysis under-counts by 10-100x. The compiled
HLO text, however, carries `backend_config={"known_trip_count":{"n":"N"}}`
on every while op, so an exact accounting is recoverable:

  cost(entry) where
    cost(while)       = trip * (cost(body) + cost(cond))
    cost(fusion|call) = flops: recurse into called computation;
                        bytes: boundary operands + result, slice/alias-aware
                        (a fusion that only dynamic-slices a stacked-params
                        buffer reads just the slice; a fusion whose root
                        updates an accumulator in place touches only the
                        update bytes — XLA aliases both patterns)
    cost(dot)         = flops: 2 * prod(result) * prod(contracted dims)
                        bytes: operands + result
    cost(collective)  = operand bytes by op class (+ ring link-bytes model)
    cost(elementwise) = flops: ~1/element; bytes: operands + result

Shapes in the compiled module are per-partition (post-GSPMD), so every
number reported here is PER DEVICE per step. `HloAnalyzer.hotspots()` is
the dry-run "profile" that the §Perf hypothesis loop reads.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
}

# one instruction:  %name = TYPE opcode(operands), attrs
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\((.*?)\)\s*->")
_TRIP_RE = re.compile(r'known_trip_count.{0,4}:.{0,4}n.{0,4}:.{0,3}"(\d+)"')
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_OPERAND_NAME_RE = re.compile(r"%([\w.\-]+)")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9, ]+?)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# opcodes that move no bytes / do no work.
# "convert" is free by design: the CPU backend has no native bf16, so XLA
# legalizes every bf16 dot/fusion by inserting f32 convert round-trips (it
# even keeps whole while-loop carries in f32). On the TPU TARGET none of
# those converts exist (bf16 is a native MXU/VPU type) and genuine dtype
# casts fuse into their consumers. Operand byte accounting resolves THROUGH
# convert chains to the source dtype, so values are costed at their true
# (TPU) width.
_FREE = {"parameter", "constant", "tuple", "get-tuple-element", "bitcast",
         "convert", "after-all", "partition-id", "replica-id", "iota",
         "rng-bit-generator", "add-dependency", "domain", "opt-barrier"}

_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                   "sine", "cosine", "logistic", "expm1", "log1p", "atan2",
                   "cbrt", "erf", "exponential-minus-one"}

_MOVE_OPS = {"copy", "copy-start", "copy-done", "transpose", "reshape",
             "concatenate", "pad", "reverse", "sort", "reduce",
             "reduce-window", "select-and-scatter", "map", "cholesky",
             "triangular-solve", "custom-call", "convert", "scatter"}


def _shape_of(type_str: str):
    """All dtype[dims] groups in a type string -> [(dtype, [dims]), ...]."""
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        d = [int(x) for x in dims.split(",")] if dims else []
        out.append((dt, d))
    return out


def _nbytes(shapes) -> int:
    total = 0
    for dt, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _nelems(shapes) -> int:
    total = 0
    for _, dims in shapes:
        n = 1
        for d in dims:
            n *= d
        total += n
    return total


@dataclass
class Instr:
    name: str
    opcode: str
    result_shapes: list            # [(dtype, dims), ...]
    operand_names: list[str]
    line: str


@dataclass
class Cost:
    flops: float = 0.0
    transcendentals: float = 0.0
    hbm_bytes: float = 0.0
    coll: dict = field(default_factory=lambda: {c: 0.0 for c in COLLECTIVES})
    link_bytes: float = 0.0

    def add(self, other: "Cost", mult: float = 1.0):
        self.flops += other.flops * mult
        self.transcendentals += other.transcendentals * mult
        self.hbm_bytes += other.hbm_bytes * mult
        for c in COLLECTIVES:
            self.coll[c] += other.coll[c] * mult
        self.link_bytes += other.link_bytes * mult

    @property
    def collective_bytes(self) -> float:
        return sum(self.coll.values())

    def as_dict(self) -> dict:
        d = {"flops": self.flops, "transcendentals": self.transcendentals,
             "hbm_bytes": self.hbm_bytes, "link_bytes": self.link_bytes}
        d["collectives"] = {k: v for k, v in self.coll.items()}
        d["collective_bytes"] = self.collective_bytes
        return d


class HloAnalyzer:
    def __init__(self, hlo_text: str):
        self.computations: dict[str, list[Instr]] = {}
        self.entry: str | None = None
        self._cost_cache: dict[tuple[str, bool], Cost] = {}
        self._promo_cache: dict[str, bool] = {}
        self._parse(hlo_text)

    # ------------------------------------------------------------------ parse
    def _parse(self, text: str):
        cur: list[Instr] | None = None
        for raw in text.splitlines():
            line = raw.rstrip()
            if not line:
                continue
            hdr = _COMP_HDR_RE.match(line)
            if hdr and line.endswith("{"):
                cur = []
                self.computations[hdr.group(1)] = cur
                if line.startswith("ENTRY"):
                    self.entry = hdr.group(1)
                continue
            if line.startswith("}"):
                cur = None
                continue
            if cur is None:
                continue
            m = _INSTR_RE.match(line)
            if not m:
                continue
            name, type_str, opcode, rest = m.groups()
            depth, end = 1, len(rest)
            for i, ch in enumerate(rest):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            ops = _OPERAND_NAME_RE.findall(rest[:end])
            cur.append(Instr(name=name, opcode=opcode,
                             result_shapes=_shape_of(type_str),
                             operand_names=ops, line=line))

    # ------------------------------------------------------------- dot flops
    def _dot_flops(self, instr: Instr, symtab: dict) -> float:
        out_elems = _nelems(instr.result_shapes)
        m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
        lhs = symtab.get(instr.operand_names[0]) \
            if instr.operand_names else None
        if not m or lhs is None:
            return 2.0 * out_elems
        cdims = [int(x) for x in m.group(1).split(",") if x]
        _, ldims = lhs[0]
        k = 1
        for c in cdims:
            if c < len(ldims):
                k *= ldims[c]
        return 2.0 * out_elems * k

    # -------------------------------------------------------- per-instruction
    def _instr_cost(self, ins: Instr, symtab: dict, *,
                    inside_fusion: bool = False) -> Cost:
        total = Cost()
        op = ins.opcode
        if op in _FREE or op == "while":
            return total              # while handled by caller (multiplicity)
        res_b = _nbytes(ins.result_shapes)
        opd_b = sum(_nbytes(symtab[o]) for o in ins.operand_names
                    if o in symtab)
        io_b = 0.0 if inside_fusion else float(res_b + opd_b)

        if op in ("fusion", "call", "async-start"):
            mc = _CALLS_RE.search(ins.line)
            if mc:
                if ins.opcode == "fusion" and \
                        self._is_promotion_fusion(mc.group(1)):
                    return total               # CPU bf16-emulation artifact
                inner = self.cost_of(mc.group(1), inside_fusion=True)
                total.add(inner)
                if not inside_fusion:
                    pb, out_override = self._fusion_param_bytes(
                        mc.group(1), ins, symtab)
                    out_b = res_b if out_override is None else out_override
                    total.hbm_bytes += out_b + pb
            return total
        if op == "conditional":
            branches = re.search(r"branch_computations=\{([^}]*)\}", ins.line)
            if branches:
                names = _OPERAND_NAME_RE.findall(branches.group(1))
                costs = [self.cost_of(n) for n in names]
                if costs:
                    total.add(max(costs, key=lambda c: c.flops + c.hbm_bytes))
            total.hbm_bytes += io_b
            return total
        if op in COLLECTIVES:
            # size from RESOLVED operand bytes (symtab follows convert
            # chains to the source dtype): a bf16 tensor the CPU backend
            # promoted to f32 still moves bf16 on the TPU target.
            g = self._group_size(ins.line)
            base = float(opd_b or res_b)
            if op == "all-gather":
                operand = base
                link = base * (g - 1)
            elif op == "reduce-scatter":
                operand = base
                link = base * (g - 1) / max(g, 1)
            elif op == "all-reduce":
                operand = base
                link = 2.0 * base * (g - 1) / max(g, 1)
            else:                      # all-to-all, collective-permute
                operand = base
                link = base
            total.coll[op] += operand
            total.link_bytes += link
            total.hbm_bytes += io_b
            return total
        if op in ("dot", "convolution"):
            total.flops += self._dot_flops(ins, symtab)
            total.hbm_bytes += io_b
            return total
        # aliasing / partial-touch data movement:
        if op in ("slice", "dynamic-slice"):
            if not inside_fusion:
                total.hbm_bytes += 2.0 * res_b
            return total
        if op == "dynamic-update-slice":
            if not inside_fusion:
                upd = (ins.operand_names[1]
                       if len(ins.operand_names) > 1 else None)
                upd_b = _nbytes(symtab.get(upd, [])) if upd else res_b
                total.hbm_bytes += 2.0 * upd_b
            return total
        if op == "gather":
            if not inside_fusion:
                total.hbm_bytes += 2.0 * res_b
            return total
        if op == "broadcast":
            if not inside_fusion:
                total.hbm_bytes += res_b + opd_b
            return total
        if op in _MOVE_OPS:
            if op in ("reduce", "map", "sort", "scatter"):
                total.flops += _nelems(
                    [symtab[o][0] for o in ins.operand_names
                     if o in symtab and symtab[o]])
            total.hbm_bytes += io_b
            return total
        # elementwise and everything else: 1 flop per output element
        ne = _nelems(ins.result_shapes)
        total.flops += ne
        if op in _TRANSCENDENTAL:
            total.transcendentals += ne
        total.hbm_bytes += io_b
        return total

    def _is_promotion_fusion(self, comp_name: str) -> bool:
        """True when a fused computation only re-types/reshapes data
        (convert/bitcast/reshape/copy/slice-of-full): a CPU bf16-emulation
        artifact with no TPU counterpart. Costed as free."""
        if comp_name in self._promo_cache:
            return self._promo_cache[comp_name]
        ok = True
        for i in self.computations.get(comp_name, []):
            if i.opcode in ("parameter", "constant", "tuple",
                            "get-tuple-element", "bitcast", "convert",
                            "reshape", "copy", "broadcast"):
                continue
            ok = False
            break
        self._promo_cache[comp_name] = ok
        return ok

    def _resolved_symtab(self, instrs) -> dict:
        """name -> result_shapes, with convert/bitcast chains (incl.
        convert-only fusions) resolved to their source so operands are
        costed at source (TPU) width."""
        symtab = {i.name: i.result_shapes for i in instrs}
        alias = {}
        for i in instrs:
            if i.opcode in ("convert", "bitcast") and i.operand_names:
                alias[i.name] = i.operand_names[0]
            elif i.opcode == "fusion" and i.operand_names:
                mc = _CALLS_RE.search(i.line)
                if mc and self._is_promotion_fusion(mc.group(1)):
                    alias[i.name] = i.operand_names[0]
        out = {}
        for name, shapes in symtab.items():
            cur, hops = name, 0
            while cur in alias and hops < 20:
                cur = alias[cur]
                hops += 1
            out[name] = symtab.get(cur, shapes)
        return out

    # ------------------------------------------------------------- cost walk
    def cost_of(self, comp_name: str, *, inside_fusion: bool = False) -> Cost:
        key = (comp_name, inside_fusion)
        if key in self._cost_cache:
            return self._cost_cache[key]
        total = Cost()
        instrs = self.computations.get(comp_name, [])
        symtab = self._resolved_symtab(instrs)
        for ins in instrs:
            if ins.opcode == "while":
                trip = 1
                mt = _TRIP_RE.search(ins.line)
                if mt:
                    trip = int(mt.group(1))
                body = _BODY_RE.search(ins.line)
                cond = _COND_RE.search(ins.line)
                if body:
                    total.add(self.cost_of(body.group(1)), trip)
                if cond:
                    total.add(self.cost_of(cond.group(1)), trip)
                continue
            total.add(self._instr_cost(ins, symtab,
                                       inside_fusion=inside_fusion))
        self._cost_cache[key] = total
        return total

    def _fusion_param_bytes(self, comp_name: str, call: Instr,
                            caller_symtab: dict) -> float:
        """Bytes a fusion actually reads from each boundary operand.

        Follows bitcast/reshape/copy aliases transitively. If every terminal
        use of parameter(i) is a (dynamic-)slice, only the slice bytes leave
        HBM; if a use is a dynamic-update-slice whose target aliases the
        param (in-place accumulator), only ~the update bytes are touched —
        and when that DUS is the fusion ROOT, the fusion *output* is aliased
        to the input too, so the returned out_override replaces the result
        bytes with the update bytes.

        Returns (param_read_bytes, out_bytes_override | None).
        """
        instrs = self.computations.get(comp_name, [])
        symtab = {i.name: i.result_shapes for i in instrs}
        params: dict[int, str] = {}
        for i in instrs:
            if i.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", i.line)
                if m:
                    params[int(m.group(1))] = i.name
        root = None
        for i in instrs:
            if "ROOT" in i.line.split("=")[0]:
                root = i
        # unwrap convert/bitcast roots: ROOT convert(DUS(...)) is still an
        # in-place update on the TPU target
        by_name = {i.name: i for i in instrs}
        seen_root = set()
        while (root is not None
               and root.opcode in ("convert", "bitcast", "reshape", "copy")
               and root.operand_names
               and root.name not in seen_root):
            seen_root.add(root.name)
            nxt = by_name.get(root.operand_names[0])
            if nxt is None:
                break
            root = nxt
        total = 0.0
        out_override = None
        for idx, pname in params.items():
            if idx >= len(call.operand_names):
                continue
            full = float(_nbytes(caller_symtab.get(call.operand_names[idx],
                                                   [])))
            alias = {pname}
            changed = True
            # "convert" is transparent here: XLA CPU emulates bf16 by
            # promoting fusion internals to f32 (convert(param) wrappers
            # around slice/update chains); on the TPU target those converts
            # do not exist, so they must not break in-place detection.
            _transparent = ("bitcast", "reshape", "copy", "convert")
            while changed:
                changed = False
                for i in instrs:
                    if (i.opcode in _transparent
                            and i.name not in alias
                            and any(o in alias for o in i.operand_names)):
                        alias.add(i.name)
                        changed = True
            per_use = 0.0
            sliced_only = True
            for u in instrs:
                if u.opcode in _transparent:
                    continue
                if not any(o in alias for o in u.operand_names):
                    continue
                if u.opcode in ("slice", "dynamic-slice"):
                    per_use = max(per_use, float(_nbytes(u.result_shapes)))
                elif (u.opcode == "dynamic-update-slice"
                      and u.operand_names and u.operand_names[0] in alias):
                    upd = (_nbytes(symtab.get(u.operand_names[1], []))
                           if len(u.operand_names) > 1 else 0)
                    per_use = max(per_use, float(upd))
                    if root is not None and u.name == root.name:
                        # in-place accumulator: output aliases this param
                        out_override = float(upd)
                else:
                    sliced_only = False
                    break
            total += per_use if sliced_only else full
        return total, out_override

    @staticmethod
    def _group_size(line: str) -> int:
        m = _GROUPS_RE.search(line)
        if m:
            return max(1, int(m.group(2)))
        m = _GROUPS_LIST_RE.search(line)
        if m:
            return max(1, len(m.group(1).split(",")))
        return 1

    def entry_cost(self) -> Cost:
        if self.entry is None:
            return Cost()
        return self.cost_of(self.entry)

    # ------------------------------------------------------------- hotspots
    def multiplicities(self) -> dict[str, float]:
        """Execution count of each computation (trip counts down the graph)."""
        mult: dict[str, float] = {self.entry: 1.0}
        changed = True
        for _ in range(30):            # call graph is shallow; iterate to fix
            if not changed:
                break
            changed = False
            for cn, instrs in self.computations.items():
                m = mult.get(cn)
                if m is None:
                    continue
                for ins in instrs:
                    trip = 1
                    mt = _TRIP_RE.search(ins.line)
                    if mt:
                        trip = int(mt.group(1))
                    for pat in (_CALLS_RE, _BODY_RE, _COND_RE):
                        mm = pat.search(ins.line)
                        if mm:
                            sub = mm.group(1)
                            new = m * trip
                            if mult.get(sub, 0.0) < new:
                                mult[sub] = new
                                changed = True
        return mult

    def hotspots(self, metric: str = "hbm_bytes", top: int = 20) -> list:
        """Top instructions by metric x multiplicity, using the SAME rules
        as cost_of. Returns [(value, mult, opcode, line_prefix), ...] —
        this is the dry-run 'profile' the §Perf hypothesis loop reads."""
        mult = self.multiplicities()
        # computations reached via calls= are fusion bodies: their
        # instructions are accounted at the CALL site, not individually.
        fusion_comps = set()
        for instrs in self.computations.values():
            for ins in instrs:
                if ins.opcode in ("fusion", "call", "async-start"):
                    mc = _CALLS_RE.search(ins.line)
                    if mc:
                        fusion_comps.add(mc.group(1))
        rows = []
        for cn, instrs in self.computations.items():
            m = mult.get(cn, 0.0)
            if m <= 0 or cn in fusion_comps:
                continue
            symtab = self._resolved_symtab(instrs)
            for ins in instrs:
                if ins.opcode == "while":
                    continue
                c = self._instr_cost(ins, symtab, inside_fusion=False)
                v = (c.collective_bytes if metric == "collective_bytes"
                     else getattr(c, metric))
                if v > 0:
                    rows.append((v * m, m, ins.opcode,
                                 ins.line.strip()[:160]))
        rows.sort(key=lambda r: -r[0])
        return rows[:top]


def analyze(hlo_text: str) -> dict:
    """One-call API: per-device {flops, hbm_bytes, collectives, link_bytes}."""
    return HloAnalyzer(hlo_text).entry_cost().as_dict()
