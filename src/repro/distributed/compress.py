"""Pool-level page codecs (memory tiering) + int8 gradient compression.

Two independent codec families live here:

1. **Page codecs** (PR 10, docs/tiering.md) — LOSSLESS, byte-exact codecs
   the `FarPool` applies to COLD pages in place:

   * `encode_word_page` / `decode_word_page`: fixed-width word pages.
     Each column plane of a page is stored either bit-packed
     **int-delta** (u32 wrap-around deltas from a per-(page, column)
     base, `width` bits each) or bit-packed **dictionary** (indices into
     an inline u32 dictionary) — whichever costs fewer bits; a plane
     that compresses to >= 32 bits/value falls back to verbatim 32-bit
     packing, and a PAGE whose total stream would not fit a frame
     returns None (the tier bit says "raw"). Everything operates on the
     u32 BITCAST of the stored f32 words, never on float values, so the
     roundtrip is exact for any bit pattern (NaNs included).

   * `encode_blocks` / `decode_blocks`: length-prefixed block codec for
     byte streams (string pages): per-block `[raw_len][enc_len][mode]`
     headers with RLE or stored payloads and a whole-stream CRC. The
     net tier uses it to ship zero-padded string matrices compactly.

   Both verify a CRC on decode and raise the typed `PageCodecError`
   (a `FarviewError`) instead of ever returning wrong bytes.

2. **Int8 gradient compression** (unchanged, pre-dates tiering): the
   DP-axis traffic reducer with error feedback.

At 1000+ nodes the data-parallel gradient reduction crosses DCN (between
pods), where bandwidth is ~10x scarcer than ICI. Compressing gradients to
int8 with per-tensor scales cuts that traffic 4x (vs f32) / 2x (vs bf16);
the quantization error is fed back into the next step's gradient (error
feedback, 1-bit-Adam style) so convergence is preserved.

Farview connection: this is the same economics as operator push-down —
reduce bytes *before* they cross the slow link.

Implementation note: under GSPMD the all-reduce itself is emitted by XLA,
so we express compression as quantize -> (reduction happens on the int8
domain values re-expressed as f32) -> dequantize around the optimizer;
the roofline accounting in launch/roofline.py reports the collective bytes
either way. The error-feedback residual is part of the train state and is
checkpointed with it.
"""
from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.errors import PageCodecError

# ------------------------------------------------------------ word-page codec
# per-(page, column) plane modes. MODE_RAW marks a whole RAW page in the
# pool's tier descriptors (never appears inside a PagePlan: a plane that
# doesn't compress is stored as width-32 delta, which decodes verbatim).
MODE_RAW = 0
MODE_DELTA = 1
MODE_DICT = 2

_DICT_MAX = 4096        # dictionary entries per plane (keeps dicts tiny)
_U32 = np.uint64(0xFFFFFFFF)


@dataclass
class PagePlan:
    """One compressed logical page: descriptor arrays + the bit stream.

    The descriptors are exactly what the fused device decoder
    (`kernels/tier.py`) consumes as operands; `bitoff`/`dictoff` are
    STREAM-relative here — the pool rebases them to frame-absolute when
    it places the stream inside a cold frame. `crc` covers the stream
    AND the descriptors, so host decode catches any corruption before
    bytes reach a caller."""
    n_words: int            # logical words this page carries
    phase: int              # (page_index * page_words) % n_cols
    modes: np.ndarray       # (C,) int32: MODE_DELTA | MODE_DICT
    widths: np.ndarray      # (C,) int32: bits per packed value (1..32)
    base: np.ndarray        # (C,) uint32: delta base (0 for dict planes)
    dictoff: np.ndarray     # (C,) int32: dict word offset in stream (-1: none)
    bitoff: np.ndarray      # (C,) int32: packed plane's bit offset in stream
    dictlen: np.ndarray     # (C,) int32: dict words per plane (0: no dict)
    stream: np.ndarray      # (m,) uint32: dicts + packed planes (+1 slack)
    crc: int = 0

    @property
    def stream_words(self) -> int:
        return int(self.stream.shape[0])

    def plane_counts(self, n_cols: int) -> np.ndarray:
        """(C,) values per column plane (how many words of each column
        this page holds, given its phase)."""
        k = np.arange(self.n_words, dtype=np.int64)
        cols = (self.phase + k) % n_cols
        return np.bincount(cols, minlength=n_cols).astype(np.int64)

    def descriptor_crc_payload(self) -> bytes:
        return b"".join([
            struct.pack("<iiii", self.n_words, self.phase, 0, 0),
            self.modes.astype(np.int32).tobytes(),
            self.widths.astype(np.int32).tobytes(),
            self.base.astype(np.uint32).tobytes(),
            self.dictoff.astype(np.int32).tobytes(),
            self.bitoff.astype(np.int32).tobytes(),
            self.dictlen.astype(np.int32).tobytes()])

    def seal(self) -> "PagePlan":
        self.crc = zlib.crc32(self.descriptor_crc_payload()
                              + self.stream.tobytes()) & 0xFFFFFFFF
        return self


def _pack_bits(stream: np.ndarray, vals: np.ndarray, width: int,
               bit0: int) -> None:
    """OR `vals` (u32, `width` bits each) into `stream` starting at bit
    `bit0`. Contributions are bit-disjoint, so bitwise_or.at accumulates
    exactly even when adjacent values share a word."""
    if vals.size == 0:
        return
    pos = bit0 + np.arange(vals.size, dtype=np.int64) * width
    wi = pos >> 5
    sh = (pos & 31).astype(np.uint64)
    big = vals.astype(np.uint64) << sh
    np.bitwise_or.at(stream, wi, (big & _U32).astype(np.uint32))
    np.bitwise_or.at(stream, wi + 1, (big >> np.uint64(32)).astype(np.uint32))


def _unpack_bits(stream: np.ndarray, n: int, width: int,
                 bit0: int) -> np.ndarray:
    """Inverse of `_pack_bits`: n values of `width` bits from `bit0`."""
    if n == 0:
        return np.zeros((0,), np.uint32)
    pos = bit0 + np.arange(n, dtype=np.int64) * width
    wi = pos >> 5
    if int(wi[-1]) + 1 >= stream.shape[0]:
        raise PageCodecError("compressed plane overruns its stream")
    sh = (pos & 31).astype(np.uint64)
    pair = stream[wi].astype(np.uint64) | (
        stream[wi + 1].astype(np.uint64) << np.uint64(32))
    mask = np.uint64((1 << width) - 1) if width < 64 else ~np.uint64(0)
    return ((pair >> sh) & mask).astype(np.uint32)


def encode_word_page(words: np.ndarray, n_cols: int, *, phase: int = 0,
                     page_words: int | None = None) -> "PagePlan | None":
    """Compress one logical page of u32 words (column-plane bit packing).

    `words`: the page's words as uint32 (bitcast of the pool's f32 —
    callers do `f32.view(np.uint32)`). `phase` is the column of the
    page's FIRST word, `(page_index * page_words) % n_cols`, because a
    row may straddle a page boundary when n_cols doesn't divide the
    page size. Returns None when the page is incompressible — the
    stream (plus one slack word for the decoder's 2-word straddle read)
    would not fit inside `page_words` — in which case the pool keeps
    the page raw and its tier bit says so.
    """
    words = np.ascontiguousarray(words, np.uint32)
    n = int(words.shape[0])
    C = int(n_cols)
    modes = np.zeros((C,), np.int32)
    widths = np.ones((C,), np.int32)
    base = np.zeros((C,), np.uint32)
    dictoff = np.full((C,), -1, np.int32)
    bitoff = np.zeros((C,), np.int32)
    dictlen = np.zeros((C,), np.int32)
    cols = (phase + np.arange(n, dtype=np.int64)) % C

    plane_vals: list = []
    plane_dicts: list = []
    for c in range(C):
        v = words[cols == c]
        if v.size == 0:
            modes[c] = MODE_DELTA
            widths[c] = 1
            plane_vals.append(v)
            plane_dicts.append(None)
            continue
        lo = np.uint64(v.min())
        span = int(np.uint64(v.max()) - lo)
        w_delta = max(1, span.bit_length())
        cost_delta = v.size * min(w_delta, 32)
        uniq = np.unique(v)
        k = int(uniq.size)
        w_dict = max(1, (k - 1).bit_length())
        cost_dict = (k * 32 + v.size * w_dict if k <= _DICT_MAX
                     else cost_delta + 1)
        if cost_dict < cost_delta and cost_dict < v.size * 32:
            modes[c] = MODE_DICT
            widths[c] = w_dict
            idx = np.searchsorted(uniq, v).astype(np.uint32)
            plane_vals.append(idx)
            plane_dicts.append(uniq.astype(np.uint32))
        elif w_delta < 32:
            modes[c] = MODE_DELTA
            widths[c] = w_delta
            base[c] = np.uint32(lo)
            plane_vals.append((v.astype(np.uint64)
                               - lo).astype(np.uint32))
            plane_dicts.append(None)
        else:
            # incompressible plane: verbatim 32-bit packing (still exact)
            modes[c] = MODE_DELTA
            widths[c] = 32
            plane_vals.append(v)
            plane_dicts.append(None)

    dict_words = sum(0 if d is None else d.size for d in plane_dicts)
    bits = 0
    for c in range(C):
        bitoff[c] = dict_words * 32 + bits
        bits += plane_vals[c].size * int(widths[c])
    total_words = dict_words + (bits + 31) // 32 + 1     # +1 slack word
    if page_words is not None and total_words >= page_words:
        return None                             # raw fallback (tier bit)

    stream = np.zeros((total_words,), np.uint32)
    off = 0
    for c in range(C):
        d = plane_dicts[c]
        if d is not None:
            dictoff[c] = off
            dictlen[c] = d.size
            stream[off:off + d.size] = d
            off += d.size
    for c in range(C):
        _pack_bits(stream, plane_vals[c], int(widths[c]), int(bitoff[c]))
    return PagePlan(n, int(phase), modes, widths, base, dictoff, bitoff,
                    dictlen, stream).seal()


def decode_word_page(plan: PagePlan, n_cols: int) -> np.ndarray:
    """Exact inverse of `encode_word_page` -> (n_words,) uint32.

    Verifies the CRC over descriptors + stream first and validates every
    descriptor range, raising `PageCodecError` on any mismatch — a
    corrupted cold page is a typed failure, never wrong bytes."""
    crc = zlib.crc32(plan.descriptor_crc_payload()
                     + np.ascontiguousarray(plan.stream).tobytes()
                     ) & 0xFFFFFFFF
    if crc != plan.crc:
        raise PageCodecError(
            f"compressed page failed CRC (stored {plan.crc:#x}, "
            f"computed {crc:#x})")
    C = int(n_cols)
    counts = plan.plane_counts(C)
    out = np.zeros((plan.n_words,), np.uint32)
    cols = (plan.phase + np.arange(plan.n_words, dtype=np.int64)) % C
    for c in range(C):
        n = int(counts[c])
        w = int(plan.widths[c])
        if not 1 <= w <= 32:
            raise PageCodecError(f"plane {c}: invalid width {w}")
        packed = _unpack_bits(plan.stream, n, w, int(plan.bitoff[c]))
        if plan.modes[c] == MODE_DICT:
            d0 = int(plan.dictoff[c])
            if d0 < 0 or d0 >= plan.stream.shape[0]:
                raise PageCodecError(f"plane {c}: dict offset {d0} "
                                     "outside stream")
            top = int(packed.max()) if n else 0
            if d0 + top >= plan.stream.shape[0]:
                raise PageCodecError(f"plane {c}: dict index {top} "
                                     "outside stream")
            vals = plan.stream[d0 + packed.astype(np.int64)]
        elif plan.modes[c] == MODE_DELTA:
            vals = (packed.astype(np.uint64)
                    + np.uint64(plan.base[c])).astype(np.uint32)
        else:
            raise PageCodecError(f"plane {c}: unknown mode "
                                 f"{int(plan.modes[c])}")
        out[cols == c] = vals
    return out


# ------------------------------------------------------- byte-block codec
_BLOCK_MAGIC = b"FVB1"
_BLOCK = 4096           # raw bytes per block (fits the u16 length prefix)


def _run_lengths(chunk: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    edges = np.flatnonzero(chunk[1:] != chunk[:-1]) + 1
    starts = np.concatenate([[0], edges])
    ends = np.concatenate([edges, [chunk.size]])
    return starts, ends - starts


def _rle_size(chunk: np.ndarray) -> int:
    """Exact encoded size of `_rle_encode(chunk)` WITHOUT materializing it
    (vectorized) — so incompressible blocks never pay the encode loop."""
    if chunk.size == 0:
        return 0
    _, runs = _run_lengths(chunk)
    return int(2 * (runs.size + np.sum((runs - 1) // 255)))


def _rle_encode(chunk: np.ndarray) -> bytes:
    """(count u8, byte) run pairs; runs longer than 255 split."""
    if chunk.size == 0:
        return b""
    starts, runs = _run_lengths(chunk)
    out = bytearray()
    for s, run in zip(starts, runs):
        b = int(chunk[s])
        run = int(run)
        while run > 0:
            take = min(run, 255)
            out.append(take)
            out.append(b)
            run -= take
    return bytes(out)


def _rle_decode(payload: bytes, raw_len: int) -> bytes:
    if len(payload) % 2:
        raise PageCodecError("RLE payload has a dangling half-pair")
    out = bytearray()
    for i in range(0, len(payload), 2):
        out.extend(payload[i + 1:i + 2] * payload[i])
    if len(out) != raw_len:
        raise PageCodecError(
            f"RLE block decoded to {len(out)} bytes, header says {raw_len}")
    return bytes(out)


def _zstrip_encode(chunk: np.ndarray) -> bytes:
    """Zero-strip: a presence bitmap + the nonzero bytes. Targets exactly
    the shape of padded string pages (text runs + zero padding), and both
    directions are fully vectorized."""
    nz = chunk != 0
    return np.packbits(nz).tobytes() + chunk[nz].tobytes()


def _zstrip_decode(payload: bytes, raw_len: int) -> bytes:
    head = (raw_len + 7) // 8
    if len(payload) < head:
        raise PageCodecError("zero-strip block shorter than its bitmap")
    mask = np.unpackbits(
        np.frombuffer(payload[:head], np.uint8))[:raw_len].astype(bool)
    vals = np.frombuffer(payload[head:], np.uint8)
    if vals.size != int(mask.sum()):
        raise PageCodecError(
            f"zero-strip block carries {vals.size} bytes, bitmap wants "
            f"{int(mask.sum())}")
    out = np.zeros((raw_len,), np.uint8)
    out[mask] = vals
    return out.tobytes()


def encode_blocks(data: bytes, *, block: int = _BLOCK) -> bytes:
    """Length-prefixed block codec for byte pages (string tables, padded
    string matrices on the wire): per block `[raw_len u16][enc_len u16]
    [mode u8]` + payload — mode 1 = RLE run pairs, mode 2 = zero-strip
    (presence bitmap + nonzero bytes), mode 0 = stored, whichever is
    smallest — framed by a magic + total length header and a whole-stream
    CRC trailer."""
    if not 1 <= block <= 0xFFFF:
        raise ValueError("block size must fit the u16 length prefix")
    arr = np.frombuffer(bytes(data), np.uint8)
    out = [_BLOCK_MAGIC, struct.pack("<I", arr.size)]
    for s in range(0, arr.size, block):
        chunk = arr[s:s + block]
        rle_n = _rle_size(chunk)
        zs_n = (chunk.size + 7) // 8 + int(np.count_nonzero(chunk))
        best = min(chunk.size, rle_n, zs_n)
        if best == rle_n and rle_n < chunk.size:
            out.append(struct.pack("<HHB", chunk.size, rle_n, 1))
            out.append(_rle_encode(chunk))
        elif best == zs_n and zs_n < chunk.size:
            payload = _zstrip_encode(chunk)
            out.append(struct.pack("<HHB", chunk.size, len(payload), 2))
            out.append(payload)
        else:
            out.append(struct.pack("<HHB", chunk.size, chunk.size, 0))
            out.append(chunk.tobytes())
    out.append(struct.pack("<I", zlib.crc32(bytes(data)) & 0xFFFFFFFF))
    return b"".join(out)


def decode_blocks(buf: bytes) -> bytes:
    """Exact inverse of `encode_blocks`; `PageCodecError` on any framing
    or checksum mismatch."""
    buf = bytes(buf)
    if len(buf) < 12 or buf[:4] != _BLOCK_MAGIC:
        raise PageCodecError("block stream: bad magic")
    (total,) = struct.unpack_from("<I", buf, 4)
    pos, out = 8, bytearray()
    while len(out) < total:
        if pos + 5 > len(buf) - 4:
            raise PageCodecError("block stream truncated mid-header")
        raw_len, enc_len, mode = struct.unpack_from("<HHB", buf, pos)
        pos += 5
        payload = buf[pos:pos + enc_len]
        if len(payload) != enc_len:
            raise PageCodecError("block stream truncated mid-payload")
        pos += enc_len
        if mode == 1:
            out.extend(_rle_decode(payload, raw_len))
        elif mode == 2:
            out.extend(_zstrip_decode(payload, raw_len))
        elif mode == 0:
            if raw_len != enc_len:
                raise PageCodecError("stored block length mismatch")
            out.extend(payload)
        else:
            raise PageCodecError(f"unknown block mode {mode}")
    if len(out) != total:
        raise PageCodecError(
            f"block stream decoded to {len(out)} bytes, header says {total}")
    if pos + 4 > len(buf):
        raise PageCodecError("block stream truncated before CRC trailer")
    (crc,) = struct.unpack_from("<I", buf, pos)
    if zlib.crc32(bytes(out)) & 0xFFFFFFFF != crc:
        raise PageCodecError("block stream failed CRC")
    return bytes(out)


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(g: jnp.ndarray):
    """Symmetric per-tensor int8 quantization. Returns (q_int8, scale)."""
    absmax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, err_state):
    """Error-feedback int8 round trip on every gradient leaf.

    Returns (decompressed grads, new error state). The compressed
    representation is what would cross the DP/DCN links.
    """
    def leaf(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _quantize(gf)
        deq = _dequantize(q, scale)
        return deq, gf - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_e = jax.tree.unflatten(tdef, [o[1] for o in out])
    return new_g, new_e


def compressed_bytes(grads) -> int:
    """Bytes that cross the wire with int8 compression (1B/el + scale)."""
    return sum(int(x.size) + 4 for x in jax.tree.leaves(grads))


def raw_bytes(grads, bytes_per_el: int = 4) -> int:
    return sum(int(x.size) * bytes_per_el for x in jax.tree.leaves(grads))
