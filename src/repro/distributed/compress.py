"""Int8 gradient compression with error feedback (DP-axis traffic reducer).

At 1000+ nodes the data-parallel gradient reduction crosses DCN (between
pods), where bandwidth is ~10x scarcer than ICI. Compressing gradients to
int8 with per-tensor scales cuts that traffic 4x (vs f32) / 2x (vs bf16);
the quantization error is fed back into the next step's gradient (error
feedback, 1-bit-Adam style) so convergence is preserved.

Farview connection: this is the same economics as operator push-down —
reduce bytes *before* they cross the slow link.

Implementation note: under GSPMD the all-reduce itself is emitted by XLA,
so we express compression as quantize -> (reduction happens on the int8
domain values re-expressed as f32) -> dequantize around the optimizer;
the roofline accounting in launch/roofline.py reports the collective bytes
either way. The error-feedback residual is part of the train state and is
checkpointed with it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _quantize(g: jnp.ndarray):
    """Symmetric per-tensor int8 quantization. Returns (q_int8, scale)."""
    absmax = jnp.max(jnp.abs(g))
    scale = jnp.maximum(absmax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, err_state):
    """Error-feedback int8 round trip on every gradient leaf.

    Returns (decompressed grads, new error state). The compressed
    representation is what would cross the DP/DCN links.
    """
    def leaf(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _quantize(gf)
        deq = _dequantize(q, scale)
        return deq, gf - deq

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err_state)
    out = [leaf(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_e = jax.tree.unflatten(tdef, [o[1] for o in out])
    return new_g, new_e


def compressed_bytes(grads) -> int:
    """Bytes that cross the wire with int8 compression (1B/el + scale)."""
    return sum(int(x.size) + 4 for x in jax.tree.leaves(grads))


def raw_bytes(grads, bytes_per_el: int = 4) -> int:
    return sum(int(x.size) * bytes_per_el for x in jax.tree.leaves(grads))
