"""Sharding rules: param/opt/batch/cache PartitionSpecs per architecture.

The mesh is (data, model) single-pod or (pod, data, model) multi-pod
(launch/mesh.py). Roles:

  * batch / FSDP axis: ("pod",)+("data",) — data parallel batch sharding AND
    ZeRO-style parameter+optimizer sharding (the *other* dim of each weight).
  * "model" axis: tensor parallelism (heads / d_ff / experts / vocab) AND the
    disaggregated-pool axis (far-KV sequence shards, Farview table striping).

All rules are *divisibility-checked*: if a dim doesn't divide the axis size
the axis is dropped (replicated) rather than failing — this is what lets the
same rule table serve 10 architectures x reduced smoke configs x 4-device
test meshes without special cases.

Layout conventions (matching models/):
  stacked group weights carry a leading G axis (never sharded);
  "up" projections  (d_in -> big): shard in over FSDP, out over model;
  "down" projections (big -> d_out): shard in over model, out over FSDP;
  experts (E, d, f): E over model (expert parallelism), d over FSDP;
  embeddings (V, d): V over model, d over FSDP;
  norms / biases / scalars: FSDP on the last dim when divisible.
"""
from __future__ import annotations

import re
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, Shape

# weight-name classes ---------------------------------------------------------
_UP_NAMES = {
    "wq", "wk", "wv",                     # attention in-projections
    "w_gate", "w_up",                     # GLU MLP
    "w_in",                               # mamba2 fused in-proj
    "w_q", "w_k", "w_v",                  # mlstm projections (square di x di)
    "w_i", "w_f", "w_z",                  # gate projections
    "skip",                               # mlstm learnable skip (di x di)
}
_DOWN_NAMES = {"wo", "w_down", "w_out", "w_o"}
_VEC_NAMES = {"w", "a_log", "dt_bias", "d_skip", "b", "scale"}


def dp_axes_of(mesh: Mesh) -> tuple[str, ...]:
    """FSDP/batch axes = every mesh axis that isn't 'model'."""
    return tuple(a for a in mesh.axis_names if a != "model")


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return True
    if isinstance(axes, str):
        axes = (axes,)
    total = 1
    for a in axes:
        total *= mesh.shape[a]
    return dim % total == 0 and dim >= total


def _maybe(dim: int, mesh: Mesh, axes):
    """Axes if divisible else None (replicate)."""
    if axes is None:
        return None
    if isinstance(axes, str):
        axes = (axes,)
    # progressively drop trailing axes until it fits
    for k in range(len(axes), 0, -1):
        sub = axes[:k]
        if _fits(dim, mesh, sub):
            return sub if len(sub) > 1 else sub[0]
    return None


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_spec(path: str, shape: tuple[int, ...], mesh: Mesh,
               cfg: ModelConfig | None = None) -> P:
    """PartitionSpec for one parameter leaf, by path + shape."""
    dp = dp_axes_of(mesh)
    name = path.split("/")[-1]
    stacked = path.startswith("groups/")
    lead = (None,) if stacked else ()
    body = shape[1:] if stacked else shape

    # --- embeddings / head --------------------------------------------------
    if name == "table":                                   # (V, d)
        v, d = body
        return P(*lead, _maybe(v, mesh, "model"), _maybe(d, mesh, dp))
    if "head" in path and name == "w" and len(body) == 2:  # (d, V)
        d, v = body
        return P(*lead, _maybe(d, mesh, dp), _maybe(v, mesh, "model"))

    # --- MoE expert banks (E, d, f) / (E, f, d) ------------------------------
    if "moe" in path and name in ("w_gate", "w_up") and len(body) == 3:
        e, d, f = body
        return P(*lead, _maybe(e, mesh, "model"), _maybe(d, mesh, dp), None)
    if "moe" in path and name == "w_down" and len(body) == 3:
        e, f, d = body
        return P(*lead, _maybe(e, mesh, "model"), _maybe(f, mesh, dp), None)
    if name == "router":                                  # (d, E)
        d, e = body
        return P(*lead, _maybe(d, mesh, dp), None)

    # --- sLSTM per-head recurrent blocks (H, dh, dh) --------------------------
    if re.fullmatch(r"r_[ifzo]", name) and len(body) == 3:
        h = body[0]
        return P(*lead, _maybe(h, mesh, "model"), None, None)

    # --- generic matmuls ------------------------------------------------------
    if len(body) == 2:
        d_in, d_out = body
        if name in _DOWN_NAMES:
            return P(*lead, _maybe(d_in, mesh, "model"),
                     _maybe(d_out, mesh, dp))
        if name in _UP_NAMES or name == "w_o" or len(body) == 2:
            # default: treat as up-projection
            return P(*lead, _maybe(d_in, mesh, dp),
                     _maybe(d_out, mesh, "model"))

    # --- vectors / scalars ----------------------------------------------------
    if len(body) == 1:
        return P(*lead, _maybe(body[0], mesh, dp))
    if len(body) == 0:
        return P(*lead)
    # fallback: shard last dim over dp if possible
    spec = [None] * len(body)
    spec[-1] = _maybe(body[-1], mesh, dp)
    return P(*lead, *spec)


def param_specs(params_shapes, mesh: Mesh,
                cfg: ModelConfig | None = None):
    """Pytree of PartitionSpec mirroring a params (or ShapeDtypeStruct) tree."""
    def leaf_spec(path, leaf):
        return param_spec(_path_str(path), tuple(leaf.shape), mesh, cfg)
    return jax.tree_util.tree_map_with_path(leaf_spec, params_shapes)


def opt_specs(opt_shapes, pspecs):
    """Optimizer state shardings mirror the params (step replicated)."""
    return {"m": pspecs, "v": pspecs, "step": P()}


# --------------------------------------------------------------------------- #
# activations / batch / cache
# --------------------------------------------------------------------------- #
def batch_axes(mesh: Mesh, global_batch: int) -> tuple[str, ...] | None:
    """Batch-sharding axes: as many dp axes as the batch divides."""
    dp = dp_axes_of(mesh)
    got = _maybe(global_batch, mesh, dp)
    if got is None:
        return None
    return (got,) if isinstance(got, str) else tuple(got)


def batch_specs(cfg: ModelConfig, shape: Shape, mesh: Mesh) -> dict:
    """PartitionSpecs for one step's data batch."""
    dp = batch_axes(mesh, shape.global_batch)
    bs = dp if dp else None
    specs: dict[str, P] = {}
    kind = shape.kind
    seq_axis = None
    if kind in ("train", "prefill"):
        seq_axis = _maybe(shape.seq_len, mesh, "model")
    if cfg.embed_input:
        specs["embeds"] = P(bs, seq_axis if kind != "decode" else None, None)
    else:
        specs["tokens"] = P(bs, seq_axis)
    if cfg.n_image_tokens and kind != "decode":
        # decode reads image KV from the prefilled cross-attn cache instead
        specs["image_embeds"] = P(bs, None, None)
    if kind == "train":
        specs["labels"] = P(bs, seq_axis)
    return specs


def cache_specs(cache_shapes, mesh: Mesh, global_batch: int) -> Any:
    """Decode-cache shardings.

    Attention KV leaves (G, B, S, H, D): batch over dp, sequence over "model"
    (the far pool axis). Recurrent-state leaves: batch over dp, heads over
    "model" when divisible.
    """
    dp = batch_axes(mesh, global_batch)
    bs = dp if dp else None

    def leaf(path, sds):
        shp = tuple(sds.shape)
        name = _path_str(path).split("/")[-1]
        if name.startswith(("k_", "v_")) and len(shp) == 5:
            # (G, B, Hkv, S, D) pre-transposed layout: S (dim 3) is the
            # far-pool axis
            g, b, h, s, d = shp
            return P(None, bs, None, _maybe(s, mesh, "model"), None)
        if name.startswith("ssm_") and len(shp) == 5:
            g, b, h, n, pdim = shp
            return P(None, bs, _maybe(h, mesh, "model"), None, None)
        if name.startswith("C_") and len(shp) == 5:      # mlstm (G,B,H,dh,dh)
            g, b, h, d1, d2 = shp
            return P(None, bs, _maybe(h, mesh, "model"), None, None)
        if len(shp) >= 2:
            return P(None, bs, *([None] * (len(shp) - 2)))
        return P(None)

    return jax.tree_util.tree_map_with_path(leaf, cache_shapes)


def activation_spec(mesh: Mesh, global_batch: int, *,
                    seq_sharded: bool = True) -> P:
    """Residual-stream constraint (B, S, d): batch over dp, seq over model
    (Megatron-style sequence parallelism for train/prefill)."""
    dp = batch_axes(mesh, global_batch)
    return P(dp if dp else None, "model" if seq_sharded else None, None)


def constrain(x, spec: P):
    """with_sharding_constraint that is a no-op outside jit/mesh context."""
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


# --------------------------------------------------------------------------- #
# Farview pool partitioning (multi-node FarCluster, paper's scale-out)
# --------------------------------------------------------------------------- #
_HASH_MULT = np.uint64(0x9E3779B1)      # Fibonacci hashing (same family as
#                                         the pool's group-bucket hash)


def _hash_keys(keys: np.ndarray) -> np.ndarray:
    """Fibonacci hash of a key column -> uint64 (shared by every
    key-partitioner so two tables hashing the same key values always agree
    on the owner — the invariant co-partitioned joins rest on)."""
    keys = np.asarray(keys)
    h = (keys.astype(np.int64).view(np.uint64)
         if keys.dtype == np.int64 else
         keys.astype(np.int64).astype(np.uint64))
    return (h * _HASH_MULT) >> np.uint64(13)


class CoPartition:
    """A captured key -> owning-node assignment.

    Built once when a table is key-partitioned (`co_partition_spec`) and
    handed to `partition_rows(..., co_partition=...)` to place a SECOND
    table's rows on the same nodes by key — the locality contract of a
    co-partitioned build-probe join: every build row lives on the node that
    owns the equal-keyed probe rows, so each node joins purely locally and
    the build table is written exactly once cluster-wide (vs the N-copy
    replicated broadcast join).

      hash   owners derive from the shared hash formula — any key, even one
             the original table never held, maps consistently.
      skew   owners come from the greedy placement's key->node table; keys
             unseen by the original table fall back to the hash rule (they
             co-locate with nothing, so placement is free).
    """

    def __init__(self, kind: str, n_parts: int,
                 key_owner: "tuple[np.ndarray, np.ndarray] | None" = None):
        self.kind = kind
        self.n_parts = n_parts
        self._key_owner = key_owner     # (sorted uniq hashes, owners)

    def compatible_with(self, other: "CoPartition | None") -> bool:
        """Whether two tables are co-located BY CONSTRUCTION: only when
        they share this very spec object (the build was allocated with
        co_partition=<that probe>). Two hash specs with equal n_parts
        place equal HASH inputs on the same node, but a spec does not know
        which COLUMN its keys came from — a probe hash-partitioned on a
        non-join column would false-pass a formula comparison and silently
        drop join matches, so structural equality is deliberately NOT
        enough."""
        return other is not None and self is other

    def owners_of(self, keys: np.ndarray) -> np.ndarray:
        h = _hash_keys(keys)
        fallback = (h % np.uint64(self.n_parts)).astype(np.int64)
        if self.kind == "hash" or self._key_owner is None:
            return fallback
        hk, ow = self._key_owner
        if len(hk) == 0:
            return fallback
        pos = np.clip(np.searchsorted(hk, h), 0, len(hk) - 1)
        return np.where(hk[pos] == h, ow[pos], fallback)


def _skew_owner_map(h: np.ndarray, n_parts: int):
    """Greedy LPT placement: key-groups largest-first onto the least-loaded
    node. Returns (sorted uniq hashes, owner per uniq hash, owner per row)."""
    uniq, inv, counts = np.unique(h, return_inverse=True, return_counts=True)
    owner_of_key = np.zeros(len(uniq), np.int64)
    load = np.zeros(n_parts, np.int64)
    for g in np.argsort(-counts, kind="stable"):   # largest group first
        tgt = int(np.argmin(load))
        owner_of_key[g] = tgt
        load[tgt] += counts[g]
    return uniq, owner_of_key, owner_of_key[inv]


def co_partition_spec(kind: str, n_parts: int,
                      keys: "np.ndarray | None") -> "CoPartition | None":
    """The reusable key->node assignment behind a key-partitioned table,
    or None when the partitioning carries no key rule (range, or hash/skew
    over row indices): nothing can co-locate with it."""
    if keys is None or kind not in ("hash", "skew"):
        return None
    if kind == "hash":
        return CoPartition("hash", n_parts)
    uniq, owner_of_key, _ = _skew_owner_map(_hash_keys(keys), n_parts)
    return CoPartition("skew", n_parts, (uniq, owner_of_key))


def partition_rows(n_rows: int, n_parts: int, kind: str = "range", *,
                   keys: "np.ndarray | None" = None,
                   co_partition: "CoPartition | None" = None,
                   ) -> "list[np.ndarray]":
    """Client-side partition map: original row index -> owning pool node.

    Returns one sorted int64 index array per part (some possibly empty).
    Decided once at `alloc_table_mem` time — pure metadata, no node-to-node
    traffic; the cluster's scatter-gather merge uses the same map to splice
    per-node partials back into single-node row order.

      range   contiguous blocks (balanced +-1 row). Order-preserving
              concat; the default.
      hash    Fibonacci hash of the partition key (co-locates equal keys:
              joins and group-bys see all rows of a key on one node).
              Hashes the row index when no keys are given.
      skew    skew-aware: group rows by key, place key-groups largest-first
              onto the currently least-loaded node (greedy LPT). A heavy
              hitter key costs ONE node its group size instead of
              hash-landing several heavy keys together.

    `co_partition=` (a CoPartition from `co_partition_spec`) overrides the
    kind: rows are placed wherever the REFERENCED table's partitioning put
    that key, co-locating the two tables for local build-probe joins.

    The map this returns is the cluster's version-0 placement; online
    rebalancing (`distributed.rebalance` + `FarCluster.rebalance`)
    re-captures it when the key distribution drifts away from what it
    was built for. See docs/cluster.md for the partitioner/rebalance
    lifecycle.
    """
    if n_parts <= 0:
        raise ValueError("n_parts must be positive")
    idx = np.arange(n_rows, dtype=np.int64)
    if co_partition is not None:
        if keys is None:
            raise ValueError("co_partition placement needs keys= (the join "
                             "key value of every row)")
        if co_partition.n_parts != n_parts:
            raise ValueError(
                f"co_partition spans {co_partition.n_parts} nodes, "
                f"requested {n_parts}")
        keys = np.asarray(keys)
        if keys.shape[0] != n_rows:
            raise ValueError(
                f"partition keys cover {keys.shape[0]} rows, "
                f"table has {n_rows}")
        owner = co_partition.owners_of(keys)
        return [idx[owner == p] for p in range(n_parts)]
    if kind == "range" and keys is not None:
        # silently dropping the keys would scatter equal-key rows across
        # nodes while the caller believes they co-locate (join/group-by)
        raise ValueError(
            "partition keys were given but the 'range' partitioner "
            "ignores them — use 'hash' or 'skew' for key co-location")
    if n_parts == 1:
        return [idx]
    if kind == "range":
        return list(np.array_split(idx, n_parts))
    if keys is None:
        if kind == "skew":      # nothing to balance without keys
            return list(np.array_split(idx, n_parts))
        keys = idx
    keys = np.asarray(keys)
    if keys.shape[0] != n_rows:
        raise ValueError(
            f"partition keys cover {keys.shape[0]} rows, table has {n_rows}")
    h = _hash_keys(keys)
    if kind == "hash":
        owner = (h % np.uint64(n_parts)).astype(np.int64)
        return [idx[owner == p] for p in range(n_parts)]
    if kind == "skew":
        _, _, owner = _skew_owner_map(h, n_parts)
        return [idx[owner == p] for p in range(n_parts)]
    raise ValueError(f"unknown partitioner {kind!r} "
                     "(expected range | hash | skew)")
