"""Skew-drift detection and migration planning for `FarCluster` (PR 5).

The cluster's partition map is decided once, at `alloc_table_mem` time,
from the key distribution the table had *then*. When the distribution
shifts — a rekeying rewrite routes most rows to whatever node the stale
key rule assigns them — the paper's "central pool serving many small
processing nodes" degenerates into one hot node: every scatter waits on
the straggler that owns the hot partition. This module is the brain of
the fix; `FarCluster.rebalance` (core/cluster.py) is the muscle.

Three pieces, all pure client-side metadata (numpy only, no node traffic):

  * `TableHeat` — cheap per-`(table, node)` load counters. Rows-touched is
    recorded at scatter time (the partition sizes are already known
    client-side, so this costs an integer add per node — no device sync);
    bytes-shipped is recorded when a gather's partials finalize (the
    merge already materializes those counts). Stored on the catalog's
    `ClusterTable` entries.
  * `detect_drift` — compares the observed per-node load against the
    balanced ideal of the current partition map and reports the
    max/mean imbalance ratio. `ratio > threshold` flags the table.
  * `plan_rebalance` — emits a `MigrationPlan`: the target per-node row
    assignment (skew-aware LPT over the current keys when the table is
    key-partitioned, minimal-move count balancing otherwise), plus the
    concrete `MigrationStep`s — which original-row ids move from which
    node to which, chunked so no step copies more than
    `max_step_bytes` — that `FarCluster.rebalance` executes live.

The planner never touches data: correctness of the scatter-gather merge
depends only on the partition map staying exact, so any target assignment
is *safe*; the plan only decides which one is *fast*. Co-location is the
exception — a key-partitioned table's new placement is captured as a new
`CoPartition` spec so co-partitioned join builds can be re-placed by the
same rule in the same plan (see `FarCluster.rebalance`).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field

import numpy as np

from repro.distributed.sharding import (CoPartition, _hash_keys,
                                        _skew_owner_map, co_partition_spec,
                                        partition_rows)


# --------------------------------------------------------------------- heat
@dataclass
class TableHeat:
    """Per-node load counters for one cluster table.

    `rows_touched[i]` counts rows node `i`'s partition contributed to
    dispatched verbs (recorded at scatter time — pure metadata, no sync);
    `bytes_shipped[i]` counts response bytes node `i` actually shipped
    (recorded when the gather's partials finalize). `requests` counts
    cluster verbs. `reset()` is called after a migration so the detector
    sees post-migration traffic only.

    Thread-safe: `FarCluster.flush` drains nodes from parallel threads,
    and each drain records into the SAME per-table ledger — an unlocked
    `+=` on the numpy counters loses increments under contention, which
    silently skews the drift detector. All counter traffic goes through
    the `record_*` methods, which take `_lock`; readers (`detect_drift`,
    dashboards) snapshot under the same lock."""

    rows_touched: np.ndarray                    # guarded-by: self._lock
    bytes_shipped: np.ndarray                   # guarded-by: self._lock
    requests: int = 0                           # guarded-by: self._lock
    # replication ledger (PR 6): primary vs replica traffic per node.
    # `replica_rows`[i] counts rows node i served AS A REPLICA (failover
    # reads routed around a dead/refusing primary); `replica_bytes_written`
    # [i] counts redundant write traffic node i absorbed for copies it
    # holds of partitions primaried elsewhere — the write-amplification
    # cost of `alloc_table_mem(replicas=k)` made visible per node.
    replica_rows: "np.ndarray | None" = None    # guarded-by: self._lock
    replica_bytes_written: "np.ndarray | None" = None  # guarded-by: self._lock
    failovers: int = 0                          # guarded-by: self._lock
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False, compare=False)

    @classmethod
    def zeros(cls, n_nodes: int) -> "TableHeat":
        return cls(np.zeros(n_nodes, np.int64), np.zeros(n_nodes, np.int64),
                   replica_rows=np.zeros(n_nodes, np.int64),
                   replica_bytes_written=np.zeros(n_nodes, np.int64))

    def record_dispatch(self, node: int, rows: int) -> None:
        with self._lock:
            self.rows_touched[node] += int(rows)

    def record_request(self) -> None:
        """One cluster verb touched this table."""
        with self._lock:
            self.requests += 1

    def record_failover(self, node: int, rows: int) -> None:
        """A replica on `node` served a partition whose primary could not."""
        with self._lock:
            if self.replica_rows is None:
                self.replica_rows = np.zeros_like(self.rows_touched)
            self.replica_rows[node] += int(rows)
            self.failovers += 1

    def record_replica_write(self, node: int, n_bytes: int) -> None:
        with self._lock:
            if self.replica_bytes_written is None:
                self.replica_bytes_written = np.zeros_like(self.rows_touched)
            self.replica_bytes_written[node] += int(n_bytes)

    def record_response(self, node: int, n_bytes: int) -> None:
        with self._lock:
            self.bytes_shipped[node] += int(n_bytes)

    def rows_snapshot(self) -> np.ndarray:
        """A consistent copy of the rows-touched vector for readers."""
        with self._lock:
            return np.asarray(self.rows_touched).copy()

    def reset(self) -> None:
        with self._lock:
            self.rows_touched[:] = 0
            self.bytes_shipped[:] = 0
            self.requests = 0
            if self.replica_rows is not None:
                self.replica_rows[:] = 0
            if self.replica_bytes_written is not None:
                self.replica_bytes_written[:] = 0
            self.failovers = 0


def drift_ratio(loads) -> float:
    """Imbalance of a per-node load vector: hottest node / mean load.

    1.0 is perfectly balanced; k is "everything on one of k nodes". The
    mean is over ALL nodes (idle nodes count — an empty node IS the
    imbalance), so the ratio is exactly the scatter's straggler factor:
    wall time of the slowest node over the balanced ideal."""
    loads = np.asarray(loads, np.float64)
    if loads.size == 0 or loads.sum() <= 0:
        return 1.0
    return float(loads.max() / loads.mean())


@dataclass
class DriftReport:
    """Verdict of `detect_drift` for one table. `ratio` is the observed
    straggler share divided by the best ACHIEVABLE share — 1.0 means the
    current map is as good as a fresh re-placement could be, even when
    the raw sizes are lopsided (a 60%-heavy key group cannot be split)."""
    table: str
    ratio: float                # observed / achievable straggler share
    loads: np.ndarray           # the per-node load vector the ratio is from
    threshold: float
    achievable_share: float = 0.0   # best max-node share a re-place can hit

    @property
    def drifted(self) -> bool:
        return self.ratio > self.threshold


def achievable_share(n_nodes: int, keys: "np.ndarray | None") -> float:
    """The smallest max-node load share any re-placement can reach.

    Without a key rule any row can move anywhere: 1/k. With keys, key
    groups must stay whole (co-location), so the floor is what the greedy
    LPT placement itself achieves over the current key frequencies — the
    same target `plan_rebalance` would emit. Judging drift against THIS,
    not against perfect balance, is what stops the detector from flagging
    an inherently skewed but already-optimal placement forever."""
    if n_nodes <= 0:
        return 1.0
    if keys is None or len(np.asarray(keys)) == 0:
        return 1.0 / n_nodes
    _, _, owner = _skew_owner_map(_hash_keys(np.asarray(keys)), n_nodes)
    sizes = np.bincount(owner, minlength=n_nodes)
    return float(sizes.max() / max(1, sizes.sum()))


def detect_drift(table: str, heat: TableHeat, part_sizes, *,
                 keys: "np.ndarray | None" = None,
                 threshold: float = 1.5) -> DriftReport:
    """Compare observed load against the best placement still available.

    Observed load is the heat counters when the table has seen traffic
    (rows-touched: the straggler cost of a scatter is the rows the
    hottest node scans), falling back to the partition sizes for a cold
    table. The ratio divides the observed max-node share by
    `achievable_share` (LPT over the table's current keys), so a table
    whose skew is intrinsic to its key distribution reads ~1.0 and is
    left alone, while a stale map that a re-placement would fix reads
    > 1 in proportion to the winnable straggler time."""
    rows = heat.rows_snapshot()
    loads = rows if int(rows.sum()) > 0 else np.asarray(part_sizes, np.int64)
    loads = np.asarray(loads, np.float64)
    k = len(loads)
    if loads.size == 0 or loads.sum() <= 0 or k == 0:
        return DriftReport(table, 1.0, loads, threshold,
                           1.0 / max(1, k))
    share = float(loads.max() / loads.sum())
    # cheap early-out: against PERFECT balance (ach >= 1/k always) the
    # ratio is bounded by share*k — if even that bound clears nobody,
    # skip the O(n-keys) LPT pass; periodic sweeps over healthy tables
    # stay O(nodes)
    if keys is None or share * k <= threshold:
        return DriftReport(table, share * k, loads, threshold, 1.0 / k)
    ach = achievable_share(k, keys)
    if ach <= 0:
        return DriftReport(table, 1.0, loads, threshold, ach)
    return DriftReport(table, share / ach, loads, threshold, ach)


# --------------------------------------------------------------------- plan
@dataclass
class MigrationStep:
    """One bounded unit of live migration: move `row_ids` (original-table
    indices, sorted) from node `src` to node `dst`. `n_bytes` is the moved
    payload (rows x row bytes) — each step stays under the plan's
    `max_step_bytes` so the transient copy traffic is bounded."""
    table: str
    src: int
    dst: int
    row_ids: np.ndarray
    n_bytes: int


@dataclass
class MigrationPlan:
    """What `FarCluster.rebalance` executes.

    `target_part_rows` is the complete new partition map (one sorted
    original-row index array per node); `steps` are the bounded moves that
    transform the current map into it. `new_spec` is the re-captured
    key->node rule when the table is key-partitioned — co-partitioned
    join builds are re-placed by this same object in the same plan so the
    identity-based co-location check keeps holding after the flip."""
    table: str
    target_part_rows: list
    new_spec: CoPartition | None
    steps: list = field(default_factory=list)
    co_tables: tuple = ()           # co-partitioned builds moved in-plan

    @property
    def n_moved(self) -> int:
        return sum(len(s.row_ids) for s in self.steps)

    @property
    def total_bytes(self) -> int:
        return sum(s.n_bytes for s in self.steps)

    @property
    def empty(self) -> bool:
        return not self.steps


def _owner_of(part_rows, n_rows: int) -> np.ndarray:
    owner = np.full(n_rows, -1, np.int64)
    for i, p in enumerate(part_rows):
        owner[np.asarray(p, np.int64)] = i
    return owner


def balance_counts(part_rows) -> list:
    """Minimal-move row-count balancing (tables with no key rule).

    Target sizes are total/k (+-1); the +1 remainders go to the nodes that
    are currently largest so as few rows move as possible. Surplus rows are
    taken from the tail of each over-full node's (sorted) index array and
    handed to the under-full nodes; every array stays sorted."""
    part_rows = [np.asarray(p, np.int64) for p in part_rows]
    k = len(part_rows)
    sizes = np.asarray([len(p) for p in part_rows], np.int64)
    total = int(sizes.sum())
    base, rem = divmod(total, k)
    targets = np.full(k, base, np.int64)
    # hand the +1 remainders to the currently-largest nodes (fewest moves)
    for i in np.argsort(-sizes, kind="stable")[:rem]:
        targets[i] += 1
    surplus: list[np.ndarray] = []
    keep = list(part_rows)
    for i in range(k):
        if sizes[i] > targets[i]:
            cut = int(sizes[i] - targets[i])
            keep[i] = part_rows[i][:-cut]
            surplus.append(part_rows[i][-cut:])
    pool = (np.concatenate(surplus) if surplus
            else np.zeros(0, np.int64))
    out = []
    off = 0
    for i in range(k):
        need = int(targets[i] - len(keep[i]))
        if need > 0:
            out.append(np.sort(np.concatenate(
                [keep[i], pool[off:off + need]])))
            off += need
        else:
            out.append(keep[i])
    return out


def plan_moves(table: str, current_part_rows, target_part_rows,
               row_bytes: int, *,
               max_step_bytes: int | None = None) -> list:
    """Diff two partition maps into bounded `MigrationStep`s.

    Only rows whose owner changes move; moves are grouped per (src, dst)
    pair and chunked so no single step copies more than `max_step_bytes`
    of row payload (None = one step per pair, unbounded)."""
    n_rows = sum(len(np.asarray(p)) for p in current_part_rows)
    cur = _owner_of(current_part_rows, n_rows)
    new = _owner_of(target_part_rows, n_rows)
    if len(cur) != len(new) or (new < 0).any() or (cur < 0).any():
        raise ValueError("partition maps must cover the same rows exactly")
    steps: list[MigrationStep] = []
    moving = cur != new
    rows_per_step = None
    if max_step_bytes is not None:
        rows_per_step = max(1, int(max_step_bytes) // max(1, row_bytes))
    for src in range(len(current_part_rows)):
        for dst in range(len(target_part_rows)):
            if src == dst:
                continue
            ids = np.nonzero(moving & (cur == src) & (new == dst))[0]
            if not len(ids):
                continue
            chunks = ([ids] if rows_per_step is None else
                      [ids[i:i + rows_per_step]
                       for i in range(0, len(ids), rows_per_step)])
            steps.extend(MigrationStep(table, src, dst, c.astype(np.int64),
                                       len(c) * row_bytes)
                         for c in chunks)
    return steps


def plan_rebalance(table: str, current_part_rows, n_rows: int,
                   row_bytes: int, *, n_nodes: int,
                   keys: "np.ndarray | None" = None,
                   max_step_bytes: int | None = None,
                   co_tables: tuple = ()) -> MigrationPlan:
    """Build the full migration plan for one table.

    With `keys` (the table's CURRENT per-row key column), the target is the
    skew-aware greedy LPT placement re-run on today's key frequencies —
    key groups stay whole (co-location survives) and land largest-first on
    the least-loaded node, exactly what `alloc_table_mem(partitioner=
    "skew")` would produce for a fresh table. The re-captured rule is
    returned as `new_spec` so co-partitioned builds follow. Without keys
    the target is minimal-move row-count balancing (no co-location to
    preserve, so any row can move anywhere)."""
    if keys is not None:
        keys = np.asarray(keys)
        if keys.shape[0] != n_rows:
            raise ValueError(
                f"rebalance keys cover {keys.shape[0]} rows, "
                f"table has {n_rows}")
        new_spec = co_partition_spec("skew", n_nodes, keys)
        target = partition_rows(n_rows, n_nodes, keys=keys,
                                co_partition=new_spec)
    else:
        new_spec = None
        target = balance_counts(current_part_rows)
    steps = plan_moves(table, current_part_rows, target, row_bytes,
                       max_step_bytes=max_step_bytes)
    return MigrationPlan(table, target, new_spec, steps,
                         co_tables=tuple(co_tables))
