"""Node failure detection for `FarCluster` (PR 6).

A pooled-memory node that dies takes its partitions with it — Maruf &
Chowdhury (PAPERS.md) call exactly this resilience gap THE open problem
of memory disaggregation. This module is the detection half of the fix:
the replication / failover / self-healing half lives in
`core/cluster.py` (k-replica placement, rerouted reads, `heal`).

Three pieces:

  * `HealthMonitor` — the node lifecycle state machine. Every node is
    ALIVE until evidence says otherwise; transient dispatch failures or
    slow drains move it to SUSPECT; a fatal error (`NodeDeadError`) or
    `dead_after` consecutive strikes move it to DEAD. DEAD is terminal
    for routing purposes until an explicit `revive` (a replaced node).
    Evidence arrives from the dispatch path itself — every
    `FarCluster.flush` drain doubles as a heartbeat (`heartbeat` records
    the drain latency; a drain past `slow_after_s` is a SUSPECT strike),
    so there is no separate prober thread to keep honest.
  * `FaultInjector` — failures as first-class, testable inputs. A node
    holds a reference and consults it on every dispatch / pool verb
    (`FViewNode.check_fault`), so kill-node, slow-node and drop-dispatch
    faults hit exactly where a real NIC timeout or dead host would.
  * typed errors — `NodeDeadError` (the node is gone; reads must fail
    over) vs `DroppedDispatchError` (transient; retry the same node) vs
    `ReplicaUnavailableError` (redundancy exhausted: every copy of a
    partition is on a DEAD node — loud, never silent).

The monitor is pure client-side metadata, in keeping with the cluster's
one-sided design: nodes never gossip about each other; the client that
observes a failure is the one that records it.
"""
from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field

from repro.core.client import (DeadlineExceededError, FarviewError,
                               NodeDeadError)

__all__ = [
    "ALIVE", "SUSPECT", "DEAD",
    "CLOSED", "OPEN", "HALF_OPEN",
    "DroppedDispatchError", "OverloadedError", "ReplicaUnavailableError",
    "DeadlineExceededError",        # re-export: defined with the core errors
    "FaultInjector", "NodeHealth", "HealthMonitor", "CircuitBreaker",
]

ALIVE = "alive"
SUSPECT = "suspect"
DEAD = "dead"


class DroppedDispatchError(FarviewError):
    """A dispatch was lost in flight (injected or transient): the node is
    still there, so the right response is a bounded same-node retry —
    repeated drops escalate the node to SUSPECT and then DEAD."""

    def __init__(self, node_id: int):
        super().__init__(f"node {node_id}: dispatch dropped in flight")
        self.node_id = node_id


class OverloadedError(FarviewError):
    """The server shed this request at ADMISSION (global queue depth or
    the tenant's fair share exhausted) — backpressure before the pool
    or the scheduler ever sees the verb. Deliberately NOT a health
    strike and NOT retried by failover (`ClusterPending._settle_entry`
    re-raises it): the node is alive and explicitly telling this client
    to back off, so rerouting the same load to a replica would just
    spread the overload. Travels the wire as a typed `OVERLOADED`
    frame (net/wire.py)."""

    def __init__(self, node_id: int, detail: str = "queue full"):
        super().__init__(f"node {node_id} overloaded: {detail}")
        self.node_id = node_id
        self.detail = detail


class ReplicaUnavailableError(FarviewError):
    """Redundancy exhausted: every copy of a partition (primary and all
    replicas) lives on a DEAD node. Raised loudly instead of serving a
    partial result — zero wrong bytes beats availability here. The last
    resort past this error is a cold-storage snapshot restore
    (`FarCluster.heal(..., manager=)` / `restore_table`)."""


# ------------------------------------------------------------------ injector
class FaultInjector:
    """Injectable failures, threaded through every node's verb path.

    The cluster hands one injector to all of its `FViewNode`s; each node
    calls `check(node_id)` before a dispatch or pool verb. Faults:

      kill(i)               every verb on node i raises NodeDeadError
                            until revive(i) — the dead-host case.
      slow(i, seconds)      every verb on node i first sleeps — the
                            degraded-NIC / overloaded-host case that the
                            heartbeat latency check escalates to SUSPECT.
      drop_dispatches(i, n) the next n verbs on node i raise
                            DroppedDispatchError (transient; a same-node
                            retry succeeds once the budget is spent).
                            With `prob=` each dispatch inside the budget
                            drops with that probability instead of
                            deterministically — drawn from the
                            injector's SEEDED rng, so a probabilistic
                            chaos run replays bit-identically from the
                            same seed (CI threads `--seed` through
                            bench_failover / bench_chaos).

    Thread-safe: `FarCluster.flush` drains nodes in concurrent threads.
    """

    def __init__(self, seed: int | None = None) -> None:
        self._lock = threading.Lock()
        self._rng = random.Random(seed)     # guarded-by: self._lock
        self.seed = seed
        self._killed: set[int] = set()      # guarded-by: self._lock
        self._slow: dict[int, float] = {}   # guarded-by: self._lock
        self._drop: dict[int, int] = {}     # guarded-by: self._lock
        self._drop_prob: dict[int, float] = {}  # guarded-by: self._lock

    # -- fault controls (the test/bench-facing surface) ---------------------
    def kill(self, node_id: int) -> None:
        with self._lock:
            self._killed.add(node_id)

    def revive(self, node_id: int) -> None:
        with self._lock:
            self._killed.discard(node_id)
            self._slow.pop(node_id, None)
            self._drop.pop(node_id, None)
            self._drop_prob.pop(node_id, None)

    def slow(self, node_id: int, seconds: float) -> None:
        with self._lock:
            self._slow[node_id] = float(seconds)

    def drop_dispatches(self, node_id: int, n: int = 1,
                        prob: float | None = None) -> None:
        """Arm a drop budget of `n` dispatches on `node_id`. With `prob`,
        each dispatch inside the budget drops with that probability (the
        seeded rng decides), so faults land at reproducible-but-spread
        points instead of the next n calls back to back."""
        with self._lock:
            self._drop[node_id] = self._drop.get(node_id, 0) + int(n)
            if prob is not None:
                if not 0.0 < prob <= 1.0:
                    raise ValueError(f"drop prob {prob} not in (0, 1]")
                self._drop_prob[node_id] = float(prob)

    def is_killed(self, node_id: int) -> bool:
        with self._lock:
            return node_id in self._killed

    # -- the node-side check ------------------------------------------------
    def check(self, node_id: int, op: str = "dispatch") -> None:
        """Called by the node before serving a verb; raises the fault."""
        with self._lock:
            if node_id in self._killed:
                raise NodeDeadError(node_id, op=op)
            delay = self._slow.get(node_id, 0.0)
            drop = False
            if op == "dispatch" and self._drop.get(node_id, 0) > 0:
                prob = self._drop_prob.get(node_id)
                if prob is None or self._rng.random() < prob:
                    self._drop[node_id] -= 1
                    drop = True
        if delay:
            time.sleep(delay)
        if drop:
            raise DroppedDispatchError(node_id)


# ------------------------------------------------------------------ breaker
CLOSED = "closed"
OPEN = "open"
HALF_OPEN = "half_open"


class CircuitBreaker:
    """Per-node circuit breaker layered UNDER the health monitor (PR 9).

    The lifecycle monitor answers "is the node gone?"; the breaker
    answers the cheaper, faster question "should the next attempt even
    be made?" — so a FLAPPING node (alive enough to accept work, broken
    enough to fail it) stops eating retry budgets:

      CLOSED     normal service. `open_after` consecutive failures trip
                 it OPEN (`record_failure`); any success resets the
                 strike count.
      OPEN       `allow()` answers False — callers skip the node (route
                 to a replica, fail fast) WITHOUT spending a timeout on
                 it. After `reset_after_s` the breaker moves to...
      HALF_OPEN  exactly ONE probe is allowed through (`allow()` True
                 once, False while the probe is outstanding). The
                 probe's outcome decides: success -> CLOSED (service
                 resumes), failure -> OPEN again with a fresh window.

    Thread-safe; every method may be called from the cluster's parallel
    drain threads and from `RemoteNodeHandle`s reconnect path at once.
    """

    def __init__(self, n_nodes: int, *, open_after: int = 3,
                 reset_after_s: float = 1.0):
        self._lock = threading.Lock()
        self.open_after = int(open_after)
        self.reset_after_s = float(reset_after_s)
        self._state = [CLOSED] * n_nodes        # guarded-by: self._lock
        self._strikes = [0] * n_nodes           # guarded-by: self._lock
        self._opened_at = [0.0] * n_nodes       # guarded-by: self._lock
        self._probing = [False] * n_nodes       # guarded-by: self._lock
        self.trips = [0] * n_nodes              # OPEN transitions, telemetry

    def state(self, node_id: int) -> str:
        with self._lock:
            self._maybe_half_open(node_id)
            return self._state[node_id]

    def _maybe_half_open(self, node_id: int) -> None:
        # lock-held helper: every caller enters via `with self._lock:`
        if (self._state[node_id] == OPEN  # farlint: ok FL001 -- caller holds self._lock
                and time.monotonic() - self._opened_at[node_id]  # farlint: ok FL001 -- caller holds self._lock
                >= self.reset_after_s):
            self._state[node_id] = HALF_OPEN  # farlint: ok FL001 -- caller holds self._lock
            self._probing[node_id] = False  # farlint: ok FL001 -- caller holds self._lock

    def allow(self, node_id: int) -> bool:
        """May the caller attempt this node right now? CLOSED: yes.
        OPEN: no (until the reset window elapses). HALF_OPEN: yes for
        exactly one in-flight probe."""
        with self._lock:
            self._maybe_half_open(node_id)
            state = self._state[node_id]
            if state == CLOSED:
                return True
            if state == HALF_OPEN and not self._probing[node_id]:
                self._probing[node_id] = True
                return True
            return False

    def record_success(self, node_id: int) -> None:
        with self._lock:
            self._state[node_id] = CLOSED
            self._strikes[node_id] = 0
            self._probing[node_id] = False

    def record_failure(self, node_id: int) -> str:
        with self._lock:
            self._maybe_half_open(node_id)
            state = self._state[node_id]
            if state == HALF_OPEN or state == OPEN:
                # a failed probe (or a straggler failure) re-arms the
                # full reset window
                self._state[node_id] = OPEN
                self._opened_at[node_id] = time.monotonic()
                self._probing[node_id] = False
                if state == HALF_OPEN:
                    self.trips[node_id] += 1
                return OPEN
            self._strikes[node_id] += 1
            if self._strikes[node_id] >= self.open_after:
                self._state[node_id] = OPEN
                self._opened_at[node_id] = time.monotonic()
                self.trips[node_id] += 1
                return OPEN
            return CLOSED

    def summary(self) -> dict[int, str]:
        with self._lock:
            for i in range(len(self._state)):
                self._maybe_half_open(i)
            return dict(enumerate(self._state))


# ------------------------------------------------------------------- monitor
@dataclass
class NodeHealth:
    """One node's lifecycle record."""
    state: str = ALIVE
    strikes: int = 0                # consecutive failures / slow drains
    last_error: Exception | None = None
    last_latency_s: float = 0.0
    heartbeats: int = 0
    failures: int = 0


class HealthMonitor:
    """The ALIVE → SUSPECT → DEAD lifecycle, driven by dispatch outcomes.

    `record_failure` classifies: a `NodeDeadError` is conclusive (DEAD
    immediately — the node itself said so); anything else is a strike,
    SUSPECT on the first and DEAD once `dead_after` consecutive strikes
    accumulate. `record_success` clears strikes (SUSPECT heals back to
    ALIVE; DEAD does not — a dead node that answers again is a split
    brain, and only an explicit `revive` readmits it). `heartbeat`
    records a drain latency; past `slow_after_s` it counts as a strike,
    so a hung-but-not-gone node still escalates.
    """

    def __init__(self, n_nodes: int, *, dead_after: int = 3,
                 slow_after_s: float = 30.0,
                 breaker: "CircuitBreaker | None" = None):
        # An optional CircuitBreaker layers on top: every success /
        # failure recorded here is forwarded (outside the monitor's
        # lock — the two are independent state machines), so routing
        # can consult `breaker.allow()` without a second bookkeeping
        # path.
        self._lock = threading.Lock()
        self.nodes = [NodeHealth() for _ in range(n_nodes)]    # guarded-by: self._lock
        self.dead_after = int(dead_after)
        self.slow_after_s = float(slow_after_s)
        self.breaker = breaker

    # -- queries ------------------------------------------------------------
    # Queries take the lock too: routing decisions read `state` while the
    # parallel drain threads are writing it, and an unlocked read of a
    # NodeHealth mid-transition is exactly the race this monitor exists
    # to prevent.
    def state(self, node_id: int) -> str:
        with self._lock:
            return self.nodes[node_id].state

    def is_alive(self, node_id: int) -> bool:
        """Routable: ALIVE or SUSPECT (a suspect still serves; it is just
        one strike from losing that right)."""
        with self._lock:
            return self.nodes[node_id].state != DEAD

    def alive_nodes(self) -> list[int]:
        with self._lock:
            return [i for i, h in enumerate(self.nodes) if h.state != DEAD]

    def dead_nodes(self) -> list[int]:
        with self._lock:
            return [i for i, h in enumerate(self.nodes) if h.state == DEAD]

    def summary(self) -> dict[int, str]:
        with self._lock:
            return {i: h.state for i, h in enumerate(self.nodes)}

    # -- evidence -----------------------------------------------------------
    def record_success(self, node_id: int) -> None:
        with self._lock:
            h = self.nodes[node_id]
            if h.state == DEAD:
                return              # only revive() readmits a dead node
            h.strikes = 0
            h.state = ALIVE
            h.last_error = None
        if self.breaker is not None:
            self.breaker.record_success(node_id)

    def record_failure(self, node_id: int, err: Exception) -> str:
        with self._lock:
            h = self.nodes[node_id]
            h.failures += 1
            h.last_error = err
            if h.state == DEAD:
                state = DEAD
            elif isinstance(err, NodeDeadError):
                h.state = DEAD      # conclusive: the node itself said so
                state = DEAD
            else:
                h.strikes += 1
                h.state = DEAD if h.strikes >= self.dead_after else SUSPECT
                state = h.state
        if self.breaker is not None:
            self.breaker.record_failure(node_id)
        return state

    def heartbeat(self, node_id: int, latency_s: float) -> None:
        """A completed drain IS the heartbeat; a slow one is a strike."""
        with self._lock:
            h = self.nodes[node_id]
            h.heartbeats += 1
            h.last_latency_s = float(latency_s)
        if latency_s > self.slow_after_s:
            self.record_failure(node_id, FarviewError(
                f"node {node_id}: drain took {latency_s:.2f}s "
                f"(> {self.slow_after_s:.2f}s slow threshold)"))
        else:
            self.record_success(node_id)

    def mark_dead(self, node_id: int) -> None:
        with self._lock:
            self.nodes[node_id].state = DEAD

    def revive(self, node_id: int) -> None:
        """Explicit readmission (a replaced/recovered node)."""
        with self._lock:
            h = self.nodes[node_id]
            h.state = ALIVE
            h.strikes = 0
            h.last_error = None
        if self.breaker is not None:    # readmitted nodes start CLOSED
            self.breaker.record_success(node_id)
