"""Mamba2 (SSD — state space dual) blocks, chunked scan + recurrent decode.

TPU-native: the intra-chunk part is a masked (decay-weighted) attention-like
matmul on the MXU; inter-chunk states are carried by a short lax.scan
(S/chunk steps). Decode is an O(1) recurrent state update — the "cache" for
hybrid archs (zamba2) is this state, not a KV pool.

Head layout follows Mamba2: x projected to (H, P) value heads; B and C are
shared across heads (single group), state size N per head; A scalar per head
(negative, learned via log); dt per head via softplus.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init, init_rmsnorm, rms_norm


def init_mamba2(key, d_model, n_heads, d_state, dtype, *, expand: int = 2):
    d_inner = expand * d_model
    head_p = d_inner // n_heads
    ks = jax.random.split(key, 6)
    return {
        # fused input projection: [x (d_inner), z gate (d_inner), B (N), C (N), dt (H)]
        "w_in": dense_init(ks[0], d_model,
                           2 * d_inner + 2 * d_state + n_heads, dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm": init_rmsnorm(d_inner, dtype),
        "w_out": dense_init(ks[1], d_inner, d_model, dtype,
                            scale=1.0 / math.sqrt(d_inner)),
    }


def _split_proj(xp, d_inner, d_state, n_heads):
    xs = xp[..., :d_inner]
    z = xp[..., d_inner:2 * d_inner]
    bmat = xp[..., 2 * d_inner:2 * d_inner + d_state]
    cmat = xp[..., 2 * d_inner + d_state:2 * d_inner + 2 * d_state]
    dt = xp[..., 2 * d_inner + 2 * d_state:]
    return xs, z, bmat, cmat, dt


def ssd_chunk_scan(xh, bmat, cmat, dt, a_log, chunk: int):
    """Chunked SSD. xh: (B,S,H,P); bmat/cmat: (B,S,N); dt: (B,S,H) (+softplus
    already applied); a_log (H,). Returns y (B,S,H,P)."""
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    nc = max(1, s // chunk)
    cs = s // nc
    a = -jnp.exp(a_log)                                    # (H,) negative
    da = dt * a[None, None, :]                             # (B,S,H) log decay
    xc = xh.reshape(b, nc, cs, h, p)
    bc = bmat.reshape(b, nc, cs, n)
    cc = cmat.reshape(b, nc, cs, n)
    dtc = dt.reshape(b, nc, cs, h)
    dac = da.reshape(b, nc, cs, h)
    da_cum = jnp.cumsum(dac, axis=2)                       # (B,nc,cs,H)
    da_tot = da_cum[:, :, -1]                              # (B,nc,H)

    def step(state, inp):
        xb, bb, cb, dtb, dacum, datot = inp
        # inter-chunk: y_i += (C_i . state) * exp(dacum_i)
        y_inter = jnp.einsum("bcn,bhnp->bchp", cb.astype(jnp.float32), state,
                             optimize=True) * jnp.exp(dacum)[..., None]
        # intra-chunk: L[i,j] = exp(dacum_i - dacum_j) for j<=i.
        # Mask BEFORE exp: non-causal lw is positive-large, exp overflows,
        # and where(causal, exp(lw), 0) then yields inf*0 = NaN in the
        # BACKWARD (d exp = exp). Masking the exponent keeps both passes
        # finite.
        lw = dacum[:, :, None, :] - dacum[:, None, :, :]   # (B,ci,cj,H)
        causal = jnp.tril(jnp.ones((cs, cs), bool))
        lw = jnp.where(causal[None, :, :, None], lw, -1e30)
        L = jnp.exp(lw)
        cb_f = cb.astype(jnp.float32)
        bb_f = bb.astype(jnp.float32)
        scores = jnp.einsum("bin,bjn->bij", cb_f, bb_f, optimize=True)
        A = scores[..., None] * L * dtb[:, None, :, :]     # (B,ci,cj,H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", A,
                             xb.astype(jnp.float32), optimize=True)
        # state update: S' = exp(datot) S + sum_j exp(datot - dacum_j) dt_j B_j x_j^T
        w = jnp.exp(datot[:, None] - dacum) * dtb          # (B,cs,H)
        upd = jnp.einsum("bjn,bjhp->bhnp", bb_f,
                         xb.astype(jnp.float32) * w[..., None], optimize=True)
        state = state * jnp.exp(datot)[:, :, None, None] + upd
        return state, y_inter + y_intra

    s0 = jnp.zeros((b, h, n, p), jnp.float32)
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in
               (xc, bc, cc, dtc, da_cum, da_tot))
    final, ys = jax.lax.scan(step, s0, xs)
    return jnp.moveaxis(ys, 0, 1).reshape(b, s, h, p), final


def mamba2_block(x, p, *, n_heads: int, d_state: int, chunk: int = 256,
                 expand: int = 2, return_state: bool = False):
    """x: (B,S,d) -> (B,S,d)."""
    b, s, d = x.shape
    d_inner = expand * d
    xp = x @ p["w_in"]
    xs, z, bmat, cmat, dt_raw = _split_proj(xp, d_inner, d_state, n_heads)
    head_p = d_inner // n_heads
    xh = xs.reshape(b, s, n_heads, head_p)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"][None, None, :])
    y, final_state = ssd_chunk_scan(xh, bmat, cmat, dt, p["a_log"], chunk)
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(b, s, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, p["norm"])
    out = y @ p["w_out"]
    return (out, final_state) if return_state else out


def mamba2_decode_step(x, p, state, *, n_heads: int, d_state: int,
                       expand: int = 2):
    """x: (B, d); state (B,H,N,P) -> (out (B,d), new state)."""
    b, d = x.shape
    d_inner = expand * d
    xp = x @ p["w_in"]
    xs, z, bmat, cmat, dt_raw = _split_proj(xp, d_inner, d_state, n_heads)
    head_p = d_inner // n_heads
    xh = xs.reshape(b, n_heads, head_p).astype(jnp.float32)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"][None, :])
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt * a[None, :])                        # (B,H)
    upd = jnp.einsum("bn,bhp->bhnp", bmat.astype(jnp.float32),
                     xh * dt[..., None])
    state = state * decay[..., None, None] + upd
    y = jnp.einsum("bn,bhnp->bhp", cmat.astype(jnp.float32), state)
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(b, d_inner).astype(x.dtype)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)
    y = rms_norm(y, p["norm"])
    return y @ p["w_out"], state


def mamba2_init_state(batch, d_model, n_heads, d_state, *, expand: int = 2):
    head_p = expand * d_model // n_heads
    return jnp.zeros((batch, n_heads, d_state, head_p), jnp.float32)
