"""Modality frontend STUBS (per assignment spec).

[audio] musicgen-large and [vlm] llama-3.2-vision specify the transformer
BACKBONE only; the modality frontend supplies precomputed embeddings:

  * musicgen: EnCodec frame embeddings. The real model sums 4 codebook
    embeddings per frame with a delay pattern; the stub emits the summed
    (B, S, d_model) frame embedding directly (deterministic from seed).
  * llama-3.2-vision: ViT patch/tile embeddings projected to d_model,
    (B, n_image_tokens, d_model).

`input_specs()` (configs/__init__.py) returns ShapeDtypeStructs for these;
the generators below produce concrete deterministic arrays for smoke tests
and examples.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def audio_frame_embeddings(cfg: ModelConfig, batch: int, seq: int,
                           seed: int = 0, dtype=jnp.bfloat16):
    key = jax.random.PRNGKey(seed)
    return (jax.random.normal(key, (batch, seq, cfg.d_model), jnp.float32)
            * 0.02).astype(dtype)


def image_patch_embeddings(cfg: ModelConfig, batch: int, seed: int = 0,
                           dtype=jnp.bfloat16):
    key = jax.random.PRNGKey(seed + 1)
    return (jax.random.normal(
        key, (batch, cfg.n_image_tokens, cfg.d_model), jnp.float32)
        * 0.02).astype(dtype)
