"""Shared transformer layers: norms, RoPE, attention, GLU MLPs.

Pure-functional JAX (init_* return param pytrees; apply fns take them).
Attention is implemented flash-style (online softmax over KV chunks via
lax.scan) so 32k-token prefill never materializes (S, S) score matrices —
this is what keeps the dry-run memory_analysis honest at long context.

Conventions:
  * params are dicts of jnp arrays; stacked-layer variants add a leading
    layer axis and are consumed by lax.scan in blocks.py.
  * activations (B, S, D); attention heads explicit (B, S, H, Dh).
  * dtypes: params in cfg.param_dtype (bf16 default), math in f32 where it
    matters (softmax, norms, rope).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

Params = dict


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, d_in, d_out, dtype, scale: float | None = None):
    s = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * s).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------
def init_rmsnorm(d, dtype):
    return {"w": jnp.zeros((d,), dtype)}


def rms_norm(x, p, eps=1e-6, *, gemma_style: bool = True):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    w = p["w"].astype(jnp.float32)
    return (y * (1.0 + w)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope(x, positions, *, theta: float = 10000.0):
    """x: (B, S, H, Dh); positions: (B, S) or (S,)."""
    b, s, h, dh = x.shape
    half = dh // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freq   # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# flash-style chunked attention
# ---------------------------------------------------------------------------
def _softcap(x, cap):
    if cap is None or cap <= 0:
        return x
    return jnp.tanh(x / cap) * cap


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float | None = None, q_offset=0,
                    kv_chunk: int = 1024, kv_valid_len=None,
                    scale: float | None = None):
    """Online-softmax attention over KV chunks.

    q: (B, Sq, Hq, Dh); k/v: (B, Skv, Hkv, Dh). GQA by head grouping.
    causal masks by (global) position: q position = q_offset + i.
    window > 0 adds sliding-window masking (positions within `window`).
    kv_valid_len: (B,) optional ragged KV lengths.
    Returns (B, Sq, Hq, Dh) in q.dtype.
    """
    b, sq, hq, dh = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    if scale is None:
        scale = 1.0 / math.sqrt(dh)
    nchunks = max(1, skv // kv_chunk)
    assert skv % nchunks == 0, (skv, kv_chunk)
    cs = skv // nchunks

    # MXU-native dtype discipline (§Perf B1): QK^T and PV consume K/V in
    # their stored dtype with f32 accumulation (preferred_element_type) —
    # no f32 copies of the K/V chunks ever hit HBM. The f32 softmax state
    # (m, l, o) is what carries precision.
    qc = q.astype(k.dtype).reshape(b, sq, hkv, g, dh)
    kc = k.reshape(b, nchunks, cs, hkv, dh)
    vc = v.reshape(b, nchunks, cs, hkv, dh)
    q_pos = q_offset + jnp.arange(sq)

    def step(carry, inp):
        m_prev, l_prev, o_prev = carry
        kb, vb, ci = inp
        k_pos = ci * cs + jnp.arange(cs)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", qc, kb, optimize=True,
                            preferred_element_type=jnp.float32) * scale
        scores = _softcap(scores, softcap)
        mask = jnp.ones((sq, cs), bool)
        if causal:
            mask &= q_pos[:, None] >= k_pos[None, :]
        if window and window > 0:
            mask &= (q_pos[:, None] - k_pos[None, :]) < window
        mask = jnp.broadcast_to(mask[None, None, None], scores.shape)
        if kv_valid_len is not None:
            kvm = k_pos[None, :] < kv_valid_len[:, None]      # (B, cs)
            mask &= kvm[:, None, None, None, :]
        scores = jnp.where(mask, scores, -1e30)
        m_cur = jnp.max(scores, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(scores - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        o_new = o_prev * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb, optimize=True,
            preferred_element_type=jnp.float32)
        return (m_new, l_new, o_new), None

    m0 = jnp.full((b, hkv, g, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    o0 = jnp.zeros((b, hkv, g, sq, dh), jnp.float32)
    ks = jnp.moveaxis(kc, 1, 0)
    vs = jnp.moveaxis(vc, 1, 0)
    (m, l, o), _ = jax.lax.scan(step, (m0, l0, o0),
                                (ks, vs, jnp.arange(nchunks)))
    out = o / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out.reshape(b, hkv * g, sq, dh), 1, 2)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block (training / prefill path)
# ---------------------------------------------------------------------------
def init_attention(key, d_model, n_heads, n_kv_heads, head_dim, dtype):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, d_model, n_heads * head_dim, dtype),
        "wk": dense_init(k2, d_model, n_kv_heads * head_dim, dtype),
        "wv": dense_init(k3, d_model, n_kv_heads * head_dim, dtype),
        "wo": dense_init(k4, n_heads * head_dim, d_model, dtype,
                         scale=1.0 / math.sqrt(n_heads * head_dim)),
    }


def attention(x, p, *, n_heads, n_kv_heads, head_dim, causal=True, window=0,
              softcap=None, rope_theta=10000.0, positions=None,
              kv_chunk=1024, query_pre_scale=None, kv_override=None,
              q_offset=0):
    """Full attention block: qkv proj + rope + flash + out proj.

    kv_override: optional (k, v) tensors (cross attention).
    Returns (out, (k, v)) so callers can stash the KV for caches.
    """
    b, s, d = x.shape
    q = (x @ p["wq"]).reshape(b, s, n_heads, head_dim)
    if kv_override is None:
        k = (x @ p["wk"]).reshape(b, s, n_kv_heads, head_dim)
        v = (x @ p["wv"]).reshape(b, s, n_kv_heads, head_dim)
        if positions is None:
            positions = jnp.arange(s)
        q = rope(q, positions, theta=rope_theta)
        k = rope(k, positions, theta=rope_theta)
    else:
        k, v = kv_override
        if positions is None:
            positions = jnp.arange(s)
        q = rope(q, positions, theta=rope_theta)
    scale = query_pre_scale if query_pre_scale is not None else None
    out = flash_attention(q, k, v, causal=causal and kv_override is None,
                          window=window, softcap=softcap, kv_chunk=kv_chunk,
                          scale=scale, q_offset=q_offset)
    return out.reshape(b, s, -1) @ p["wo"], (k, v)


# ---------------------------------------------------------------------------
# GLU MLP
# ---------------------------------------------------------------------------
def init_mlp(key, d_model, d_ff, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, d_model, d_ff, dtype),
        "w_up": dense_init(k2, d_model, d_ff, dtype),
        "w_down": dense_init(k3, d_ff, d_model, dtype,
                             scale=1.0 / math.sqrt(d_ff)),
    }


def mlp(x, p, *, act: str = "silu"):
    gate = x @ p["w_gate"]
    up = x @ p["w_up"]
    if act == "silu":
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    elif act == "gelu":
        h = jax.nn.gelu(gate.astype(jnp.float32),
                        approximate=True).astype(x.dtype) * up
    else:
        raise ValueError(act)
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# embeddings / head
# ---------------------------------------------------------------------------
def init_embedding(key, vocab, d_model, dtype):
    return {"table": (jax.random.normal(key, (vocab, d_model), jnp.float32)
                      * 0.02).astype(dtype)}


def embed(tokens, p, *, scale_by_dim: bool = False):
    x = jnp.take(p["table"], tokens, axis=0)
    if scale_by_dim:
        x = x * jnp.asarray(math.sqrt(x.shape[-1]), x.dtype)
    return x


def unembed(x, p_embed=None, p_head=None, *, softcap=None):
    if p_head is not None:
        logits = x @ p_head["w"]
    else:
        logits = x @ p_embed["table"].T
    logits = _softcap(logits.astype(jnp.float32), softcap)
    return logits


def cross_entropy(logits, labels, *, ignore_id: int = -1):
    """Mean token CE; logits (B, S, V) f32, labels (B, S) int32."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    valid = labels != ignore_id
    safe = jnp.where(valid, labels, 0)
    ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return -jnp.sum(jnp.where(valid, ll, 0.0)) / jnp.maximum(
        jnp.sum(valid), 1)


def chunked_cross_entropy(x, w, labels, *, transpose_w: bool = False,
                          softcap: float | None = None, chunk: int = 1024,
                          ignore_id: int = -1):
    """CE without materializing (B, S, V): scan over sequence chunks.

    x: (B, S, d) final hidden states; w: (d, V) head (or (V, d) embedding
    table with transpose_w=True); labels (B, S).
    Each chunk computes its logits, softcaps, log-softmaxes, and reduces to
    (sum_ll, n_valid) — only (B, chunk, V) is ever live. This is what keeps
    the train-step memory_analysis bounded at vocab=256k x 1M tokens.
    """
    b, s, d = x.shape
    if chunk >= s:
        logits = _ce_logits(x, w, transpose_w, softcap)
        return cross_entropy(logits, labels, ignore_id=ignore_id)
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    xc = jnp.moveaxis(x.reshape(b, nc, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)

    def body(carry, inp):
        ll_sum, n_valid = carry
        xb, lb = inp
        logits = _ce_logits(xb, w, transpose_w, softcap)
        logp = jax.nn.log_softmax(logits, axis=-1)
        valid = lb != ignore_id
        safe = jnp.where(valid, lb, 0)
        ll = jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
        ll_sum = ll_sum + jnp.sum(jnp.where(valid, ll, 0.0))
        n_valid = n_valid + jnp.sum(valid)
        return (ll_sum, n_valid), None

    body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    (ll_sum, n_valid), _ = jax.lax.scan(
        body, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (xc, lc))
    return -ll_sum / jnp.maximum(n_valid, 1)


def _ce_logits(x, w, transpose_w, softcap):
    logits = x @ (w.T if transpose_w else w)
    return _softcap(logits.astype(jnp.float32), softcap)
