"""CausalLM driver: embedding -> scan(groups) -> norm -> logits.

One class serves all 10 assigned architectures; family differences live in
blocks.py. Three entry points:

  loss/forward : training & prefill (full-sequence, flash attention),
                 optional cache collection for the prefill->decode handoff.
  decode_step  : single-token serve step against caches. Attention-bearing
                 families read/write the disaggregated KV pool (far mode =
                 the paper's operator push-down; naive/local = the paper's
                 RCPU/LCPU baselines). Recurrent families carry O(1) state.

Scan-over-groups keeps HLO size ~constant in depth; jax.checkpoint (remat)
around the group body keeps train memory bounded at 32k context.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks as B
from repro.models import layers as L


class LM:
    def __init__(self, cfg: ModelConfig, *, mesh=None, dp_axes=("data",),
                 act_spec=None, ce_act_spec=None):
        self.cfg = cfg
        self.mesh = mesh
        self.dp_axes = dp_axes
        # residual-stream sharding constraints (set by launch/ for pjit runs)
        self.act_spec = act_spec          # applied inside the group scan
        self.ce_act_spec = ce_act_spec    # applied to x before chunked CE

    def _constrain(self, x, spec):
        if spec is None:
            return x
        return jax.lax.with_sharding_constraint(x, spec)

    # ------------------------------------------------------------------ init
    def init(self, key) -> dict:
        cfg = self.cfg
        dt = jnp.dtype(cfg.param_dtype)
        k1, k2, k3, k4 = jax.random.split(key, 4)
        params: dict[str, Any] = {}
        if not cfg.embed_input:
            params["embed"] = L.init_embedding(k1, cfg.vocab, cfg.d_model, dt)
        groups, shared = B.init_stacked(k2, cfg)
        params["groups"] = groups
        params["shared"] = shared
        params["ln_f"] = L.init_rmsnorm(cfg.d_model, dt)
        if cfg.embed_input or not cfg.tie_embeddings:
            params["head"] = {"w": L.dense_init(k3, cfg.d_model, cfg.vocab,
                                                dt)}
        return params

    def param_count(self, params) -> int:
        return sum(int(x.size) for x in jax.tree.leaves(params))

    # --------------------------------------------------------------- forward
    def _embed_in(self, params, batch):
        cfg = self.cfg
        if cfg.embed_input:
            return batch["embeds"].astype(jnp.dtype(cfg.param_dtype))
        return L.embed(batch["tokens"], params["embed"],
                       scale_by_dim=cfg.scale_embed)

    def _logits(self, params, x):
        cfg = self.cfg
        x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        if "head" in params:
            return L.unembed(x, p_head=params["head"],
                             softcap=cfg.softcap_logits or None)
        return L.unembed(x, p_embed=params["embed"],
                         softcap=cfg.softcap_logits or None)

    def _backbone(self, params, batch, *, collect_kv: bool = False):
        """embed -> scan(groups). Returns (x, aux, kvs)."""
        cfg = self.cfg
        x = self._embed_in(params, batch)
        x = self._constrain(x, self.act_spec)
        image_embeds = batch.get("image_embeds")
        if image_embeds is not None:
            image_embeds = image_embeds.astype(x.dtype)
        shared = params["shared"]

        def body(xc, gp):
            y, aux, kvs = B.group_fwd(xc, gp, cfg, shared,
                                      image_embeds=image_embeds,
                                      collect_kv=collect_kv,
                                      mesh=self.mesh, dp_axes=self.dp_axes)
            y = self._constrain(y, self.act_spec)
            return y, (aux, kvs)

        if cfg.remat:
            policy = {
                "nothing": jax.checkpoint_policies.nothing_saveable,
                "dots_no_batch":
                    jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
                "dots": jax.checkpoint_policies.dots_saveable,
            }[cfg.remat_policy]
            body = jax.checkpoint(body, policy=policy)
        x, (auxs, kvs) = jax.lax.scan(body, x, params["groups"])
        return x, (jnp.mean(auxs) if cfg.n_experts else 0.0), kvs

    def forward(self, params, batch, *, collect_kv: bool = False,
                max_seq: int | None = None):
        """Returns (logits, aux_loss, cache|None)."""
        x, aux, kvs = self._backbone(params, batch, collect_kv=collect_kv)
        logits = self._logits(params, x)
        cache = None
        if collect_kv:
            s = x.shape[1]
            tgt = max_seq or s
            def _pad(key, leaf):
                # KV leaves are (G, B, Hkv, S, D): pad S (dim 3) to max_seq
                if (key.startswith(("k_", "v_")) and "cross" not in key
                        and leaf.ndim == 5 and leaf.shape[3] == s):
                    pad = [(0, 0)] * leaf.ndim
                    pad[3] = (0, tgt - s)
                    return jnp.pad(leaf, pad)
                return leaf
            cache = {k: _pad(k, v) for k, v in kvs.items()}
        return logits, aux, cache

    def prefill(self, params, batch, *, max_seq: int | None = None):
        """Serve prefill: last-position logits + KV cache (far-pool layout)."""
        logits, _, cache = self.forward(params, batch, collect_kv=True,
                                        max_seq=max_seq)
        return logits[:, -1:], cache

    def loss(self, params, batch):
        """Train loss with chunked CE (never materializes (B, S, V))."""
        cfg = self.cfg
        x, aux, _ = self._backbone(params, batch)
        x = L.rms_norm(x, params["ln_f"], cfg.norm_eps)
        x = self._constrain(x, self.ce_act_spec)
        if "head" in params:
            w, tr = params["head"]["w"], False
        else:
            w, tr = params["embed"]["table"], True
        ce = L.chunked_cross_entropy(
            x, w, batch["labels"], transpose_w=tr,
            softcap=cfg.softcap_logits or None, chunk=cfg.ce_chunk)
        if cfg.n_experts:
            ce = ce + cfg.router_aux_weight * aux
        return ce

    # ---------------------------------------------------------------- decode
    def init_cache(self, batch: int, max_seq: int,
                   kv_dtype=jnp.bfloat16) -> dict:
        cfg = self.cfg
        g = B.n_groups(cfg)
        proto = B.group_cache(cfg, batch, max_seq, kv_dtype)
        return {k: jnp.zeros((g,) + v.shape, v.dtype)
                for k, v in proto.items()}

    def decode_step(self, params, cache, batch, pos, length, *,
                    mode: str = "far"):
        """batch: {"tokens": (B,1)} or {"embeds": (B,1,d)}. pos: () int32.

        Returns (logits (B,1,V), new_cache).

        The cache rides the scan as xs->ys (sliced per group in, restacked
        out); with donation the ys buffer aliases the input cache. §Perf B2
        tried cache-as-carry with per-group dynamic updates instead — XLA's
        copy-insertion then cloned every stacked buffer once per iteration
        (read-write overlap), 3.5x MORE HBM traffic; xs->ys restacks only
        the per-group slice. (Hypothesis refuted; kept the xs->ys form.)
        """
        cfg = self.cfg
        x = self._embed_in(params, batch)
        shared = params["shared"]
        mesh, dp = self.mesh, self.dp_axes

        def body(xc, inp):
            gp, cg = inp
            y, nc = B.group_dec(xc, gp, cg, cfg, shared, pos, length,
                                mode=mode, mesh=mesh, dp_axes=dp)
            return y, nc

        x, new_cache = jax.lax.scan(body, x, (params["groups"], cache))
        logits = self._logits(params, x)
        return logits, new_cache
