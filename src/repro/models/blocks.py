"""Block assembly per architecture family.

Every architecture is expressed as a stack of identical *groups* scanned
with `jax.lax.scan` (stacked params, leading group axis) so the HLO stays
small and compile time flat in depth. A group bundles the repeating pattern:

  dense / moe / audio : 1 block               x n_layers groups
  gemma2              : (local, global) pair  x n_layers/2 groups
  vlm (llama-vision)  : 4 self + 1 cross      x n_layers/5 groups
  ssm (xlstm)         : (k-1) mLSTM + 1 sLSTM x n_layers/k groups
  hybrid (zamba2)     : k Mamba2 + shared attn x n_layers/k groups
                        (the shared attention block's params are NOT stacked
                        — one set, applied between groups, per zamba2)

Each family implements: init_group / group_fwd (train & prefill; emits KV) /
group_dec (single-token decode vs caches) / group_cache (cache zeros).
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.jax_compat import shard_map
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models import xlstm as XL


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def n_groups(cfg: ModelConfig) -> int:
    per = group_layout(cfg)["layers_per_group"]
    assert cfg.n_layers % per == 0, (cfg.arch, cfg.n_layers, per)
    return cfg.n_layers // per


def group_layout(cfg: ModelConfig) -> dict:
    """Describes the repeating sub-layer pattern of one group."""
    if cfg.family in ("dense", "audio"):
        return {"layers_per_group": 1, "subs": ["attn"]}
    if cfg.family == "moe":
        return {"layers_per_group": 1, "subs": ["attn"]}
    if cfg.attn_pattern == "gemma2_alt":
        return {"layers_per_group": 2, "subs": ["attn_local", "attn_global"]}
    if cfg.family == "vlm":
        k = cfg.cross_every
        return {"layers_per_group": k, "subs": ["attn"] * (k - 1) + ["cross"]}
    if cfg.family == "ssm":        # xlstm
        k = cfg.slstm_every or cfg.n_layers
        k = min(k, cfg.n_layers)
        return {"layers_per_group": k,
                "subs": ["mlstm"] * (k - 1) + ["slstm"]}
    if cfg.family == "hybrid":     # zamba2
        k = cfg.shared_attn_every
        return {"layers_per_group": k, "subs": ["mamba"] * k + ["shared"]}
    raise ValueError(cfg.family)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _init_tf_layer(key, cfg: ModelConfig, *, is_moe: bool, post_norm=False):
    dt = _dtype(cfg)
    k1, k2 = jax.random.split(key)
    p = {
        "ln_attn": L.init_rmsnorm(cfg.d_model, dt),
        "attn": L.init_attention(k1, cfg.d_model, cfg.n_heads,
                                 cfg.n_kv_heads, cfg.resolved_head_dim, dt),
        "ln_mlp": L.init_rmsnorm(cfg.d_model, dt),
    }
    if post_norm:
        p["ln_attn_post"] = L.init_rmsnorm(cfg.d_model, dt)
        p["ln_mlp_post"] = L.init_rmsnorm(cfg.d_model, dt)
    if is_moe:
        p["moe"] = MOE.init_moe(k2, cfg.d_model, cfg.d_expert,
                                cfg.n_experts, dt)
    else:
        p["mlp"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff, dt)
    return p


def init_group(key, cfg: ModelConfig) -> dict:
    lay = group_layout(cfg)
    dt = _dtype(cfg)
    p: dict = {}
    keys = jax.random.split(key, len(lay["subs"]) + 1)
    gemma = cfg.attn_pattern == "gemma2_alt"
    for i, sub in enumerate(lay["subs"]):
        k = keys[i]
        if sub in ("attn", "attn_local", "attn_global", "cross"):
            p[f"{sub}_{i}"] = _init_tf_layer(
                k, cfg, is_moe=cfg.family == "moe", post_norm=gemma)
            if sub == "cross":
                # cross-attention has its own kv projections over image tokens
                p[f"{sub}_{i}"]["ln_xattn"] = L.init_rmsnorm(cfg.d_model, dt)
        elif sub == "mlstm":
            p[f"{sub}_{i}"] = {"ln": L.init_rmsnorm(cfg.d_model, dt),
                               "core": XL.init_mlstm(k, cfg.d_model,
                                                     cfg.n_heads, dt)}
        elif sub == "slstm":
            p[f"{sub}_{i}"] = {"ln": L.init_rmsnorm(cfg.d_model, dt),
                               "core": XL.init_slstm(k, cfg.d_model,
                                                     cfg.n_heads, dt)}
        elif sub == "mamba":
            p[f"{sub}_{i}"] = {"ln": L.init_rmsnorm(cfg.d_model, dt),
                               "core": M2.init_mamba2(k, cfg.d_model,
                                                      cfg.n_heads,
                                                      cfg.ssm_state, dt,
                                                      expand=cfg.ssm_expand)}
        elif sub == "shared":
            pass  # shared params live outside the stacked groups
    return p


def init_shared(key, cfg: ModelConfig) -> dict:
    """Non-stacked shared params (zamba2 shared attention block)."""
    if cfg.family == "hybrid" and cfg.shared_attn_every:
        return {"shared_attn": _init_tf_layer(key, cfg, is_moe=False)}
    return {}


def init_stacked(key, cfg: ModelConfig) -> tuple[dict, dict]:
    g = n_groups(cfg)
    keys = jax.random.split(key, g)
    stacked = jax.vmap(lambda k: init_group(k, cfg))(keys)
    shared = init_shared(jax.random.fold_in(key, 987), cfg)
    return stacked, shared


# ---------------------------------------------------------------------------
# forward (train / prefill) — group body
# ---------------------------------------------------------------------------
def _tf_layer_fwd(x, p, cfg: ModelConfig, *, window=0, softcap=None,
                  kv_override=None, causal=True, positions=None,
                  collect_kv=False, mesh=None, dp_axes=("data",)):
    gemma = cfg.attn_pattern == "gemma2_alt"
    h = L.rms_norm(x, p["ln_attn"], cfg.norm_eps)
    attn_out, kv = L.attention(
        h, p["attn"], n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
        head_dim=cfg.resolved_head_dim, causal=causal, window=window,
        softcap=softcap, rope_theta=cfg.rope_theta, positions=positions,
        kv_chunk=cfg.kv_chunk, kv_override=kv_override)
    if gemma:
        attn_out = L.rms_norm(attn_out, p["ln_attn_post"], cfg.norm_eps)
    x = x + attn_out
    h = L.rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    aux = 0.0
    if "moe" in p:
        if mesh is not None:
            # §Perf A1: explicit all_to_all expert parallelism
            mlp_out, aux = MOE.moe_ffn_a2a(
                h, p["moe"], top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor, act=cfg.act,
                mesh=mesh, dp_axes=dp_axes)
        else:
            mlp_out, aux = MOE.moe_ffn(h, p["moe"], top_k=cfg.top_k,
                                       capacity_factor=cfg.capacity_factor,
                                       act=cfg.act)
    else:
        mlp_out = L.mlp(h, p["mlp"], act=cfg.act)
    if gemma:
        mlp_out = L.rms_norm(mlp_out, p["ln_mlp_post"], cfg.norm_eps)
    x = x + mlp_out
    return x, aux, (kv if collect_kv else None)


def group_fwd(x, gp, cfg: ModelConfig, shared: dict, *,
              image_embeds=None, collect_kv: bool = False, mesh=None,
              dp_axes=("data",)):
    """One group forward. Returns (x, aux_loss, cache_dict).

    cache_dict (when collect_kv) uses the same keys as group_cache() so a
    prefill can hand its stacked ys directly to the decoder.
    """
    lay = group_layout(cfg)
    aux_total = 0.0
    kvs: dict[str, Any] = {}
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    for i, sub in enumerate(lay["subs"]):
        name = f"{sub}_{i}"
        if sub in ("attn", "attn_global", "attn_local"):
            window = cfg.window if sub == "attn_local" else 0
            x, aux, kv = _tf_layer_fwd(
                x, gp[name], cfg, window=window,
                softcap=cfg.softcap_attn or None, collect_kv=collect_kv,
                mesh=mesh, dp_axes=dp_axes)
            aux_total += aux
            if collect_kv:
                # store (B, Hkv, S, D) — the decode cache layout (§Perf B5)
                kvs[f"k_{name}"] = jnp.swapaxes(kv[0], 1, 2)
                kvs[f"v_{name}"] = jnp.swapaxes(kv[1], 1, 2)
        elif sub == "cross":
            t_img = image_embeds.shape[1]
            kimg = (image_embeds @ gp[name]["attn"]["wk"]).reshape(
                b, t_img, cfg.n_kv_heads, hd)
            vimg = (image_embeds @ gp[name]["attn"]["wv"]).reshape(
                b, t_img, cfg.n_kv_heads, hd)
            x, aux, _ = _tf_layer_fwd(
                x, gp[name], cfg, kv_override=(kimg, vimg), causal=False)
            aux_total += aux
            if collect_kv:
                kvs["k_cross"] = jnp.swapaxes(kimg, 1, 2)
                kvs["v_cross"] = jnp.swapaxes(vimg, 1, 2)
        elif sub == "mlstm":
            h = L.rms_norm(x, gp[name]["ln"], cfg.norm_eps)
            y = XL.mlstm_block(h, gp[name]["core"], n_heads=cfg.n_heads,
                               chunk=cfg.ssm_chunk, return_state=collect_kv)
            if collect_kv:
                y, st = y
                (kvs[f"C_{name}"], kvs[f"n_{name}"],
                 kvs[f"m_{name}"]) = st
            x = x + y
        elif sub == "slstm":
            h = L.rms_norm(x, gp[name]["ln"], cfg.norm_eps)
            # §Perf C1/C2: sequential recurrence runs inside shard_map
            y = XL.slstm_block(h, gp[name]["core"], n_heads=cfg.n_heads,
                               return_state=collect_kv, mesh=mesh,
                               dp_axes=dp_axes)
            if collect_kv:
                y, st = y
                (kvs[f"c_{name}"], kvs[f"n_{name}"], kvs[f"h_{name}"],
                 kvs[f"m_{name}"]) = st
            x = x + y
        elif sub == "mamba":
            h = L.rms_norm(x, gp[name]["ln"], cfg.norm_eps)
            y = M2.mamba2_block(h, gp[name]["core"], n_heads=cfg.n_heads,
                                d_state=cfg.ssm_state, chunk=cfg.ssm_chunk,
                                expand=cfg.ssm_expand,
                                return_state=collect_kv)
            if collect_kv:
                y, st = y
                kvs[f"ssm_{name}"] = st
            x = x + y
        elif sub == "shared":
            x, aux, kv = _tf_layer_fwd(
                x, shared["shared_attn"], cfg, collect_kv=collect_kv)
            aux_total += aux
            if collect_kv:
                kvs[f"k_shared_{i}"] = jnp.swapaxes(kv[0], 1, 2)
                kvs[f"v_shared_{i}"] = jnp.swapaxes(kv[1], 1, 2)
    return x, aux_total, kvs


# ---------------------------------------------------------------------------
# decode — group body (single token, recurrent/cached)
# ---------------------------------------------------------------------------
def _attn_decode(x, p, cfg: ModelConfig, cache_k, cache_v, pos, length, *,
                 window=0, softcap=None, mode="far", mesh=None,
                 dp_axes=("data",), kv_override_cache=None):
    """Single-token attention against a cache.

    x: (B, 1, d). cache_k/v: (B, Hkv, S_max, Dh) — stored PRE-TRANSPOSED
    (§Perf B5) so the QK^T and PV dots consume the cache directly; the
    (B,S,H,D) layout cost a full transpose-copy of the cache per layer per
    step. Returns (out, ck, cv).
    mode: far (shard_map push-down) | naive (shard_map fetch) |
          local (heads-TP, GSPMD only).
    """
    b = x.shape[0]
    hd = cfg.resolved_head_dim
    h = L.rms_norm(x, p["ln_attn"], cfg.norm_eps)
    q = (h @ p["attn"]["wq"]).reshape(b, 1, cfg.n_heads, hd)
    pos_arr = jnp.full((b, 1), pos, jnp.int32)
    q = L.rope(q, pos_arr, theta=cfg.rope_theta)[:, 0]        # (B, Hq, Dh)
    append = kv_override_cache is None
    if append:
        k_new = (h @ p["attn"]["wk"]).reshape(b, 1, cfg.n_kv_heads, hd)
        v_new = (h @ p["attn"]["wv"]).reshape(b, 1, cfg.n_kv_heads, hd)
        k_new = L.rope(k_new, pos_arr, theta=cfg.rope_theta)
        k_row = jnp.swapaxes(k_new, 1, 2)       # (B, Hkv, 1, Dh)
        v_row = jnp.swapaxes(v_new, 1, 2)
        glen = jnp.maximum(length, pos + 1) * jnp.ones((b,), jnp.int32)
    else:
        cache_k, cache_v = kv_override_cache
        glen = length * jnp.ones((b,), jnp.int32)

    if window and window > 0:
        lo = jnp.maximum(0, pos + 1 - window)
    else:
        lo = 0

    scale = 1.0 / math.sqrt(hd)

    if mode in ("far", "naive") and mesh is not None:
        from repro.core import far_kv
        from jax.sharding import PartitionSpec as P

        def sm(qr, kn, vn, ck, cv, gl, lo_):
            # ck/cv: (B_loc, Hkv, S_loc, Dh) — this device's pool shard.
            b_loc = ck.shape[0]
            s_loc = ck.shape[2]
            start = jax.lax.axis_index("model") * s_loc
            if append:
                # §Perf B3: the append touches exactly ONE cache row on the
                # owning shard (predicated 1-row DUS). Appending at the
                # GSPMD level instead made the partitioner rewrite the
                # whole local slice through a masked select every step.
                off = jnp.clip(pos - start, 0, s_loc - 1)
                in_range = (pos >= start) & (pos < start + s_loc)
                cur_k = jax.lax.dynamic_slice(
                    ck, (0, 0, off, 0), (b_loc, ck.shape[1], 1, hd))
                cur_v = jax.lax.dynamic_slice(
                    cv, (0, 0, off, 0), (b_loc, cv.shape[1], 1, hd))
                row_k = jnp.where(in_range, kn.astype(ck.dtype), cur_k)
                row_v = jnp.where(in_range, vn.astype(cv.dtype), cur_v)
                ck = jax.lax.dynamic_update_slice(ck, row_k, (0, 0, off, 0))
                cv = jax.lax.dynamic_update_slice(cv, row_v, (0, 0, off, 0))
            if mode == "naive":
                # RCPU: fetch raw KV rows, then attend locally
                ckf = jax.lax.all_gather(ck, "model", axis=2, tiled=True)
                cvf = jax.lax.all_gather(cv, "model", axis=2, tiled=True)
                o, m, l = _partial_attention_window(
                    qr, ckf, cvf, gl, lo_, 0, scale, softcap)
                return (o / jnp.maximum(l, 1e-30)[..., None], ck, cv)
            # FV: partials at the shard owner, ship only (o, m, l)
            o, m, l = _partial_attention_window(
                qr, ck, cv, gl, lo_, start, scale, softcap)
            return (far_kv.merge_partials_named(o, m, l, "model"), ck, cv)

        lo_arr = lo * jnp.ones((b,), jnp.int32)
        kn = k_row if append else jnp.zeros((b, cache_k.shape[1], 1, hd),
                                            cache_k.dtype)
        vn = v_row if append else kn
        # check_vma=False: the naive path's all_gather output is replicated
        # over "model" mathematically but not statically inferable.
        attn, cache_k, cache_v = shard_map(
            sm, mesh=mesh,
            in_specs=(P(dp_axes), P(dp_axes), P(dp_axes),
                      P(dp_axes, None, "model"), P(dp_axes, None, "model"),
                      P(dp_axes), P(dp_axes)),
            out_specs=(P(dp_axes), P(dp_axes, None, "model"),
                       P(dp_axes, None, "model")),
            check_vma=False)(q, kn, vn, cache_k, cache_v, glen, lo_arr)
    else:
        # local/GSPMD path: plain masked attention over the whole cache
        # (same MXU-native dtype discipline as the far path — no f32 cache
        # copies; see _partial_attention_window)
        if append:
            cache_k = jax.lax.dynamic_update_slice(
                cache_k, k_row.astype(cache_k.dtype), (0, 0, pos, 0))
            cache_v = jax.lax.dynamic_update_slice(
                cache_v, v_row.astype(cache_v.dtype), (0, 0, pos, 0))
        s_max = cache_k.shape[2]
        kpos = jnp.arange(s_max)
        valid = (kpos[None] < glen[:, None]) & (kpos[None] >= lo)
        g = cfg.n_heads // cfg.n_kv_heads
        qc = q.astype(cache_k.dtype).reshape(b, cfg.n_kv_heads, g, hd)
        scores = jnp.einsum("bhgd,bhsd->bhgs", qc, cache_k, optimize=True,
                            preferred_element_type=jnp.float32) * scale
        if softcap:
            scores = jnp.tanh(scores / softcap) * softcap
        scores = jnp.where(valid[:, None, None], scores, -1e30)
        w = jax.nn.softmax(scores, axis=-1)
        attn = jnp.einsum("bhgs,bhsd->bhgd", w.astype(cache_v.dtype),
                          cache_v, optimize=True,
                          preferred_element_type=jnp.float32)
        attn = attn.reshape(b, cfg.n_heads, hd)

    out = attn.reshape(b, -1).astype(x.dtype) @ p["attn"]["wo"]
    if cfg.attn_pattern == "gemma2_alt":
        out = L.rms_norm(out, p["ln_attn_post"], cfg.norm_eps)
    x = x + out[:, None]
    # mlp
    h = L.rms_norm(x, p["ln_mlp"], cfg.norm_eps)
    if "moe" in p:
        # decode has few tokens; generous capacity avoids routing drops
        mlp_out, _ = MOE.moe_ffn(h, p["moe"], top_k=cfg.top_k,
                                 capacity_factor=4.0, act=cfg.act)
    else:
        mlp_out = L.mlp(h, p["mlp"], act=cfg.act)
    if cfg.attn_pattern == "gemma2_alt":
        mlp_out = L.rms_norm(mlp_out, p["ln_mlp_post"], cfg.norm_eps)
    x = x + mlp_out
    return x, cache_k, cache_v


def _partial_attention_window(q, k, v, glen, lo, start, scale, softcap=None):
    """partial_attention with a lower-bound position mask (sliding window).

    k/v: (B, Hkv, S, Dh) — the §Perf B5 pre-transposed cache layout, so the
    dots consume the cache with no transpose copy.

    MXU-native numerics: QK^T and PV consume the cache in its STORED dtype
    (bf16 on the wire) with f32 accumulation via preferred_element_type —
    never materializing an f32 copy of the cache slice. §Perf B1: the f32
    `.astype` copies made XLA carry an f32 scan accumulator for the whole
    stacked cache (6 full-cache HBM passes per decode step instead of 1).
    """
    b, hq, d = q.shape
    hkv, s = k.shape[1], k.shape[2]
    g = hq // hkv
    qc = q.astype(k.dtype).reshape(b, hkv, g, d)
    scores = jnp.einsum("bhgd,bhsd->bhgs", qc, k, optimize=True,
                        preferred_element_type=jnp.float32) * scale
    if softcap:
        scores = jnp.tanh(scores / softcap) * softcap
    kpos = start + jnp.arange(s)
    valid = (kpos[None] < glen[:, None]) & (kpos[None] >= lo[:, None])
    scores = jnp.where(valid[:, None, None], scores, -1e30)
    m = jnp.max(scores, axis=-1)
    p = jnp.where(valid[:, None, None], jnp.exp(scores - m[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgs,bhsd->bhgd", p.astype(k.dtype), v, optimize=True,
                   preferred_element_type=jnp.float32)
    return (o.reshape(b, hq, d), m.reshape(b, hq), l.reshape(b, hq))


def group_dec(x, gp, cache, cfg: ModelConfig, shared: dict, pos, length, *,
              mode="far", mesh=None, dp_axes=("data",)):
    """Single-token decode through one group. cache: dict of per-sub states."""
    lay = group_layout(cfg)
    new_cache = dict(cache)
    for i, sub in enumerate(lay["subs"]):
        name = f"{sub}_{i}"
        if sub in ("attn", "attn_global", "attn_local"):
            window = cfg.window if sub == "attn_local" else 0
            x, ck, cv = _attn_decode(
                x, gp[name], cfg, cache[f"k_{name}"], cache[f"v_{name}"],
                pos, length, window=window,
                softcap=cfg.softcap_attn or None, mode=mode, mesh=mesh,
                dp_axes=dp_axes)
            new_cache[f"k_{name}"] = ck
            new_cache[f"v_{name}"] = cv
        elif sub == "cross":
            x, _, _ = _attn_decode(
                x, gp[name], cfg, cache["k_cross"], cache["v_cross"],
                pos, cfg.n_image_tokens, mode="local", mesh=mesh,
                dp_axes=dp_axes,
                kv_override_cache=(cache["k_cross"], cache["v_cross"]))
        elif sub == "mlstm":
            h = L.rms_norm(x, gp[name]["ln"], cfg.norm_eps)
            y, st = XL.mlstm_decode_step(
                h[:, 0], gp[name]["core"],
                (cache[f"C_{name}"], cache[f"n_{name}"], cache[f"m_{name}"]),
                n_heads=cfg.n_heads)
            x = x + y[:, None]
            (new_cache[f"C_{name}"], new_cache[f"n_{name}"],
             new_cache[f"m_{name}"]) = st
        elif sub == "slstm":
            h = L.rms_norm(x, gp[name]["ln"], cfg.norm_eps)
            y, st = XL.slstm_decode_step(
                h[:, 0], gp[name]["core"],
                (cache[f"c_{name}"], cache[f"n_{name}"],
                 cache[f"h_{name}"], cache[f"m_{name}"]),
                n_heads=cfg.n_heads)
            x = x + y[:, None]
            (new_cache[f"c_{name}"], new_cache[f"n_{name}"],
             new_cache[f"h_{name}"], new_cache[f"m_{name}"]) = st
        elif sub == "mamba":
            h = L.rms_norm(x, gp[name]["ln"], cfg.norm_eps)
            y, st = M2.mamba2_decode_step(
                h[:, 0], gp[name]["core"], cache[f"ssm_{name}"],
                n_heads=cfg.n_heads, d_state=cfg.ssm_state,
                expand=cfg.ssm_expand)
            x = x + y[:, None]
            new_cache[f"ssm_{name}"] = st
        elif sub == "shared":
            x, ck, cv = _attn_decode(
                x, shared["shared_attn"], cfg, cache[f"k_shared_{i}"],
                cache[f"v_shared_{i}"], pos, length, mode=mode, mesh=mesh,
                dp_axes=dp_axes)
            new_cache[f"k_shared_{i}"] = ck
            new_cache[f"v_shared_{i}"] = cv
    return x, new_cache


# ---------------------------------------------------------------------------
# cache init
# ---------------------------------------------------------------------------
def group_cache(cfg: ModelConfig, batch: int, max_seq: int,
                kv_dtype=jnp.bfloat16) -> dict:
    lay = group_layout(cfg)
    hd = cfg.resolved_head_dim
    c: dict = {}
    for i, sub in enumerate(lay["subs"]):
        name = f"{sub}_{i}"
        if sub in ("attn", "attn_global", "attn_local"):
            # (B, Hkv, S, Dh): pre-transposed for the decode dots (§Perf B5)
            shape = (batch, cfg.n_kv_heads, max_seq, hd)
            c[f"k_{name}"] = jnp.zeros(shape, kv_dtype)
            c[f"v_{name}"] = jnp.zeros(shape, kv_dtype)
        elif sub == "cross":
            shape = (batch, cfg.n_kv_heads, cfg.n_image_tokens, hd)
            c["k_cross"] = jnp.zeros(shape, kv_dtype)
            c["v_cross"] = jnp.zeros(shape, kv_dtype)
        elif sub == "mlstm":
            C, n, m = XL.mlstm_init_state(batch, cfg.d_model, cfg.n_heads)
            c[f"C_{name}"], c[f"n_{name}"], c[f"m_{name}"] = C, n, m
        elif sub == "slstm":
            cc, n, h, m = XL.slstm_init_state(batch, cfg.d_model, cfg.n_heads)
            (c[f"c_{name}"], c[f"n_{name}"], c[f"h_{name}"],
             c[f"m_{name}"]) = cc, n, h, m
        elif sub == "mamba":
            c[f"ssm_{name}"] = M2.mamba2_init_state(
                batch, cfg.d_model, cfg.n_heads, cfg.ssm_state,
                expand=cfg.ssm_expand)
        elif sub == "shared":
            shape = (batch, cfg.n_kv_heads, max_seq, hd)
            c[f"k_shared_{i}"] = jnp.zeros(shape, kv_dtype)
            c[f"v_shared_{i}"] = jnp.zeros(shape, kv_dtype)
    return c
