"""xLSTM blocks: mLSTM (matrix memory) + sLSTM (scalar memory).

Follows arXiv:2405.04517. The mLSTM is computed *chunkwise* (TPU-native:
the within-chunk part is causal linear attention on the MXU; the cross-chunk
part is a short lax.scan over chunk states), with the paper's exponential
input gate / log-sigmoid forget gate stabilized by a running max m_t.

The sLSTM has true sequential recurrence (hidden-to-hidden weights), so it
scans over time; xLSTM-125m uses it in a minority of blocks (pattern set by
config), so the scan does not dominate step cost.

Decode: both blocks are recurrent; their state tuple is the "cache" (O(1)
per token — no KV pool; DESIGN.md notes far-KV inapplicability for this
family).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.jax_compat import shard_map
from repro.models.layers import dense_init, init_rmsnorm, rms_norm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def init_mlstm(key, d_model, n_heads, dtype, *, proj_factor: float = 2.0):
    dh = int(d_model * proj_factor) // n_heads
    d_inner = dh * n_heads
    ks = jax.random.split(key, 8)
    return {
        "w_up": dense_init(ks[0], d_model, 2 * d_inner, dtype),
        "w_q": dense_init(ks[1], d_inner, d_inner, dtype),
        "w_k": dense_init(ks[2], d_inner, d_inner, dtype),
        "w_v": dense_init(ks[3], d_inner, d_inner, dtype),
        "w_i": dense_init(ks[4], d_inner, n_heads, dtype),
        "w_f": dense_init(ks[5], d_inner, n_heads, dtype),
        "w_o": dense_init(ks[6], d_inner, d_model, dtype,
                          scale=1.0 / math.sqrt(d_inner)),
        "norm": init_rmsnorm(d_inner, dtype),
        "skip": dense_init(ks[7], d_inner, d_inner, dtype),
    }


def _mlstm_chunk_scan(q, k, v, log_f, log_i, chunk: int):
    """Stabilized chunkwise mLSTM scan.

    q/k/v: (B, S, H, Dh); log_f/log_i: (B, S, H). Returns (B, S, H, Dh).
    State: C (B, H, Dh, Dh), n (B, H, Dh), m (B, H).
    """
    b, s, h, dh = q.shape
    nc = max(1, s // chunk)
    cs = s // nc
    qc = q.reshape(b, nc, cs, h, dh)
    kc = k.reshape(b, nc, cs, h, dh)
    vc = v.reshape(b, nc, cs, h, dh)
    lf = log_f.reshape(b, nc, cs, h).astype(jnp.float32)
    li = log_i.reshape(b, nc, cs, h).astype(jnp.float32)

    # within-chunk cumulative forget
    lf_cum = jnp.cumsum(lf, axis=2)                      # (B, nc, cs, H)
    lf_tot = lf_cum[:, :, -1]                            # (B, nc, H)

    def step(carry, inp):
        C, n, m = carry                                  # (B,H,Dh,Dh),(B,H,Dh),(B,H)
        # §Perf C4: the inter-chunk state is CARRIED in bf16 (math in f32).
        # scan saves every per-chunk carry for the backward; in f32 those
        # saves alone exceed the 16 GiB HBM budget at train_4k (18.4 GiB
        # temp measured). bf16 halves them; the normalizer n and max m stay
        # f32 (they carry the numerical conditioning).
        C = C.astype(jnp.float32)
        qb, kb, vb, lfc, lit, lft = inp
        # Stabilizer covering the state update's exponent range; the output
        # y is invariant to the exact m (it cancels), so a per-chunk m that
        # upper-bounds the kv weights is sufficient (xLSTM App. stabilized).
        m_kv = jnp.max(lft[:, None] - lfc + lit, axis=1)  # (B, H)
        m_new = jnp.maximum(m + lft, m_kv)

        # inter-chunk: y_i += q_i @ C * exp(lfc_i + m - m_new)
        # §Perf C5: dots consume q and the carried state in the stream
        # dtype (bf16) with f32 accumulation — the f32 `.astype` versions
        # made XLA materialize full-sequence f32 copies of the stacked
        # q/k/v scan inputs (0.38 GiB each, the top temp-memory holders).
        w_inter = jnp.exp(lfc + m[:, None] - m_new[:, None])   # (B, cs, H)
        y_inter = jnp.einsum("bchd,bhde->bche", qb, C.astype(qb.dtype),
                             optimize=True,
                             preferred_element_type=jnp.float32
                             ) * w_inter[..., None]
        n_inter = jnp.einsum("bchd,bhd->bch", qb, n.astype(qb.dtype),
                             optimize=True,
                             preferred_element_type=jnp.float32) * w_inter

        # intra-chunk: D[i,j] = exp(lfc_i - lfc_j + li_j - m_new), causal
        lw = (lfc[:, :, None, :] - lfc[:, None, :, :]
              + lit[:, None, :, :] - m_new[:, None, None, :])  # (B, ci, cj, H)
        causal = jnp.tril(jnp.ones((cs, cs), bool))
        # mask BEFORE exp: non-causal exponents overflow and poison the
        # backward with inf*0 (see mamba2.ssd_chunk_scan) — this was the
        # source of the xlstm train NaN-gradient events
        lw = jnp.where(causal[None, :, :, None], lw, -1e30)
        D = jnp.exp(lw)
        # §Perf C3: MXU-native — dots consume q/k/v in their stored dtype
        # with f32 accumulation; the decay-weighted A casts to the value
        # dtype for the PV dot (flash-attention-style p handling).
        scores = jnp.einsum("bchd,bkhd->bckh", qb, kb, optimize=True,
                            preferred_element_type=jnp.float32)
        A = scores * D                                     # (B, ci, cj, H)
        y_intra = jnp.einsum("bckh,bkhd->bchd", A.astype(vb.dtype), vb,
                             optimize=True,
                             preferred_element_type=jnp.float32)
        n_intra = jnp.sum(A, axis=2)                       # (B, ci, H)

        y = y_inter + y_intra
        n_i = n_inter + n_intra
        denom = jnp.maximum(jnp.abs(n_i), jnp.exp(-m_new)[:, None])
        y = y / denom[..., None]

        # state update: C' = exp(lft + m - m_new) C + sum_j exp(lft-lfc_j+li_j-m_new) k_j v_j^T
        w_c = jnp.exp(lft + m - m_new)                     # (B, H)
        w_kv = jnp.exp(lft[:, None] - lfc + lit - m_new[:, None])  # (B, cs, H)
        kbw = (kb.astype(jnp.float32) * w_kv[..., None]).astype(vb.dtype)
        kv = jnp.einsum("bchd,bche->bhde", kbw, vb, optimize=True,
                        preferred_element_type=jnp.float32)
        C_new = C * w_c[..., None, None] + kv
        n_add = jnp.einsum("bchd,bch->bhd", kb,
                           w_kv.astype(kb.dtype), optimize=True,
                           preferred_element_type=jnp.float32)
        n_new = n * w_c[..., None] + n_add
        return (C_new.astype(jnp.bfloat16), n_new, m_new), y

    C0 = jnp.zeros((b, h, dh, dh), jnp.bfloat16)
    n0 = jnp.zeros((b, h, dh), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)
    xs = (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(kc, 1, 0),
          jnp.moveaxis(vc, 1, 0), jnp.moveaxis(lf_cum, 1, 0),
          jnp.moveaxis(li, 1, 0), jnp.moveaxis(lf_tot, 1, 0))
    (Cf, nf, mf), ys = jax.lax.scan(step, (C0, n0, m0), xs)
    final = (Cf.astype(jnp.float32), nf, mf)
    return jnp.moveaxis(ys, 0, 1).reshape(b, s, h, dh), final


def mlstm_block(x, p, *, n_heads: int, chunk: int = 256,
                return_state: bool = False):
    """x: (B, S, d_model) -> (B, S, d_model). Pre-norm residual outside."""
    b, s, d = x.shape
    up = x @ p["w_up"]
    xi, gate = jnp.split(up, 2, axis=-1)
    d_inner = xi.shape[-1]
    dh = d_inner // n_heads
    q = (xi @ p["w_q"]).reshape(b, s, n_heads, dh)
    k = ((xi @ p["w_k"]) / math.sqrt(dh)).reshape(b, s, n_heads, dh)
    v = (xi @ p["w_v"]).reshape(b, s, n_heads, dh)
    log_i = (xi @ p["w_i"]).astype(jnp.float32)            # (B, S, H)
    log_f = jax.nn.log_sigmoid((xi @ p["w_f"]).astype(jnp.float32))
    h, final_state = _mlstm_chunk_scan(q, k, v, log_f, log_i, chunk)
    h = h.reshape(b, s, d_inner).astype(x.dtype)
    h = rms_norm(h, p["norm"]) + xi @ p["skip"]
    h = h * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype)
    out = h @ p["w_o"]
    return (out, final_state) if return_state else out


def mlstm_decode_step(x, p, state, *, n_heads: int):
    """Single-token recurrent step. state = (C, n, m)."""
    b, d = x.shape
    up = x @ p["w_up"]
    xi, gate = jnp.split(up, 2, axis=-1)
    d_inner = xi.shape[-1]
    dh = d_inner // n_heads
    q = (xi @ p["w_q"]).reshape(b, n_heads, dh).astype(jnp.float32)
    k = ((xi @ p["w_k"]) / math.sqrt(dh)).reshape(b, n_heads, dh).astype(jnp.float32)
    v = (xi @ p["w_v"]).reshape(b, n_heads, dh).astype(jnp.float32)
    log_i = (xi @ p["w_i"]).astype(jnp.float32)            # (B, H)
    log_f = jax.nn.log_sigmoid((xi @ p["w_f"]).astype(jnp.float32))
    C, n, m = state
    m_new = jnp.maximum(log_f + m, log_i)
    wf = jnp.exp(log_f + m - m_new)
    wi = jnp.exp(log_i - m_new)
    C = C * wf[..., None, None] + jnp.einsum("bhd,bhe->bhde", k * wi[..., None], v)
    n = n * wf[..., None] + k * wi[..., None]
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q, n)),
                      jnp.exp(-m_new))
    h = (num / den[..., None]).reshape(b, d_inner).astype(x.dtype)
    h = rms_norm(h, p["norm"]) + xi @ p["skip"]
    h = h * jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype)
    return h @ p["w_o"], (C, n, m_new)


def mlstm_init_state(batch, d_model, n_heads, *, proj_factor: float = 2.0):
    d_inner = int(d_model * proj_factor)
    dh = d_inner // n_heads
    return (jnp.zeros((batch, n_heads, dh, dh), jnp.float32),
            jnp.zeros((batch, n_heads, dh), jnp.float32),
            jnp.full((batch, n_heads), -1e30, jnp.float32))


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def init_slstm(key, d_model, n_heads, dtype):
    dh = d_model // n_heads
    ks = jax.random.split(key, 10)
    p = {"norm": init_rmsnorm(d_model, dtype)}
    for i, g in enumerate(("i", "f", "z", "o")):
        p[f"w_{g}"] = dense_init(ks[i], d_model, d_model, dtype)
        # block-diagonal recurrent weights: per-head (dh, dh)
        p[f"r_{g}"] = (jax.random.normal(ks[4 + i], (n_heads, dh, dh),
                                         jnp.float32) / math.sqrt(dh)).astype(dtype)
    p["w_out"] = dense_init(ks[8], d_model, d_model, dtype)
    return p


def _slstm_scan(pre_t, r_i, r_f, r_z, r_o, n_heads: int):
    """The sequential gate recurrence. pre_t: 4-tuple of (S, B, H, dh)."""
    recs = {"i": r_i, "f": r_f, "z": r_z, "o": r_o}

    def step(carry, xs_t):
        c, n, h, m = carry                   # (B,H,dh) x3, (B,H)
        pi, pf, pz, po = xs_t
        gates = {}
        for g, pg in (("i", pi), ("f", pf), ("z", pz), ("o", po)):
            rec = jnp.einsum("bhd,hde->bhe", h, recs[g].astype(jnp.float32))
            gates[g] = pg.astype(jnp.float32) + rec
        log_i = jnp.mean(gates["i"], axis=-1)               # per-head gate
        log_f = jax.nn.log_sigmoid(jnp.mean(gates["f"], axis=-1))
        m_new = jnp.maximum(log_f + m, log_i)
        wi = jnp.exp(log_i - m_new)[..., None]
        wf = jnp.exp(log_f + m - m_new)[..., None]
        z = jnp.tanh(gates["z"])
        o = jax.nn.sigmoid(gates["o"])
        c_new = wf * c + wi * z
        n_new = wf * n + wi
        h_new = o * c_new / jnp.maximum(n_new, 1e-6)
        return (c_new, n_new, h_new, m_new), h_new

    b = pre_t[0].shape[1]
    dh = pre_t[0].shape[3]
    z0 = jnp.zeros((b, n_heads, dh), jnp.float32)
    carry0 = (z0, z0, z0, jnp.full((b, n_heads), -1e30, jnp.float32))
    return jax.lax.scan(step, carry0, pre_t)


def slstm_block(x, p, *, n_heads: int, return_state: bool = False,
                mesh=None, dp_axes=("data",)):
    """Sequential scan over time. x: (B, S, d) -> (B, S, d).

    §Perf C1/C2: a strict h->h recurrence cannot be sequence-sharded —
    every step t needs step t-1. Two bad lowerings were measured on
    xlstm-125m train_4k before this form:
      * closing over the seq-sharded (B,S,d) buffer and indexing per step
        -> GSPMD full-local-buffer masked select every timestep
        (~600 GB/device/step, 75%% of the whole train step);
      * replicating via with_sharding_constraint under GSPMD -> correct
        forward, but the backward emitted a per-TIMESTEP all-reduce of the
        recurrent-weight gradients (54 GiB/step).
    Under a mesh the scan therefore runs inside shard_map: the gate
    pre-activations are all_gathered over "model" ONCE, the recurrence is
    computed redundantly on every model-axis device (its FLOPs are tiny),
    the output is sliced back to the local sequence chunk, and the
    recurrent-weight gradients psum ONCE at the region boundary.
    """
    b, s, d = x.shape
    dh = d // n_heads
    pre = {g: x @ p[f"w_{g}"] for g in ("i", "f", "z", "o")}

    if mesh is None:
        pre_t = tuple(
            jnp.moveaxis(pre[g].reshape(b, s, n_heads, dh), 1, 0)
            for g in ("i", "f", "z", "o"))
        final, hs = _slstm_scan(pre_t, p["r_i"], p["r_f"], p["r_z"],
                                p["r_o"], n_heads)
        h = jnp.moveaxis(hs, 0, 1).reshape(b, s, d).astype(x.dtype)
        out = rms_norm(h, p["norm"]) @ p["w_out"]
        return (out, final) if return_state else out

    from jax.sharding import PartitionSpec as P
    dpa = tuple(dp_axes) if dp_axes else ()

    def sm(pi, pf, pz, po, ri, rf, rz, ro):
        # each (B_loc, S_loc, d): gather the full sequence once
        full = [jax.lax.all_gather(v, "model", axis=1, tiled=True)
                for v in (pi, pf, pz, po)]
        bl, sf = full[0].shape[0], full[0].shape[1]
        pre_t = tuple(jnp.moveaxis(v.reshape(bl, sf, n_heads, dh), 1, 0)
                      for v in full)
        final, hs = _slstm_scan(pre_t, ri, rf, rz, ro, n_heads)
        hs = jnp.moveaxis(hs, 0, 1)          # (B_loc, S, H, dh)
        s_loc = pi.shape[1]
        idx = jax.lax.axis_index("model") * s_loc
        h_loc = jax.lax.dynamic_slice(
            hs, (0, idx, 0, 0), (bl, s_loc, n_heads, dh))
        return h_loc, final

    args = [pre[g] for g in ("i", "f", "z", "o")]
    args += [p[f"r_{g}"] for g in ("i", "f", "z", "o")]
    h_loc, final = shard_map(
        sm, mesh=mesh,
        in_specs=(P(dpa or None, "model", None),) * 4
        + (P(None, None, None),) * 4,
        out_specs=(P(dpa or None, "model", None, None),
                   jax.tree.map(lambda _: P(dpa or None),
                                (0, 0, 0, 0))),
        check_vma=False)(*args)
    h = h_loc.reshape(b, s, d).astype(x.dtype)
    out = rms_norm(h, p["norm"]) @ p["w_out"]
    return (out, final) if return_state else out


def slstm_decode_step(x, p, state, *, n_heads: int):
    b, d = x.shape
    dh = d // n_heads
    c, n, h, m = state
    gates = {}
    for g in ("i", "f", "z", "o"):
        rec = jnp.einsum("bhd,hde->bhe", h, p[f"r_{g}"].astype(jnp.float32))
        gates[g] = (x @ p[f"w_{g}"]).reshape(b, n_heads, dh).astype(jnp.float32) + rec
    log_i = jnp.mean(gates["i"], axis=-1)
    log_f = jax.nn.log_sigmoid(jnp.mean(gates["f"], axis=-1))
    m_new = jnp.maximum(log_f + m, log_i)
    wi = jnp.exp(log_i - m_new)[..., None]
    wf = jnp.exp(log_f + m - m_new)[..., None]
    z = jnp.tanh(gates["z"])
    o = jax.nn.sigmoid(gates["o"])
    c_new = wf * c + wi * z
    n_new = wf * n + wi
    h_new = o * c_new / jnp.maximum(n_new, 1e-6)
    out = rms_norm(h_new.reshape(b, d).astype(x.dtype), p["norm"]) @ p["w_out"]
    return out, (c_new, n_new, h_new, m_new)


def slstm_init_state(batch, d_model, n_heads):
    dh = d_model // n_heads
    z = jnp.zeros((batch, n_heads, dh), jnp.float32)
    return (z, z, z, jnp.full((batch, n_heads), -1e30, jnp.float32))
