"""Mixture-of-Experts layer with expert parallelism (EP over the model axis).

Farview connection: top-k routing is *selection push-down* — only the tokens
an expert actually needs cross the wire (all-to-all), never the full
activation set. The capacity-factor dispatch below makes the shipped volume
static and auditable in the dry-run HLO (the a2a bytes are the collective
roofline term).

Dispatch is sort-free scatter/gather (no (T, E, C) one-hot tensor — that
formulation is O(T*E*C) memory and dies at 1M tokens):
  1. router logits -> top-k (experts, weights) per token,
  2. rank of each (token, choice) within its expert via one-hot-free
     cumsum-by-sorted-segment,
  3. scatter into (E, C, d) expert buffers (drop beyond capacity),
  4. expert GLU FFN, batched einsum over the E axis (E sharded over "model"),
  5. gather back + weighted combine.
Aux load-balance loss (Switch-style) keeps routing trainable.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.jax_compat import shard_map
from repro.models.layers import dense_init


def init_moe(key, d_model, d_expert, n_experts, dtype, *, router_dtype=jnp.float32):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "router": dense_init(k1, d_model, n_experts, router_dtype),
        "w_gate": (jax.random.normal(k2, (n_experts, d_model, d_expert),
                                     jnp.float32) / math.sqrt(d_model)).astype(dtype),
        "w_up": (jax.random.normal(k3, (n_experts, d_model, d_expert),
                                   jnp.float32) / math.sqrt(d_model)).astype(dtype),
        "w_down": (jax.random.normal(k4, (n_experts, d_expert, d_model),
                                     jnp.float32) / math.sqrt(d_expert)).astype(dtype),
    }


def moe_ffn(x, p, *, top_k: int, capacity_factor: float = 1.25,
            act: str = "silu"):
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar)."""
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    e = p["router"].shape[1]

    logits = (xt.astype(p["router"].dtype) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)                   # (T, E)
    gate_w, gate_e = jax.lax.top_k(probs, top_k)              # (T, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: fraction of tokens per expert x mean router prob
    me = jnp.mean(probs, axis=0)
    ce_frac = jnp.zeros((e,), jnp.float32).at[gate_e.reshape(-1)].add(
        1.0 / (t * top_k))
    aux = e * jnp.sum(me * ce_frac)

    cap = max(1, int(capacity_factor * top_k * t / e))

    # rank within expert: sort flat (expert, arrival) pairs
    flat_e = gate_e.reshape(-1)                               # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    inv = jnp.argsort(order)                                  # undo perm
    sorted_e = flat_e[order]
    seg_start = jnp.concatenate([
        jnp.zeros((1,), jnp.int32),
        (sorted_e[1:] != sorted_e[:-1]).astype(jnp.int32)])
    # position within segment = iota - index of segment start
    idx = jnp.arange(flat_e.shape[0], dtype=jnp.int32)
    start_idx = jnp.where(seg_start == 1, idx, 0)
    start_idx = jax.lax.associative_scan(jnp.maximum, start_idx)
    rank_sorted = idx - start_idx
    rank = rank_sorted[inv].reshape(t, top_k)                 # (T, k)

    keep = rank < cap
    slot = flat_e.reshape(t, top_k) * cap + jnp.where(keep, rank, 0)
    slot = jnp.where(keep, slot, e * cap)                     # OOB -> dropped

    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    tok_idx = jnp.repeat(jnp.arange(t), top_k).reshape(t, top_k)
    buf = buf.at[slot.reshape(-1)].set(xt[tok_idx.reshape(-1)], mode="drop")
    expert_in = buf[:e * cap].reshape(e, cap, d)

    # expert FFN (E-sharded batched einsum; GSPMD turns the reshard into a2a)
    gate = jnp.einsum("ecd,edf->ecf", expert_in, p["w_gate"], optimize=True)
    up = jnp.einsum("ecd,edf->ecf", expert_in, p["w_up"], optimize=True)
    if act == "silu":
        h = jax.nn.silu(gate.astype(jnp.float32)).astype(x.dtype) * up
    else:
        h = jax.nn.gelu(gate.astype(jnp.float32),
                        approximate=True).astype(x.dtype) * up
    expert_out = jnp.einsum("ecf,efd->ecd", h, p["w_down"], optimize=True)

    flat_out = expert_out.reshape(e * cap, d)
    gathered = flat_out[jnp.clip(slot.reshape(-1), 0, e * cap - 1)]
    gathered = jnp.where(keep.reshape(-1, 1), gathered, 0)
    gathered = gathered.reshape(t, top_k, d)
    out = jnp.sum(gathered * gate_w[..., None].astype(x.dtype), axis=1)
    return out.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# explicit expert-parallel MoE: shard_map + all_to_all (§Perf A1)
# ---------------------------------------------------------------------------
def _rank_within(segment_ids, n_segments_hint=None):
    """Arrival rank of each element within its segment id (sort-free)."""
    n = segment_ids.shape[0]
    order = jnp.argsort(segment_ids, stable=True)
    inv = jnp.argsort(order)
    sorted_ids = segment_ids[order]
    seg_start = jnp.concatenate([
        jnp.zeros((1,), jnp.int32),
        (sorted_ids[1:] != sorted_ids[:-1]).astype(jnp.int32)])
    idx = jnp.arange(n, dtype=jnp.int32)
    start_idx = jnp.where(seg_start == 1, idx, 0)
    start_idx = jax.lax.associative_scan(jnp.maximum, start_idx)
    return (idx - start_idx)[inv]


def moe_ffn_a2a(x, p, *, top_k: int, capacity_factor: float = 1.25,
                act: str = "silu", mesh=None, ep_axis: str = "model",
                dp_axes=("data",)):
    """Expert-parallel MoE with EXPLICIT all_to_all dispatch (§Perf A1).

    The dense formulation above is correct under GSPMD but the partitioner
    moves the (E*cap, d) dispatch buffers with all-gathers — measured 70.2s
    of collective time per train step on qwen3-moe (16-way EP, 256 chips).
    This version is the Farview economics applied to MoE: tokens are
    *selected* (top-k routing = a selectivity-k/E predicate) and ONLY the
    selected copies cross the expert axis, as two all_to_alls per direction:

      per device/layer  a2a bytes = T_loc * k * d * bytes  (+ id channel)
      vs GSPMD-gather   ~ E*cap*d broadcast over the axis.

    Semantics match moe_ffn up to capacity policy: capacity here is
    per-destination-DEVICE (C = cf * T_loc * k / n_ep) then per-expert
    locally, instead of one global per-expert capacity.
    """
    from jax.sharding import PartitionSpec as P

    b, s, d = x.shape
    e = p["router"].shape[1]
    dpa = tuple(dp_axes) if dp_axes else ()
    all_axes = dpa + (ep_axis,)

    def sm(xs, router, wg, wu, wd):
        n_ep = jax.lax.axis_size(ep_axis)
        e_loc = wg.shape[0]
        bl, sl, _ = xs.shape
        t = bl * sl
        xt = xs.reshape(t, d)

        logits = (xt.astype(router.dtype) @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)               # (T_loc, E)
        gate_w, gate_e = jax.lax.top_k(probs, top_k)
        gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

        # Switch aux loss over the GLOBAL batch (pmean across the mesh)
        me = jnp.mean(probs, axis=0)
        ce_frac = jnp.zeros((e,), jnp.float32).at[gate_e.reshape(-1)].add(
            1.0 / (t * top_k))
        me = jax.lax.pmean(me, all_axes)
        ce_frac = jax.lax.pmean(ce_frac, all_axes)
        aux = e * jnp.sum(me * ce_frac)

        # ---- dispatch: rank within destination DEVICE ----------------------
        dest = (gate_e // e_loc).reshape(-1)                  # (T_loc*k,)
        cap = max(8, int(capacity_factor * t * top_k / n_ep + 0.5))
        rank = _rank_within(dest)
        keep = rank < cap
        slot = jnp.where(keep, dest * cap + rank, n_ep * cap)

        # §Perf A2: payloads travel in the activation dtype (bf16), not
        # f32 — the id channel stays exact (e_loc <= 256 in bf16).
        pdt = xs.dtype
        eid_local = (gate_e % e_loc).reshape(-1).astype(pdt)
        tok_idx = jnp.repeat(jnp.arange(t), top_k)
        payload = jnp.concatenate(
            [xt[tok_idx].astype(pdt), eid_local[:, None]], axis=1)
        send = jnp.zeros((n_ep * cap + 1, d + 1), pdt)
        send = send.at[slot].set(payload, mode="drop")
        send = send[:n_ep * cap].reshape(n_ep, cap, d + 1)

        recv = jax.lax.all_to_all(send, ep_axis, split_axis=0,
                                  concat_axis=0, tiled=False)
        rt = recv.reshape(n_ep * cap, d + 1)
        x_in = rt[:, :d]
        eid = jnp.round(rt[:, d].astype(jnp.float32)).astype(jnp.int32)
        # a zero row (dropped/padding slot) carries eid 0; mask by payload
        live = jnp.any(rt[:, :d] != 0.0, axis=1)
        eid = jnp.where(live, eid, e_loc)                     # park dead rows

        # ---- local per-expert dispatch (within-device, no collectives) -----
        n_recv = n_ep * cap
        cap2 = max(8, int(capacity_factor * n_recv / e_loc + 0.5))
        rank2 = _rank_within(eid)
        keep2 = (rank2 < cap2) & live
        slot2 = jnp.where(keep2, eid * cap2 + rank2, e_loc * cap2)
        buf = jnp.zeros((e_loc * cap2 + 1, d), xs.dtype)
        buf = buf.at[slot2].set(x_in, mode="drop")
        expert_in = buf[:e_loc * cap2].reshape(e_loc, cap2, d)

        gate = jnp.einsum("ecd,edf->ecf", expert_in, wg, optimize=True)
        up = jnp.einsum("ecd,edf->ecf", expert_in, wu, optimize=True)
        if act == "silu":
            h = jax.nn.silu(gate.astype(jnp.float32)).astype(xs.dtype) * up
        else:
            h = jax.nn.gelu(gate.astype(jnp.float32),
                            approximate=True).astype(xs.dtype) * up
        expert_out = jnp.einsum("ecf,efd->ecd", h, wd, optimize=True)

        out_rows = expert_out.reshape(e_loc * cap2, d)[
            jnp.clip(slot2, 0, e_loc * cap2 - 1)]
        out_rows = jnp.where(keep2[:, None], out_rows, 0)

        # ---- return trip ----------------------------------------------------
        back = out_rows.reshape(n_ep, cap, d).astype(pdt)
        ret = jax.lax.all_to_all(back, ep_axis, split_axis=0,
                                 concat_axis=0, tiled=False)
        ret_flat = ret.reshape(n_ep * cap, d)
        got = ret_flat[jnp.clip(slot, 0, n_ep * cap - 1)]
        got = jnp.where(keep[:, None], got, 0).reshape(t, top_k, d)
        out = jnp.sum(got * gate_w[..., None].astype(pdt),
                      axis=1).astype(xs.dtype)
        return out.reshape(bl, sl, d), aux

    in_specs = (P(dpa or None, ep_axis, None),   # x: batch x seq-sharded
                P(None, None),                   # router replicated
                P(ep_axis, None, None),          # experts EP-sharded
                P(ep_axis, None, None),
                P(ep_axis, None, None))
    out_specs = (P(dpa or None, ep_axis, None), P())
    out, aux = shard_map(sm, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)(
        x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return out, aux
