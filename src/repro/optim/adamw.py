"""AdamW with decoupled weight decay, global-norm clipping, LR schedules.

Built in-repo (no optax). Optimizer state is a pytree mirroring params:
{"m": ..., "v": ..., "step": ()}. All moments in f32 regardless of param
dtype (bf16-safe training). `update` is functional and jit/pjit friendly;
moment shardings mirror param shardings so the state scales with the mesh.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    learning_rate: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1000
    schedule: str = "cosine"     # cosine | linear | const
    min_lr_frac: float = 0.1


def lr_at(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    if cfg.schedule == "const":
        decay = 1.0
    else:
        frac = jnp.clip((step - cfg.warmup_steps)
                        / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
        if cfg.schedule == "cosine":
            decay = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
                1 + jnp.cos(jnp.pi * frac))
        else:
            decay = 1.0 - (1 - cfg.min_lr_frac) * frac
    return cfg.learning_rate * warm * decay


def init(params) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros,
            "v": jax.tree.map(jnp.zeros_like, zeros),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    gn = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gn


def update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics).

    Non-finite gradients (inf/NaN anywhere -> non-finite global norm) skip
    the whole update in-graph: params AND moments are kept, only `step`
    advances. This is the production NaN guard — one bad microstep (fp
    overflow, flaky host) must never corrupt the weights. `skipped` is
    surfaced in the metrics for the train-loop watchdog.
    """
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    ok = jnp.isfinite(gn)
    okf = ok.astype(jnp.float32)
    step = state["step"]
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    t = (step + 1).astype(jnp.float32)
    bc1 = 1.0 - b1 ** t
    bc2 = 1.0 - b2 ** t

    def upd(p, g, m, v):
        g = jnp.where(ok, g, 0.0)          # poison-free moments
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * g * g
        mhat = m_new / bc1
        vhat = v_new / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * okf * delta
        return (p_new.astype(p.dtype),
                jnp.where(ok, m_new, m), jnp.where(ok, v_new, v))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step + 1}
    return new_p, new_state, {"lr": lr, "grad_norm": gn,
                              "skipped": 1.0 - okf}
