"""Deterministic synthetic data pipeline (training substrate).

Multi-host discipline without real storage: every host derives its shard of
each global batch purely from (seed, step, host_slice) — restart-safe
(skip-ahead is just a step number, used by the fault-tolerant runner) and
identical across elastic re-meshes. A double-buffered prefetch thread hides
host->device transfer, mirroring a production input pipeline.

The synthetic stream is a Zipf-ish token mixture with Markov structure so
the LM loss actually *decreases* (quickstart/train_100m show learning), not
a uniform-random wall.
"""
from __future__ import annotations

import queue
import threading

import jax.numpy as jnp
import numpy as np


class TokenPipeline:
    def __init__(self, *, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, host_index: int = 0, host_count: int = 1,
                 order: int = 2):
        assert global_batch % host_count == 0
        self.vocab = vocab
        self.seq_len = seq_len
        self.local_batch = global_batch // host_count
        self.host_index = host_index
        self.seed = seed
        # fixed random Markov transition structure (shared across hosts)
        rng = np.random.default_rng(seed)
        self.n_ctx = 64
        self._ctx_next = rng.integers(0, vocab, size=(self.n_ctx, 8))
        self._order = order

    def batch_at(self, step: int) -> dict[str, np.ndarray]:
        """Deterministic batch for a global step (host-local slice)."""
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step) * 4096 + self.host_index)
        b, s = self.local_batch, self.seq_len
        toks = np.empty((b, s + 1), np.int64)
        state = rng.integers(0, self.n_ctx, size=(b,))
        toks[:, 0] = rng.integers(0, self.vocab, size=(b,))
        for t in range(1, s + 1):
            choice = rng.integers(0, 8, size=(b,))
            nxt = self._ctx_next[state, choice]
            noise = rng.random(b) < 0.1
            nxt = np.where(noise, rng.integers(0, self.vocab, size=(b,)), nxt)
            toks[:, t] = nxt
            state = (state * 31 + nxt) % self.n_ctx
        return {"tokens": toks[:, :-1].astype(np.int32),
                "labels": toks[:, 1:].astype(np.int32)}

    def iter_batches(self, start_step: int = 0):
        step = start_step
        while True:
            yield step, self.batch_at(step)
            step += 1


class Prefetcher:
    """Double-buffered background prefetch of pipeline batches to device."""

    def __init__(self, pipeline: TokenPipeline, start_step: int = 0,
                 depth: int = 2, put_fn=None):
        self._q: queue.Queue = queue.Queue(maxsize=depth)
        self._put = put_fn or jnp.asarray
        self._stop = threading.Event()

        def work():
            for step, batch in pipeline.iter_batches(start_step):
                if self._stop.is_set():
                    return
                dev = {k: self._put(v) for k, v in batch.items()}
                self._q.put((step, dev))

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def next(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass


def db_table_columns(n_rows: int, n_cols: int = 8, seed: int = 0,
                     key_cardinality: int = 0) -> dict[str, np.ndarray]:
    """Synthetic DB table for the Farview benchmarks (paper §6.1 tables:
    8 attributes; selection columns uniform; optional low-cardinality key
    column c0 for grouping experiments)."""
    rng = np.random.default_rng(seed)
    cols = {}
    for i in range(n_cols):
        if i == 0 and key_cardinality:
            cols["c0"] = rng.integers(0, key_cardinality,
                                      size=n_rows).astype(np.float32)
        else:
            cols[f"c{i}"] = rng.normal(size=n_rows).astype(np.float32)
    return cols
