"""Continuous-batching serve loop over the far-KV pool.

The paper's multi-client story (six dynamic regions, fair-shared DRAM) maps
to serving as slot-based continuous batching: the decode step always runs
at a fixed batch B (the "regions"); requests claim a slot, decode until
EOS/max, release. The KV pool rows of a slot are simply overwritten by the
next tenant (position 0 append), like a region reconfiguration.

Per-slot state: position, remaining budget, active flag. The jitted step
is shape-stable (B fixed), so new arrivals never retrigger compilation —
the serving-economics analogue of Farview's pre-compiled pipelines.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.steps import make_serve_step


@dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (L,) int32
    max_new: int = 16
    out: list = field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    def __init__(self, lm, *, batch: int, max_seq: int, mode: str = "local",
                 kv_dtype=jnp.float32, eos_id: int | None = None):
        self.lm = lm
        self.batch = batch
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.step_fn = jax.jit(make_serve_step(lm, mode=mode))
        self.cache = lm.init_cache(batch, max_seq, kv_dtype)
        self.slots: list[Request | None] = [None] * batch
        self.pos = np.zeros(batch, np.int32)       # per-slot next position
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        self.steps = 0

    # -------------------------------------------------------------- intake
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i in range(self.batch):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                self.pos[i] = 0
        return any(s is not None for s in self.slots)

    # ---------------------------------------------------------------- step
    def _tokens_for_step(self) -> np.ndarray:
        toks = np.zeros((self.batch, 1), np.int32)
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            p = int(self.pos[i])
            if p < len(req.prompt):
                toks[i, 0] = req.prompt[p]           # prefill (teacher-forced)
            elif req.out:
                toks[i, 0] = req.out[-1]             # decode
        return toks

    def run(self, max_steps: int = 10_000) -> list[Request]:
        """Drain the queue; returns finished requests."""
        while self._admit() and self.steps < max_steps:
            toks = jnp.asarray(self._tokens_for_step())
            # a single global position keeps the step shape-stable; slots
            # admitted mid-flight start at the current max position (their
            # cache rows before that are zero-length via per-slot lengths).
            # For simplicity all slots share the step's write position:
            # admission only happens when pos is uniform (slot release).
            pos = int(self.pos.max())
            nxt, self.cache = self.step_fn(
                self._params, self.cache,
                {"tokens": toks}, jnp.int32(pos), jnp.int32(pos))
            nxt = np.asarray(nxt)
            self.steps += 1
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                self.pos[i] += 1
                p = int(self.pos[i])
                if p >= len(req.prompt):
                    tok = int(nxt[i])
                    req.out.append(tok)
                    hit_eos = self.eos_id is not None and tok == self.eos_id
                    if len(req.out) >= req.max_new or hit_eos \
                            or p >= self.max_seq - 1:
                        req.done = True
                        self.finished.append(req)
                        self.slots[i] = None
            # release-then-admit keeps positions uniform across active slots
            if all(s is None for s in self.slots):
                self.pos[:] = 0
        return self.finished

    def bind_params(self, params):
        self._params = params
        return self
