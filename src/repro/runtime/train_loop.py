"""Fault-tolerant training loop.

Production behaviours, all exercised by tests/test_runtime.py on CPU:

  * restore-on-start: resumes from the latest checkpoint (params, opt state,
    step) — a crashed/preempted job restarts bit-exact (data pipeline is
    step-addressed, so skip-ahead is free);
  * checkpoint cadence + async writes (train never blocks on disk);
  * preemption hook: SIGTERM triggers a final checkpoint before exit
    (cloud TPU preemption contract);
  * NaN guard: a non-finite loss aborts the step, restores the previous
    checkpoint and continues (transient-failure containment);
  * straggler watchdog: EWMA of step time; steps slower than
    `straggler_factor` x EWMA are counted and surfaced in metrics — on a
    real fleet this feeds the re-mesh/hot-spare path (SPMD can't drop a
    chip mid-step; mitigation is restart-with-spares, which is the elastic
    restore path);
  * elastic re-mesh: checkpoints are saved unsharded, so a restart may use
    a different mesh/host count (restore takes the new shardings).
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import TrainConfig
from repro.data.pipeline import TokenPipeline
from repro.runtime import steps as R


@dataclass
class LoopStats:
    steps_done: int = 0
    nan_events: int = 0
    restarts: int = 0
    straggler_steps: int = 0
    preempted: bool = False
    losses: list = field(default_factory=list)
    step_time_ewma: float = 0.0


class TrainLoop:
    def __init__(self, lm, tcfg: TrainConfig, pipeline: TokenPipeline, *,
                 shardings=None, batch_shardings=None,
                 straggler_factor: float = 3.0, microbatches: int = 1,
                 keep_last: int = 3):
        self.lm = lm
        self.tcfg = tcfg
        self.pipe = pipeline
        self.ckpt = CheckpointManager(tcfg.checkpoint_dir,
                                      keep_last=keep_last)
        self.step_fn = jax.jit(R.make_train_step(lm, tcfg,
                                                 microbatches=microbatches))
        self.shardings = shardings
        self.batch_shardings = batch_shardings
        self.straggler_factor = straggler_factor
        self.stats = LoopStats()
        self._preempt = False

    # ------------------------------------------------------------ lifecycle
    def _install_preempt_hook(self):
        def handler(signum, frame):
            self._preempt = True
        try:
            signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass                      # non-main thread (tests)

    def _restore_or_init(self, init_key):
        tree, meta = self.ckpt.restore(shardings=self.shardings)
        if tree is not None:
            self.stats.restarts += 1
            return tree["params"], tree["opt"], int(meta["step"]) + 1
        params = self.lm.init(init_key)
        if self.shardings is not None:
            params = jax.device_put(params, self.shardings["params"])
        opt = R.init_train_state(self.lm, self.tcfg, params)
        if self.shardings is not None and "opt" in self.shardings:
            opt = jax.device_put(opt, self.shardings["opt"])
        return params, opt, 0

    def _put_batch(self, batch):
        if self.batch_shardings is None:
            return {k: jax.numpy.asarray(v) for k, v in batch.items()}
        return {k: jax.device_put(v, self.batch_shardings[k])
                for k, v in batch.items()}

    # ----------------------------------------------------------------- run
    def run(self, total_steps: int | None = None, *, seed: int = 0,
            fail_at_step: int | None = None) -> LoopStats:
        """Run until total_steps. `fail_at_step` injects a NaN loss once
        (fault-injection for tests)."""
        self._install_preempt_hook()
        total = total_steps or self.tcfg.total_steps
        params, opt, start = self._restore_or_init(
            jax.random.PRNGKey(self.tcfg.seed))
        step = start
        injected = False
        while step < total:
            if self._preempt:
                self.ckpt.wait()
                self.ckpt.save(step - 1, {"params": params, "opt": opt})
                self.stats.preempted = True
                return self.stats
            t0 = time.perf_counter()
            batch = self._put_batch(self.pipe.batch_at(step))
            params_new, opt_new, metrics = self.step_fn(params, opt, batch)
            loss = float(metrics["loss"])
            if fail_at_step == step and not injected:
                loss, injected = float("nan"), True

            if not np.isfinite(loss):
                # NaN containment: drop the update, reload last good state
                self.stats.nan_events += 1
                tree, meta = self.ckpt.restore(shardings=self.shardings)
                if tree is not None:
                    params, opt = tree["params"], tree["opt"]
                    step = int(meta["step"]) + 1
                # else: keep old params (update dropped) and move on
                else:
                    step += 1
                continue

            params, opt = params_new, opt_new
            self.stats.losses.append(loss)
            dt = time.perf_counter() - t0
            ew = self.stats.step_time_ewma
            self.stats.step_time_ewma = dt if ew == 0 else 0.9 * ew + 0.1 * dt
            if ew > 0 and dt > self.straggler_factor * ew:
                self.stats.straggler_steps += 1

            if (step + 1) % self.tcfg.checkpoint_every == 0 \
                    or step == total - 1:
                self.ckpt.save(step, {"params": params, "opt": opt},
                               {"loss": loss}, asynchronous=True)
            step += 1
            self.stats.steps_done += 1
        self.ckpt.wait()
        return self.stats
