"""Jit-ready step functions: train_step / prefill_step / serve_step.

These are the exact functions the multi-pod dry-run lowers and the train /
serve loops execute. Factories close over the LM + static config so the
jitted signature is pure arrays:

  train_step(params, opt_state, batch)        -> (params, opt_state, metrics)
  prefill_step(params, batch)                 -> (last_logits, cache)
  serve_step(params, cache, batch, pos, len)  -> (next_tokens, cache)

Gradient accumulation: microbatches > 1 splits the global batch on axis 0
and scans, accumulating f32 gradients (keeps the activation working set
1/M-th while the weights see the same effective batch).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.distributed import compress as C
from repro.models.lm import LM
from repro.optim import adamw


def make_train_step(lm: LM, tcfg: TrainConfig, *, microbatches: int = 1,
                    total_steps: int | None = None):
    acfg = adamw.AdamWConfig(
        learning_rate=tcfg.learning_rate, b1=tcfg.b1, b2=tcfg.b2,
        weight_decay=tcfg.weight_decay, grad_clip=tcfg.grad_clip,
        warmup_steps=tcfg.warmup_steps,
        total_steps=total_steps or tcfg.total_steps)
    use_ef = tcfg.grad_compression == "int8_ef"

    def loss_fn(params, mb):
        return lm.loss(params, mb)

    def train_step(params, opt_state, batch):
        if microbatches <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            m = microbatches

            def split(leaf):
                b = leaf.shape[0]
                assert b % m == 0, (b, m)
                return leaf.reshape((m, b // m) + leaf.shape[1:])

            mbs = {k: split(v) for k, v in batch.items()}

            def body(carry, mb):
                gsum, lsum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb)
                gsum = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), gsum, g)
                return (gsum, lsum + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(body, (g0, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / m, gsum)
            loss = lsum / m

        metrics = {"loss": loss}
        if use_ef:
            grads, new_err = C.compress_grads(grads, opt_state["err"])
        new_params, new_opt, opt_metrics = adamw.update(
            acfg, params, grads, opt_state["adam"])
        metrics.update(opt_metrics)
        out_state = {"adam": new_opt}
        if use_ef:
            out_state["err"] = new_err
        return new_params, out_state, metrics

    return train_step


def init_train_state(lm: LM, tcfg: TrainConfig, params):
    state = {"adam": adamw.init(params)}
    if tcfg.grad_compression == "int8_ef":
        state["err"] = C.init_error_state(params)
    return state


def make_prefill_step(lm: LM, *, max_seq: int | None = None):
    def prefill_step(params, batch):
        return lm.prefill(params, batch, max_seq=max_seq)
    return prefill_step


def make_serve_step(lm: LM, *, mode: str = "far", sample: str = "greedy"):
    """One decode step: logits for the new token + greedy next-token ids."""
    def serve_step(params, cache, batch, pos, length):
        logits, new_cache = lm.decode_step(params, cache, batch, pos, length,
                                           mode=mode)
        next_tokens = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tokens, new_cache
    return serve_step


def make_eval_step(lm: LM):
    def eval_step(params, batch):
        return lm.loss(params, batch)
    return eval_step
