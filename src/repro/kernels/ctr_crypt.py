"""Counter-mode stream cipher kernel (paper §5.5 encryption/decryption).

TPU adaptation of Farview's AES-128-CTR engine:

  * AES's S-box is an 8-bit table lookup — free in FPGA LUTs, hostile to the
    TPU VPU (no cheap gather). We keep the *system role* (CTR-mode stream
    cipher fused into the read/write data path, encrypt == decrypt) and swap
    the round function for an ARX design (Threefry-2x32, 20 rounds), which is
    pure add/rotate/xor and vectorizes perfectly over lanes.
  * Like the paper's "fully parallelized and pipelined" AES, the keystream
    for every word of a block is computed independently, so the cipher runs
    at whatever rate the HBM->VMEM stream sustains: zero throughput penalty,
    which is exactly the claim of Fig. 11 that bench_crypto.py re-validates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (256, 128)
_ROTS = (13, 15, 26, 6, 17, 29, 16, 24)
_PARITY = np.uint32(0x1BD11BDA)


def _rotl(x, r):
    return (x << np.uint32(r)) | (x >> np.uint32(32 - r))


def _threefry2x32(k0, k1, c0, c1):
    ks = [k0, k1, k0 ^ k1 ^ _PARITY]
    x0 = c0 + ks[0]
    x1 = c1 + ks[1]
    for block in range(5):
        for r in range(4):
            x0 = x0 + x1
            x1 = _rotl(x1, _ROTS[(4 * block + r) % 8])
            x1 = x0 ^ x1
        x0 = x0 + ks[(block + 1) % 3]
        x1 = x1 + ks[(block + 2) % 3] + np.uint32(block + 1)
    return x0, x1


def _kernel(block_shape, data_ref, key_ref, out_ref):
    rows, cols = block_shape
    data = data_ref[...]
    k0 = key_ref[0, 0]
    k1 = key_ref[0, 1]
    nonce = key_ref[0, 2]
    step = pl.program_id(0)

    base = (step * rows * cols).astype(jnp.uint32)
    ir = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 0)
    ic = jax.lax.broadcasted_iota(jnp.uint32, (rows, cols), 1)
    pos = base + ir * np.uint32(cols) + ic
    ctr = pos >> np.uint32(1)
    lane = pos & np.uint32(1)
    s0, s1 = _threefry2x32(k0, k1, ctr, jnp.full_like(ctr, nonce))
    stream = jnp.where(lane == 0, s0, s1)
    out_ref[...] = data ^ stream


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def ctr_crypt(data: jnp.ndarray, key: jnp.ndarray, *,
              block: tuple[int, int] = DEFAULT_BLOCK,
              interpret: bool = True):
    """data: (N, C) uint32 with N % block[0] == 0, C == block[1];
    key: (1, 4) uint32 = [k0, k1, nonce, 0]. Involutive (CTR mode)."""
    n, c = data.shape
    rows, cols = block
    assert n % rows == 0 and c == cols, (data.shape, block)
    kern = functools.partial(_kernel, block)
    return pl.pallas_call(
        kern,
        grid=(n // rows,),
        in_specs=[
            pl.BlockSpec((rows, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows, cols), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c), jnp.uint32),
        interpret=interpret,
    )(data, key)
