"""Small-table join kernel (the paper's stated future work, §Conclusions:
"performing joins against small tables in the memory by reading the small
table into the FPGA and matching the tuples read from memory against it").

TPU adaptation: the build side lives in VMEM across all grid steps (the
FPGA on-chip-table analogue); the probe stream is matched per block with a
one-hot key-equality matmul on the MXU:

    M[i, j]  = (probe_key_i == build_key_j)          (VPU compare)
    joined   = M @ build_values                       (MXU gather-by-match)
    matched  = row_sum(M) > 0

Build keys must be unique (enforced by the ops.py wrapper): each probe row
matches at most one build row, so M is one-hot per row and the matmul IS
the value gather. 16-bit key halves keep the f32 compare exact (same trick
as hash_group).

K = 0 (an empty co-partitioned build shard — a cluster node whose probe
partition's keys all miss) is handled by the ops.py wrapper: it
short-circuits to a no-match result rather than lowering a zero-row build
block, so the kernel itself always sees K >= 1.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 256


def _kernel(probe_ref, bkey_ref, bval_ref, out_ref, hit_ref):
    pk = probe_ref[...][:, 0]                                 # (R,) i32
    bk = bkey_ref[...][:, 0]                                  # (K,) i32
    bv = bval_ref[...]                                        # (K, V) f32

    match = (pk[:, None] == bk[None, :])                      # (R, K) bool
    m_f = match.astype(jnp.float32)
    joined = jax.lax.dot(m_f, bv,
                         precision=jax.lax.Precision.HIGHEST)  # (R, V)
    hits = jnp.sum(m_f, axis=1, keepdims=True)                # (R, 1)
    out_ref[...] = joined
    hit_ref[...] = (hits > 0.5).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def hash_join(probe_keys: jnp.ndarray, build_keys: jnp.ndarray,
              build_vals: jnp.ndarray, *,
              block_rows: int = DEFAULT_BLOCK_ROWS,
              interpret: bool = True):
    """probe_keys (N,1) i32; build_keys (K,1) i32 (unique);
    build_vals (K,V) f32. N % block_rows == 0 (wrapper pads).

    Returns (joined (N,V) f32 — matched build values, 0 where no match;
             hit (N,1) i32 — 1 where the probe key exists in the build).
    """
    n = probe_keys.shape[0]
    k, v = build_vals.shape
    assert n % block_rows == 0
    return pl.pallas_call(
        _kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((k, 1), lambda i: (0, 0)),       # build side: VMEM
            pl.BlockSpec((k, v), lambda i: (0, 0)),       # resident per step
        ],
        out_specs=[
            pl.BlockSpec((block_rows, v), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, v), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        interpret=interpret,
    )(probe_keys, build_keys, build_vals)
