"""Regular-expression matching kernel (paper §5.3 "Regular expression matching").

TPU adaptation of Farview's parallel regex engines:

  * Farview instantiates multiple spatial regex engines to sustain line rate;
    here the engines are VPU *lanes*: each lane runs one string's DFA.
  * FPGA state machines use LUT transition logic; TPUs have no cheap gather,
    so the per-character transition is computed as two MXU matmuls over
    one-hot encodings:   U = T^t @ OneHot(state)  -> (256, R)
                         next = sum_c U * OneHot(char) -> (R,)
    i.e. the MXU evaluates *all* transitions and the char one-hot selects.
  * As in the paper, throughput depends only on string length, never on
    pattern complexity (the DFA is precompiled host-side; see
    repro.core.regex for the regex -> NFA -> DFA compiler).

Strings are stored transposed (L, N) so the time step indexes the sublane
axis (dynamic sublane slices are TPU-friendly; dynamic lane slices are not).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 128  # strings per block (lanes)
ALPHA = 256


def _kernel(n_states, seq_len, chars_ref, len_ref, table_ref, accept_ref,
            out_ref):
    chars = chars_ref[...]                                    # (L, R) int32
    lens = len_ref[...]                                       # (1, R) int32
    table_t = table_ref[...]                                  # (256, S) f32 (T^t)
    accept = accept_ref[...]                                  # (1, S) f32
    r = chars.shape[1]
    s = n_states

    iota_s = jax.lax.broadcasted_iota(jnp.int32, (s, r), 0)
    iota_c = jax.lax.broadcasted_iota(jnp.int32, (ALPHA, r), 0)

    def step(t, state):
        ch = jax.lax.dynamic_slice(chars, (t, 0), (1, r))     # (1, R)
        st_oh = (state[None, :] == iota_s).astype(jnp.float32)    # (S, R)
        ch_oh = (ch == iota_c).astype(jnp.float32)                # (256, R)
        u = jax.lax.dot(table_t, st_oh,
                        precision=jax.lax.Precision.HIGHEST)      # (256, R)
        nxt = jnp.sum(u * ch_oh, axis=0)                          # (R,)
        nxt = jnp.round(nxt).astype(jnp.int32)
        return jnp.where(t < lens[0], nxt, state)

    state = jax.lax.fori_loop(0, seq_len, step,
                              jnp.zeros((r,), jnp.int32))
    st_oh = (state[None, :] == iota_s).astype(jnp.float32)
    acc = jax.lax.dot(accept, st_oh,
                      precision=jax.lax.Precision.HIGHEST)         # (1, R)
    out_ref[...] = (acc > 0.5).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret"))
def dfa_match(chars_t: jnp.ndarray, lengths: jnp.ndarray,
              table_t: jnp.ndarray, accept: jnp.ndarray, *,
              block_rows: int = DEFAULT_BLOCK_ROWS,
              interpret: bool = True):
    """chars_t: (L, N) int32 transposed strings; lengths: (1, N) int32;
    table_t: (256, S) f32 transition table transpose; accept: (1, S) f32.
    N % block_rows == 0. Returns match mask (1, N)... shaped (nb, block_rows).
    """
    l, n = chars_t.shape
    s = table_t.shape[1]
    assert n % block_rows == 0
    nb = n // block_rows
    kern = functools.partial(_kernel, s, l)
    out = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((l, block_rows), lambda i: (0, i)),
            pl.BlockSpec((1, block_rows), lambda i: (0, i)),
            pl.BlockSpec((ALPHA, s), lambda i: (0, 0)),
            pl.BlockSpec((1, s), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_rows), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block_rows), jnp.int32),
        interpret=interpret,
    )(chars_t, lengths, table_t, accept)
    return out.reshape(n)
