"""Pure-jnp oracles for every Pallas kernel in repro.kernels.

Each function here is the semantic ground truth: slow, simple, obviously
correct. Kernel tests sweep shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# Predicate op codes shared with the kernels (paper §5.3 predicate selection).
OP_SKIP, OP_LT, OP_LE, OP_GT, OP_GE, OP_EQ, OP_NE = range(7)

KEY_SENTINEL = np.iinfo(np.int32).min  # "empty bucket" marker (hash_group)


# ---------------------------------------------------------------------------
# select_project
# ---------------------------------------------------------------------------
def eval_predicate(table: jnp.ndarray, sel_ops: jnp.ndarray,
                   sel_vals: jnp.ndarray) -> jnp.ndarray:
    """AND-of-per-column-comparisons predicate.

    table: (N, A) float32/int32 columns.
    sel_ops: (A,) int32 op codes (OP_SKIP disables the column).
    sel_vals: (A,) same dtype as table, comparison constants.
    Returns (N,) bool mask.
    """
    col = table
    val = sel_vals[None, :]
    ops = sel_ops[None, :]
    per_col = jnp.where(
        ops == OP_LT, col < val,
        jnp.where(ops == OP_LE, col <= val,
                  jnp.where(ops == OP_GT, col > val,
                            jnp.where(ops == OP_GE, col >= val,
                                      jnp.where(ops == OP_EQ, col == val,
                                                jnp.where(ops == OP_NE, col != val,
                                                          True))))))
    return jnp.all(per_col, axis=1)


def select_project(table: jnp.ndarray, sel_ops: jnp.ndarray,
                   sel_vals: jnp.ndarray, proj_mask: jnp.ndarray):
    """Filter rows by predicate, zero out non-projected columns, compact.

    Returns (packed, count): packed (N, A) with survivors (projected columns
    only; dropped columns zeroed) moved to the front in original order, tail
    zero-filled; count = number of survivors.
    """
    n = table.shape[0]
    mask = eval_predicate(table, sel_ops, sel_vals)
    projected = jnp.where(proj_mask[None, :].astype(bool), table, 0)
    # Stable compaction: survivors first, original order preserved.
    order = jnp.argsort(~mask, stable=True)
    packed = jnp.where(mask[order][:, None], projected[order], 0)
    return packed, jnp.sum(mask.astype(jnp.int32))


# ---------------------------------------------------------------------------
# hash_group (distinct / group-by / aggregation)
# ---------------------------------------------------------------------------
def bucket_of(keys: jnp.ndarray, n_buckets: int) -> jnp.ndarray:
    """Multiplicative (Fibonacci) hash of int32 keys into n_buckets (pow2)."""
    h = (keys.astype(jnp.uint32) * jnp.uint32(0x9E3779B1))
    shift = 32 - int(np.log2(n_buckets))
    return (h >> shift).astype(jnp.int32)


def sort_by_bucket(bucket: jnp.ndarray, n_buckets: int):
    """Stable sort of rows by bucket id -> (order, sorted_buckets).

    Fast path: pack (bucket, row) into ONE uint32 composite key
    `bucket * N + row` and value-sort it — stability is by construction
    (rows of a bucket keep ascending index), and XLA's single-array
    primitive sort is ~5x faster on CPU than the comparator-pair sort
    argsort lowers to. Falls back to stable argsort when the composite
    would overflow 32 bits (n_buckets * N > 2^32).
    """
    n = bucket.shape[0]
    if n and n_buckets * n <= 2**32:
        comp = jnp.sort(bucket.astype(jnp.uint32) * jnp.uint32(n)
                        + jnp.arange(n, dtype=jnp.uint32))
        order = (comp % jnp.uint32(n)).astype(jnp.int32)
        return order, (comp // jnp.uint32(n)).astype(jnp.int32)
    order = jnp.argsort(bucket, stable=True)
    return order.astype(jnp.int32), bucket[order]


def segment_spans(sorted_seg_ids: jnp.ndarray, n_segments: int):
    """Per-segment [start, end] row spans of a bucket-sorted id array.

    sorted_seg_ids: (N,) int32, non-decreasing. Returns (start (S,), end (S,),
    nonempty (S,) bool) where end is the INCLUSIVE last row (clipped to a
    valid index; mask with `nonempty` before trusting it).
    """
    n = sorted_seg_ids.shape[0]
    seg = jnp.arange(n_segments, dtype=sorted_seg_ids.dtype)
    lo = jnp.searchsorted(sorted_seg_ids, seg, side="left")
    hi = jnp.searchsorted(sorted_seg_ids, seg, side="right")
    nonempty = hi > lo
    return (jnp.clip(lo, 0, max(n - 1, 0)).astype(jnp.int32),
            jnp.clip(hi - 1, 0, max(n - 1, 0)).astype(jnp.int32), nonempty)


def segmented_reduce(sums: jnp.ndarray, mins: jnp.ndarray, maxs: jnp.ndarray,
                     starts: jnp.ndarray, counts: jnp.ndarray | None = None):
    """Inclusive segmented scan of (sum, min, max[, count]) in one pass.

    sums/mins/maxs: (N, V); starts: (N,) bool segment-start flags over rows
    already sorted by segment; counts: optional (N,) int per-row weights
    scanned with the same flag-reset combine (the group-merge path needs
    exact int totals; group_aggregate uses a plain cumsum instead). Lowers
    to `jax.lax.associative_scan` — a log-depth data-parallel tree, never a
    serialized scatter. Row i of each output holds the running reduction
    since its segment's first row, so the segment totals sit at the segment
    END rows (gather via segment_spans). Returns (sum, min, max) or
    (count, sum, min, max) when counts is given.
    """
    f = starts[:, None]

    def comb(a, b):
        sa, mna, mxa, *ca, fa = a
        sb, mnb, mxb, *cb, fb = b
        out = (jnp.where(fb, sb, sa + sb),
               jnp.where(fb, mnb, jnp.minimum(mna, mnb)),
               jnp.where(fb, mxb, jnp.maximum(mxa, mxb)))
        if ca:
            out += (jnp.where(fb[:, 0], cb[0], ca[0] + cb[0]),)
        return out + (fa | fb,)

    if counts is None:
        s, mn, mx, _ = jax.lax.associative_scan(comb, (sums, mins, maxs, f))
        return s, mn, mx
    s, mn, mx, c, _ = jax.lax.associative_scan(
        comb, (sums, mins, maxs, counts, f))
    return c, s, mn, mx


def group_aggregate(keys: jnp.ndarray, values: jnp.ndarray, n_buckets: int):
    """Hash-grouped aggregation with first-claim buckets + overflow.

    keys: (N,) int32 (must be > KEY_SENTINEL). values: (N, V) float32.
    Bucket ownership: the first row (lowest index) hashing into a bucket
    claims it; later rows with a *different* key in the same bucket overflow
    (paper: cuckoo-collision rows are shipped to the client for software
    post-processing).

    Lowering: sort-based segment-reduce. Rows are stably sorted by bucket
    (composite-key value sort, `sort_by_bucket`), so each bucket is a
    contiguous segment whose FIRST row is the lowest-original-index row
    (the claimant); count comes from an exact int cumulative sum and
    sum/min/max from one segmented associative scan (log-depth tree) —
    all data-parallel primitives, replacing the `.at[].add/min/max`
    scatters that serialized on the host and capped cluster group
    scale-out (ROADMAP PR 3 follow-up).

    Returns dict with:
      bucket_keys (B,) int32 (KEY_SENTINEL if unclaimed)
      count (B,) int32 ; sum/min/max (B, V) float32 (claimed keys only)
      overflow_mask (N,) bool — rows that must be re-aggregated client-side
    """
    n, v = values.shape
    b = bucket_of(keys, n_buckets)
    order, sb = sort_by_bucket(b, n_buckets)
    start, end, nonempty = segment_spans(sb, n_buckets)
    # first-claim: after the stable sort, each segment's first row is the
    # bucket's lowest-original-index row
    claimed = jnp.where(nonempty, keys[order[start]], KEY_SENTINEL)
    owns = keys == claimed[b]
    ovf = ~owns
    so = owns[order]
    sv = values[order]
    # count: exact int32 prefix-sum difference over owned rows
    oc = so.astype(jnp.int32)
    csum = jnp.cumsum(oc)
    count = jnp.where(nonempty, csum[end] - (csum[start] - oc[start]), 0)
    # sum/min/max: one segmented scan; non-owned rows carry the identity
    big = jnp.asarray(jnp.finfo(values.dtype).max, values.dtype)
    flags = jnp.concatenate([jnp.ones((min(n, 1),), bool), sb[1:] != sb[:-1]])
    ssum, smin, smax = segmented_reduce(
        jnp.where(so[:, None], sv, 0), jnp.where(so[:, None], sv, big),
        jnp.where(so[:, None], sv, -big), flags)
    ne = nonempty[:, None]
    s = jnp.where(ne, ssum[end], 0)
    mn = jnp.where(ne, smin[end], big)
    mx = jnp.where(ne, smax[end], -big)
    return dict(bucket_keys=claimed, count=count, sum=s, min=mn, max=mx,
                overflow_mask=ovf)


def group_aggregate_exact(keys: np.ndarray, values: np.ndarray):
    """Dict-based exact group-by (numpy) — oracle for kernel+client-side merge."""
    out: dict[int, list] = {}
    for k, row in zip(np.asarray(keys).tolist(), np.asarray(values)):
        e = out.setdefault(k, [0, np.zeros_like(row, dtype=np.float64),
                               np.full_like(row, np.inf, dtype=np.float64),
                               np.full_like(row, -np.inf, dtype=np.float64)])
        e[0] += 1
        e[1] = e[1] + row
        e[2] = np.minimum(e[2], row)
        e[3] = np.maximum(e[3], row)
    return out


# ---------------------------------------------------------------------------
# dfa_match (regex)
# ---------------------------------------------------------------------------
def dfa_match(strings: jnp.ndarray, lengths: jnp.ndarray,
              table: jnp.ndarray, accept: jnp.ndarray) -> jnp.ndarray:
    """Run a DFA over each row of byte-strings.

    strings: (R, L) uint8 (0-padded). lengths: (R,) int32.
    table: (S, 256) int32 transition table. accept: (S,) bool.
    Semantics: start in state 0, consume chars [0, len); accept iff the state
    after the last consumed char is accepting. (Search semantics come from the
    DFA itself being built for `.*R` with absorbing accept states.)
    """
    r, l = strings.shape

    def step(state, t):
        ch = strings[:, t].astype(jnp.int32)
        nxt = table[state, ch]
        state = jnp.where(t < lengths, nxt, state)
        return state, None

    state0 = jnp.zeros((r,), jnp.int32)
    state, _ = jax.lax.scan(step, state0, jnp.arange(l))
    return accept[state]


# ---------------------------------------------------------------------------
# ctr_crypt (ARX counter-mode cipher, Threefry-2x32 schedule)
# ---------------------------------------------------------------------------
_ROTS = (13, 15, 26, 6, 17, 29, 16, 24)
_PARITY = np.uint32(0x1BD11BDA)


def _rotl(x, r):
    return (x << np.uint32(r)) | (x >> np.uint32(32 - r))


def threefry2x32(key: jnp.ndarray, c0: jnp.ndarray, c1: jnp.ndarray):
    """Threefry-2x32, 20 rounds. key: (2,) uint32; c0/c1: uint32 arrays."""
    k0, k1 = key[0], key[1]
    k2 = k0 ^ k1 ^ _PARITY
    ks = [k0, k1, k2]
    x0 = c0 + ks[0]
    x1 = c1 + ks[1]
    for block in range(5):
        for r in range(4):
            x0 = x0 + x1
            x1 = _rotl(x1, _ROTS[(4 * block + r) % 8])
            x1 = x0 ^ x1
        x0 = x0 + ks[(block + 1) % 3]
        x1 = x1 + ks[(block + 2) % 3] + np.uint32(block + 1)
    return x0, x1


def ctr_crypt(data: jnp.ndarray, key: jnp.ndarray, nonce: int,
              idx: jnp.ndarray | None = None) -> jnp.ndarray:
    """XOR data (N,) uint32 with the Threefry CTR keystream. Involutive.

    The keystream is positional: word i is XORed with stream position
    `idx[i]` (default arange(N) — a contiguous buffer starting at stream
    position 0). Passing explicit positions lets a partition of a larger
    buffer decrypt with the offsets it had inside the original flattening
    (the multi-node scatter path: each node holds a row subset of one
    encrypted table).
    """
    n = data.shape[0]
    idx = (jnp.arange(n, dtype=jnp.uint32) if idx is None
           else idx.astype(jnp.uint32))
    blk = idx >> 1  # each threefry call yields 2 words
    lane = idx & 1
    s0, s1 = threefry2x32(key, blk, jnp.full_like(blk, np.uint32(nonce)))
    stream = jnp.where(lane == 0, s0, s1)
    return data ^ stream


# ---------------------------------------------------------------------------
# decode_attention (far-KV partial flash attention)
# ---------------------------------------------------------------------------
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     lengths: jnp.ndarray, scale: float | None = None):
    """Single-token GQA attention over a KV shard, returning merge partials.

    q: (B, Hq, D); k/v: (B, S, Hkv, D); lengths: (B,) valid KV rows.
    Returns (o, m, l): o (B, Hq, D) un-normalized (o = sum softmax-weights*V
    scaled by exp(-m) convention: o = sum(exp(s - m) v)), m (B, Hq) running
    max, l (B, Hq) sum(exp(s - m)). Full attention = o / l after cross-shard
    merge. All math in f32.
    """
    b, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    if scale is None:
        scale = 1.0 / np.sqrt(d)
    qf = q.astype(jnp.float32).reshape(b, hkv, g, d)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bhgd,bshd->bhgs", qf, kf) * scale
    pos = jnp.arange(s)[None, None, None, :]
    valid = pos < lengths[:, None, None, None]
    scores = jnp.where(valid, scores, -jnp.inf)
    m = jnp.max(scores, axis=-1)
    msafe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(valid, jnp.exp(scores - msafe[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, vf)
    return (o.reshape(b, hq, d), msafe.reshape(b, hq), l.reshape(b, hq))


def merge_partials(parts):
    """Merge per-shard (o, m, l) partials into final attention output.

    parts: list of (o, m, l). Returns normalized (B, Hq, D) f32 output.
    """
    os = jnp.stack([p[0] for p in parts])
    ms = jnp.stack([p[1] for p in parts])
    ls = jnp.stack([p[2] for p in parts])
    m = jnp.max(ms, axis=0)
    w = jnp.exp(ms - m[None])
    l = jnp.sum(ls * w, axis=0)
    o = jnp.sum(os * w[..., None], axis=0)
    return o / jnp.maximum(l, 1e-30)[..., None]


def full_attention_oracle(q, k, v, lengths, scale=None):
    """Plain masked softmax attention for testing partial merges."""
    o, m, l = decode_attention(q, k, v, lengths, scale)
    return o / jnp.maximum(l, 1e-30)[..., None]


# ---------------------------------------------------------------------------
# hash_join (small-table inner join; the paper's stated future work)
# ---------------------------------------------------------------------------
def hash_join(probe_keys, build_keys, build_vals):
    """Oracle: dict-based unique-key inner join.

    probe_keys (N,) i32; build_keys (K,) i32 unique; build_vals (K, V) f32.
    Returns (joined (N, V) — matched build row or zeros, hit (N,) bool).
    """
    lut = {int(k): i for i, k in enumerate(np.asarray(build_keys))}
    n = len(probe_keys)
    v = np.asarray(build_vals).shape[1]
    joined = np.zeros((n, v), np.float32)
    hit = np.zeros((n,), bool)
    for i, k in enumerate(np.asarray(probe_keys)):
        j = lut.get(int(k))
        if j is not None:
            joined[i] = np.asarray(build_vals)[j]
            hit[i] = True
    return joined, hit
