"""Fused in-dispatch page decompression (the tiering read path).

`FarPool` keeps COLD pages compressed in place (`distributed/compress.py`
encodes each column plane bit-packed delta/dict into a shared "cold
frame"). These gathers are the device-side inverse: pure traced functions
of `(buf, descriptors)` that reconstruct the LOGICAL words of a mixed
raw/compressed page list inside the SAME jitted program as the operator
pipeline — one dispatch still does gather + decompress + operators, so
offloaded verbs over cold data run at line rate instead of bouncing
through a host-side inflate.

Descriptor layout (one row per logical page, built by `FarPool.tier_desc`):

  phys    (P,)   int32   raw page id, or the cold frame holding the stream
  mode    (P,C)  int32   per column plane: MODE_RAW | MODE_DELTA | MODE_DICT
  width   (P,C)  int32   packed bits per value (1..32)
  base    (P,C)  uint32  delta base (wrap-around add)
  dictoff (P,C)  int32   dictionary word offset, FRAME-relative
  bitoff  (P,C)  int32   packed plane bit offset, FRAME-relative
                         (a 2 MiB frame is 2^24 bits — fits int32)

A fully-raw page is one descriptor row of MODE_RAW planes whose `phys` is
the original page — including the scheduler's null-page bucket padding
(mode RAW + phys = null page reads zeros, masked by n_valid as before).
The decode is branch-free: every lane computes the raw word AND the
unpacked value (indices clamped in-bounds) and selects by mode, so mixed
hot/cold page lists stay ONE gather with no host-visible control flow.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.distributed.compress import MODE_DICT, MODE_RAW

# descriptor tuple order — every producer/consumer goes through these names
TIER_FIELDS = ("phys", "mode", "width", "base", "dictoff", "bitoff")


def null_descriptor(n_pages: int, n_cols: int, null_page: int):
    """An all-raw descriptor bundle pointing every page at `null_page` —
    what batched dispatch uses to pad a round's descriptor stack."""
    return (np.full((n_pages,), null_page, np.int32),
            np.full((n_pages, n_cols), MODE_RAW, np.int32),
            np.ones((n_pages, n_cols), np.int32),
            np.zeros((n_pages, n_cols), np.uint32),
            np.zeros((n_pages, n_cols), np.int32),
            np.zeros((n_pages, n_cols), np.int32))


def _decode_flat(buf, tier, g, page_words: int, n_cols: int):
    """Logical words at flat indices `g` (any shape, int32) -> uint32.

    Pure in (buf, tier); `page_words`/`n_cols` are static. For each index:
    locate its page + column plane, compute its rank within the plane
    (pages start mid-row when n_cols doesn't divide page_words — `phase`
    accounts for it), extract the packed value from a 2-word straddle
    read, then apply the plane's mode. All three candidate values are
    computed with clamped indices and selected by mode, keeping the
    program branch-free (vmap/batched-dispatch safe).
    """
    phys, mode, width, base, dictoff, bitoff = tier
    ubuf = jnp.asarray(buf, jnp.float32).view(jnp.uint32)
    pw = np.int32(page_words)
    C = np.int32(n_cols)

    p = g // pw                                  # logical page
    k = g % pw                                   # word within page
    c = g % C                                    # column plane (global idx)
    frame = phys[p]
    m = mode[p, c]
    w = width[p, c]

    # raw candidate: the word itself, straight from the (possibly null) page
    raw = ubuf[frame, k]

    # packed candidate: rank j of this word within its (page, column) plane
    phase = (p * pw) % C                         # column of page's word 0
    j = (k - (c - phase) % C) // C
    bit = bitoff[p, c] + j * w
    wi = jnp.clip(bit >> 5, 0, pw - 2)           # clamp: raw lanes don't read
    sh = (bit & 31).astype(jnp.uint32)
    lo = ubuf[frame, wi]
    hi = ubuf[frame, wi + 1]
    straddle = jnp.where(sh == 0, jnp.uint32(0),
                         hi << (jnp.uint32(32) - sh))
    packed = (lo >> sh) | straddle
    packed = packed & (jnp.uint32(0xFFFFFFFF)
                       >> (jnp.uint32(32) - w.astype(jnp.uint32)))

    # delta candidate: wrap-around add of the plane base (exact inverse)
    delta_val = packed + base[p, c]
    # dict candidate: frame-relative dictionary lookup (index clamped so
    # non-dict lanes stay in-bounds; their value is masked out by `m`)
    didx = jnp.clip(dictoff[p, c] + packed.astype(jnp.int32), 0, pw - 1)
    dict_val = ubuf[frame, didx]

    return jnp.where(m == MODE_RAW, raw,
                     jnp.where(m == MODE_DICT, dict_val, delta_val))


def gather_rows_tiered(buf, tier, n_rows: int, row_words: int,
                       page_words: int) -> jnp.ndarray:
    """Tiered analogue of `pool.gather_rows` -> (n_rows, row_words) f32.

    Byte-identical to gathering the raw pages: cold planes decode to the
    exact stored bit patterns (the codec works on u32 bitcasts, so NaN
    payloads survive). Safe inside a jitted/vmapped program."""
    g = (jnp.arange(n_rows, dtype=jnp.int32)[:, None] * np.int32(row_words)
         + jnp.arange(row_words, dtype=jnp.int32)[None, :])
    u = _decode_flat(buf, tier, g, page_words, row_words)
    return u.view(jnp.float32)


def gather_columns_tiered(buf, tier, n_rows: int, row_words: int,
                          col_idx: tuple[int, ...],
                          page_words: int) -> jnp.ndarray:
    """Tiered smart addressing -> (n_rows, k) f32: only the projected
    columns' planes are unpacked (a cold plane's packed words are the only
    DRAM the column touches — the accounting in `FarPool.tier_read_bytes`
    matches)."""
    g = (jnp.arange(n_rows, dtype=jnp.int32)[:, None] * np.int32(row_words)
         + jnp.asarray(col_idx, jnp.int32)[None, :])
    u = _decode_flat(buf, tier, g, page_words, row_words)
    return u.view(jnp.float32)
