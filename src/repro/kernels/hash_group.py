"""Distinct / group-by / aggregation kernel (paper §5.4).

TPU adaptation of Farview's cuckoo-hash + LRU-shift-register design:

  * FPGA BRAM hash tables -> a bucket table resident in VMEM across the whole
    grid (the output blocks are revisited by every grid step, so they act as
    on-chip accumulators, exactly like Farview's on-chip hash state).
  * hash lookups -> one-hot *matmuls* on the MXU. A (buckets x rows) one-hot
    matrix aggregates counts and sums in one dot; bucket "claims" (which key
    owns a bucket) are also resolved with one-hot matmuls over the 16-bit
    halves of the key so that f32 MXU arithmetic stays exact.
  * cuckoo collision eviction -> rows whose key differs from the bucket
    owner's key are flagged as *overflow* and shipped to the client for
    software post-aggregation — the same observable contract as the paper's
    collision buffer.
  * the LRU shift register (hazard protection) is unnecessary: the whole
    block is aggregated associatively in one step, so read-after-write
    hazards between consecutive tuples cannot occur.

Aggregates: count, sum, min, max (avg = sum/count client-side, as in ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import ref

DEFAULT_BLOCK_ROWS = 256
_BIG = np.float32(3.0e38)
_SENT = np.int32(ref.KEY_SENTINEL)


def _halves(keys_u32):
    hi = (keys_u32 >> np.uint32(16)).astype(jnp.float32)
    lo = (keys_u32 & np.uint32(0xFFFF)).astype(jnp.float32)
    return hi, lo


def _recombine(hi_f, lo_f):
    hi = jnp.round(hi_f).astype(jnp.uint32)
    lo = jnp.round(lo_f).astype(jnp.uint32)
    return ((hi << np.uint32(16)) | lo).astype(jnp.int32)


def _kernel(n_buckets, keys_ref, vals_ref, bkey_ref, cnt_ref, sum_ref,
            min_ref, max_ref, ovf_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        bkey_ref[...] = jnp.full_like(bkey_ref, _SENT)
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        sum_ref[...] = jnp.zeros_like(sum_ref)
        min_ref[...] = jnp.full_like(min_ref, _BIG)
        max_ref[...] = jnp.full_like(max_ref, -_BIG)

    keys = keys_ref[...][:, 0]                                # (R,) int32
    vals = vals_ref[...]                                      # (R, V) f32
    r = keys.shape[0]
    b = n_buckets

    ku = keys.astype(jnp.uint32)
    h = (ku * np.uint32(0x9E3779B1)) >> np.uint32(32 - int(np.log2(b)))
    bucket = h.astype(jnp.int32)                              # (R,)

    # one-hot (B, R): bucket membership, built on the VPU.
    iota_b = jax.lax.broadcasted_iota(jnp.int32, (b, r), 0)
    member = (bucket[None, :] == iota_b)                      # (B, R) bool

    # --- per-block claimant: lowest row index in each bucket ----------------
    iota_r = jax.lax.broadcasted_iota(jnp.int32, (b, r), 1)
    first_idx = jnp.min(jnp.where(member, iota_r, r), axis=1)  # (B,)
    nonempty = first_idx < r
    first_sel = (iota_r == first_idx[:, None]) & member        # (B, R) one-hot
    fsel_f = first_sel.astype(jnp.float32)
    khi, klo = _halves(ku)
    blk_hi = jax.lax.dot(fsel_f, khi[:, None],
                         precision=jax.lax.Precision.HIGHEST)[:, 0]
    blk_lo = jax.lax.dot(fsel_f, klo[:, None],
                         precision=jax.lax.Precision.HIGHEST)[:, 0]
    blk_key = jnp.where(nonempty, _recombine(blk_hi, blk_lo), _SENT)

    # --- merge with the global bucket table (claim if empty) ---------------
    cur = bkey_ref[...][:, 0]
    newkey = jnp.where(cur == _SENT, blk_key, cur)
    bkey_ref[...] = newkey[:, None]

    # --- ownership: does each row's key match its bucket's owner? ----------
    # gather owner key per row with exact one-hot matmuls over 16-bit halves
    mem_f = member.astype(jnp.float32)                        # (B, R)
    ohi, olo = _halves(newkey.astype(jnp.uint32))
    row_hi = jax.lax.dot(ohi[None, :], mem_f,
                         precision=jax.lax.Precision.HIGHEST)[0]
    row_lo = jax.lax.dot(olo[None, :], mem_f,
                         precision=jax.lax.Precision.HIGHEST)[0]
    owner_key = _recombine(row_hi, row_lo)                    # (R,)
    owns = keys == owner_key
    ovf_ref[...] = (~owns).astype(jnp.int32)[:, None]

    owned = member & owns[None, :]                            # (B, R)
    owned_f = owned.astype(jnp.float32)

    # --- aggregate on the MXU ----------------------------------------------
    cnt_ref[...] = cnt_ref[...] + jnp.round(jax.lax.dot(
        owned_f, jnp.ones((r, 1), jnp.float32),
        precision=jax.lax.Precision.HIGHEST)).astype(jnp.int32)
    sum_ref[...] = sum_ref[...] + jax.lax.dot(
        owned_f, vals.astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST)

    # --- min/max: masked reductions, bucket-chunked to bound VMEM ----------
    nv = vals.shape[1]
    chunk = min(32, b)
    valsf = vals.astype(jnp.float32)

    def mm_step(i, carry):
        cmin, cmax = carry
        own_c = jax.lax.dynamic_slice(owned, (i * chunk, 0), (chunk, r))
        sel = own_c[:, :, None]                               # (c, R, 1)
        vmin = jnp.min(jnp.where(sel, valsf[None], _BIG), axis=1)
        vmax = jnp.max(jnp.where(sel, valsf[None], -_BIG), axis=1)
        cmin = jax.lax.dynamic_update_slice(cmin, vmin, (i * chunk, 0))
        cmax = jax.lax.dynamic_update_slice(cmax, vmax, (i * chunk, 0))
        return cmin, cmax

    blk_min, blk_max = jax.lax.fori_loop(
        0, b // chunk, mm_step,
        (jnp.full((b, nv), _BIG), jnp.full((b, nv), -_BIG)))
    min_ref[...] = jnp.minimum(min_ref[...], blk_min)
    max_ref[...] = jnp.maximum(max_ref[...], blk_max)


@functools.partial(jax.jit,
                   static_argnames=("n_buckets", "block_rows", "interpret"))
def group_aggregate(keys: jnp.ndarray, values: jnp.ndarray, *,
                    n_buckets: int = 1024,
                    block_rows: int = DEFAULT_BLOCK_ROWS,
                    interpret: bool = True):
    """keys (N,1) int32, values (N,V) f32; N % block_rows == 0.

    Returns (bucket_keys (B,1) i32, count (B,1) i32, sum (B,V) f32,
             min (B,V) f32, max (B,V) f32, overflow_mask (N,1) i32).
    """
    n, _ = keys.shape
    v = values.shape[1]
    assert n % block_rows == 0
    assert n_buckets & (n_buckets - 1) == 0, "n_buckets must be a power of 2"
    nb = n // block_rows
    kern = functools.partial(_kernel, n_buckets)
    return pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_rows, v), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((n_buckets, 1), lambda i: (0, 0)),
            pl.BlockSpec((n_buckets, 1), lambda i: (0, 0)),
            pl.BlockSpec((n_buckets, v), lambda i: (0, 0)),
            pl.BlockSpec((n_buckets, v), lambda i: (0, 0)),
            pl.BlockSpec((n_buckets, v), lambda i: (0, 0)),
            pl.BlockSpec((block_rows, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_buckets, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_buckets, 1), jnp.int32),
            jax.ShapeDtypeStruct((n_buckets, v), jnp.float32),
            jax.ShapeDtypeStruct((n_buckets, v), jnp.float32),
            jax.ShapeDtypeStruct((n_buckets, v), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
        ],
        interpret=interpret,
    )(keys, values)
