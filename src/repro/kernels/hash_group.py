"""Distinct / group-by / aggregation kernel (paper §5.4).

TPU adaptation of Farview's cuckoo-hash + LRU-shift-register design,
restructured for scale (PR 4): the row stream is bucket-SORTED before the
kernel (stable composite-key value sort — part of the same jitted
program), bucket ownership is resolved globally on the sorted stream, and
the Pallas kernel aggregates into a SMALL, fixed number of partial bucket
tables that a tree merge combines:

  * hash lookups -> one-hot *matmuls* on the MXU, exactly as before: a
    (buckets x rows) one-hot matrix aggregates counts and sums in one dot.
  * FPGA BRAM hash tables -> per-grid-row partial bucket tables. The grid
    is (P, G): row p accumulates its G consecutive row-blocks into its own
    VMEM-resident (B, V) partial (the revisited-output accumulator
    pattern, scoped to one grid row), and P is capped at MAX_PARTIALS so
    partial memory stays P*B*V — never the O(n/block_rows * B * V) blowup
    a one-partial-per-block layout would allocate.
  * the P partials are combined by a log-depth pairwise TREE MERGE
    (`tree_merge`, plain jnp): count/sum add, min/max meet — associative,
    so any merge order is valid. Grid rows share NO state; only the
    G blocks inside a row accumulate sequentially (like the paper's
    on-chip hash state, which Farview also banks per pipeline).
  * cuckoo collision eviction -> rows whose key differs from the bucket
    owner's key are flagged *overflow* and shipped to the client for
    software post-aggregation. Ownership (first row by ORIGINAL index
    claims the bucket) is computed once, globally, on the sorted stream —
    block-local claims would disagree with the global claimant whenever a
    bucket spans a block boundary, so claims never enter the kernel.
  * the LRU shift register (hazard protection) stays unnecessary: each
    block is aggregated associatively in one step, and the tree merge has
    no read-after-write hazards at all.

Aggregates: count, sum, min, max (avg = sum/count client-side, as in ops.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import ref

DEFAULT_BLOCK_ROWS = 256
MAX_PARTIALS = 8            # cap on partial bucket tables (VMEM/HBM bound)
_BIG = np.float32(3.0e38)
_SENT = np.int32(ref.KEY_SENTINEL)


def _block_kernel(n_buckets, bucket_ref, vals_ref, owns_ref,
                  cnt_ref, sum_ref, min_ref, max_ref):
    """Grid (P, G): partial p accumulates its g-th row-block. The output
    blocks for partial p stay resident across that row's G steps (standard
    revisited-accumulator pattern); different partials never touch each
    other's state."""
    g = pl.program_id(1)

    @pl.when(g == 0)
    def _init():
        cnt_ref[...] = jnp.zeros_like(cnt_ref)
        sum_ref[...] = jnp.zeros_like(sum_ref)
        min_ref[...] = jnp.full_like(min_ref, _BIG)
        max_ref[...] = jnp.full_like(max_ref, -_BIG)

    bucket = bucket_ref[...][:, 0]                            # (R,) int32
    vals = vals_ref[...]                                      # (R, V) f32
    owns = owns_ref[...][:, 0] > 0                            # (R,) bool
    r = bucket.shape[0]
    b = n_buckets

    # one-hot (B, R): bucket membership of owned rows, built on the VPU.
    iota_b = jax.lax.broadcasted_iota(jnp.int32, (b, r), 0)
    owned = (bucket[None, :] == iota_b) & owns[None, :]       # (B, R)
    owned_f = owned.astype(jnp.float32)

    # --- aggregate on the MXU ----------------------------------------------
    cnt_ref[0] = cnt_ref[0] + jnp.round(jax.lax.dot(
        owned_f, jnp.ones((r, 1), jnp.float32),
        precision=jax.lax.Precision.HIGHEST)).astype(jnp.int32)
    sum_ref[0] = sum_ref[0] + jax.lax.dot(
        owned_f, vals.astype(jnp.float32),
        precision=jax.lax.Precision.HIGHEST)

    # --- min/max: masked reductions, bucket-chunked to bound VMEM ----------
    nv = vals.shape[1]
    chunk = min(32, b)
    valsf = vals.astype(jnp.float32)

    def mm_step(i, carry):
        cmin, cmax = carry
        own_c = jax.lax.dynamic_slice(owned, (i * chunk, 0), (chunk, r))
        sel = own_c[:, :, None]                               # (c, R, 1)
        vmin = jnp.min(jnp.where(sel, valsf[None], _BIG), axis=1)
        vmax = jnp.max(jnp.where(sel, valsf[None], -_BIG), axis=1)
        cmin = jax.lax.dynamic_update_slice(cmin, vmin, (i * chunk, 0))
        cmax = jax.lax.dynamic_update_slice(cmax, vmax, (i * chunk, 0))
        return cmin, cmax

    blk_min, blk_max = jax.lax.fori_loop(
        0, b // chunk, mm_step,
        (jnp.full((b, nv), _BIG), jnp.full((b, nv), -_BIG)))
    min_ref[0] = jnp.minimum(min_ref[0], blk_min)
    max_ref[0] = jnp.maximum(max_ref[0], blk_max)


def tree_merge(cnt, s, mn, mx):
    """Log-depth pairwise merge of per-partial aggregates over axis 0.

    cnt (P, B, 1) i32; s/mn/mx (P, B, V) f32. The combine is associative
    (add / add / min / max), so the merge tree is exact for count/min/max
    and order-insensitive up to f32 rounding for sum.
    """
    while cnt.shape[0] > 1:
        p = cnt.shape[0]
        if p % 2:       # odd level: pad one identity partial
            cnt = jnp.concatenate([cnt, jnp.zeros_like(cnt[:1])])
            s = jnp.concatenate([s, jnp.zeros_like(s[:1])])
            mn = jnp.concatenate([mn, jnp.full_like(mn[:1], _BIG)])
            mx = jnp.concatenate([mx, jnp.full_like(mx[:1], -_BIG)])
        cnt = cnt[0::2] + cnt[1::2]
        s = s[0::2] + s[1::2]
        mn = jnp.minimum(mn[0::2], mn[1::2])
        mx = jnp.maximum(mx[0::2], mx[1::2])
    return cnt[0], s[0], mn[0], mx[0]


@functools.partial(jax.jit,
                   static_argnames=("n_buckets", "block_rows", "interpret"))
def group_aggregate(keys: jnp.ndarray, values: jnp.ndarray, *,
                    n_buckets: int = 1024,
                    block_rows: int = DEFAULT_BLOCK_ROWS,
                    interpret: bool = True):
    """keys (N,1) int32, values (N,V) f32; N % block_rows == 0.

    Returns (bucket_keys (B,1) i32, count (B,1) i32, sum (B,V) f32,
             min (B,V) f32, max (B,V) f32, overflow_mask (N,1) i32) —
    the same contract as kernels/ref.py:group_aggregate, field for field.
    """
    n, _ = keys.shape
    v = values.shape[1]
    assert n % block_rows == 0
    assert n_buckets & (n_buckets - 1) == 0, "n_buckets must be a power of 2"
    k1 = keys[:, 0]

    # --- sort by bucket + global first-claim ownership (pure XLA) ----------
    bucket = ref.bucket_of(k1, n_buckets)
    order, sb = ref.sort_by_bucket(bucket, n_buckets)
    start, _end, nonempty = ref.segment_spans(sb, n_buckets)
    claimed = jnp.where(nonempty, k1[order[start]], _SENT)
    owns = k1 == claimed[bucket]
    ovf = (~owns).astype(jnp.int32)[:, None]        # original row order

    # --- grid shape: P partials x G blocks each, P <= MAX_PARTIALS ---------
    sv = values[order]
    so = owns[order].astype(jnp.int32)[:, None]
    nb_total = n // block_rows
    p = min(nb_total, MAX_PARTIALS)
    g = -(-nb_total // p)
    pad_rows = p * g * block_rows - n
    if pad_rows:
        # inert pad: owns=0 rows contribute to no bucket (bucket id is
        # irrelevant once the owned one-hot masks them out)
        sb = jnp.concatenate([sb, jnp.zeros((pad_rows,), sb.dtype)])
        sv = jnp.concatenate([sv, jnp.zeros((pad_rows, v), sv.dtype)])
        so = jnp.concatenate([so, jnp.zeros((pad_rows, 1), so.dtype)])

    # --- block-local one-hot MXU aggregation over the sorted stream --------
    kern = functools.partial(_block_kernel, n_buckets)
    cnt_p, sum_p, min_p, max_p = pl.pallas_call(
        kern,
        grid=(p, g),
        in_specs=[
            pl.BlockSpec((block_rows, 1), lambda i, j, g=g: (i * g + j, 0)),
            pl.BlockSpec((block_rows, v), lambda i, j, g=g: (i * g + j, 0)),
            pl.BlockSpec((block_rows, 1), lambda i, j, g=g: (i * g + j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, n_buckets, 1), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, n_buckets, v), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, n_buckets, v), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, n_buckets, v), lambda i, j: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, n_buckets, 1), jnp.int32),
            jax.ShapeDtypeStruct((p, n_buckets, v), jnp.float32),
            jax.ShapeDtypeStruct((p, n_buckets, v), jnp.float32),
            jax.ShapeDtypeStruct((p, n_buckets, v), jnp.float32),
        ],
        interpret=interpret,
    )(sb[:, None], sv, so)

    # --- tree merge of the partials ----------------------------------------
    cnt, s, mn, mx = tree_merge(cnt_p, sum_p, min_p, max_p)
    return claimed[:, None], cnt, s, mn, mx, ovf
