"""Far-KV partial attention kernel (flash-decoding over one KV-cache shard).

This is the paper's operator push-down applied to LM serving: the KV cache is
the disaggregated buffer pool, and instead of shipping raw K/V rows to the
querying device (the "RCPU" baseline), the shard owner computes a *partial*
softmax-weighted sum — the aggregation operator — and ships only
(o, m, l): d_head + 2 floats per head instead of 2 * S_shard * d_head.

Kernel structure (flash-decoding, TPU-native):
  * grid = (batch, kv_heads, S_blocks); the S dimension is sequential, so the
    output blocks (revisited every step) act as VMEM accumulators.
  * Each step: scores = Q G-group @ K-block^t on the MXU, running-max rescale
    on the VPU, P @ V-block accumulate on the MXU.
  * Masking by cache length handles ragged batches (continuous batching).

Outputs are *unnormalized* partials; repro.core.far_kv merges them across
shards with a log-sum-exp weighted combine (ref.merge_partials).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_KV = 256
NEG_INF = -1.0e30


def _kernel(scale, block_kv, q_ref, k_ref, v_ref, len_ref,
            o_ref, m_ref, l_ref):
    s_idx = pl.program_id(2)

    @pl.when(s_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[...][0, 0].astype(jnp.float32)                  # (G, D)
    k = k_ref[...][0, 0].astype(jnp.float32)                  # (T, D)
    v = v_ref[...][0, 0].astype(jnp.float32)                  # (T, D)
    length = len_ref[0, 0]

    t = k.shape[0]
    pos = s_idx * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (1, t), 1)                                 # (1, T)
    valid = pos < length

    scores = jax.lax.dot(q, k.T,
                         precision=jax.lax.Precision.HIGHEST) * scale  # (G, T)
    scores = jnp.where(valid, scores, NEG_INF)

    m_prev = m_ref[...][0, 0]                                 # (G, 1)
    l_prev = l_ref[...][0, 0]                                 # (G, 1)
    o_prev = o_ref[...][0, 0]                                 # (G, D)

    m_cur = jnp.max(scores, axis=1, keepdims=True)            # (G, 1)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)                           # (G, 1)
    p = jnp.where(valid, jnp.exp(scores - m_new), 0.0)        # (G, T)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    o_new = o_prev * alpha + jax.lax.dot(
        p, v, precision=jax.lax.Precision.HIGHEST)

    o_ref[...] = o_new[None, None]
    m_ref[...] = m_new[None, None]
    l_ref[...] = l_new[None, None]


@functools.partial(jax.jit,
                   static_argnames=("scale", "block_kv", "interpret"))
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     lengths: jnp.ndarray, *, scale: float,
                     block_kv: int = DEFAULT_BLOCK_KV,
                     interpret: bool = True):
    """q: (B, Hkv, G, D); k/v: (B, Hkv, S, D); lengths: (B, 1) int32.

    S % block_kv == 0; G a multiple of 8 and D of 128 (wrapper pads).
    Returns partials o (B, Hkv, G, D) f32, m (B, Hkv, G, 1), l (B, Hkv, G, 1).
    """
    b, hkv, g, d = q.shape
    s = k.shape[2]
    assert s % block_kv == 0, (s, block_kv)
    nsb = s // block_kv
    kern = functools.partial(_kernel, scale, block_kv)
    return pl.pallas_call(
        kern,
        grid=(b, hkv, nsb),
        in_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, si: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bi, hi, si: (bi, hi, si, 0)),
            pl.BlockSpec((1, 1, block_kv, d),
                         lambda bi, hi, si: (bi, hi, si, 0)),
            pl.BlockSpec((1, 1), lambda bi, hi, si: (bi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, g, d), lambda bi, hi, si: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda bi, hi, si: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, g, 1), lambda bi, hi, si: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hkv, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g, 1), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, g, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, lengths)
