"""Public jit'd wrappers around the Pallas kernels.

Handles: padding to tile boundaries, layout transforms (transposes, halves),
platform auto-detection (interpret=True off-TPU), and result un-padding.
These are the entry points the core/ layer and the benchmarks call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import (ctr_crypt as _ctr, decode_attention as _dec,
                           dfa_match as _dfa, hash_group as _hg,
                           hash_join as _hj, ref,
                           select_project as _sp)


@functools.cache
def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int, value=0):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


# ---------------------------------------------------------------------------
# selection / projection
# ---------------------------------------------------------------------------
def select_project(table, sel_ops, sel_vals, proj_mask, *,
                   block_rows: int = 256, interpret: bool | None = None):
    """table (N, A) f32; sel_ops (A,) i32; sel_vals/proj_mask (A,) f32.

    Returns (packed (N, A) f32 globally compacted, count scalar i32).
    """
    if interpret is None:
        interpret = _interpret_default()
    n, a = table.shape
    t = _pad_to(_pad_to(table.astype(jnp.float32), 1, 128), 0, block_rows)
    c = t.shape[1]
    # Padded columns must not affect the predicate: pad ops with OP_SKIP.
    ops2 = _pad_to(sel_ops.astype(jnp.int32)[None, :], 1, 128,
                   value=ref.OP_SKIP)
    vals2 = _pad_to(sel_vals.astype(jnp.float32)[None, :], 1, 128)
    proj2 = _pad_to(proj_mask.astype(jnp.float32)[None, :], 1, 128)
    # Padded rows must not match: force a row of zeros to fail via an
    # explicit valid-row column? Simpler: padded rows are all-zero; make them
    # fail by post-masking counts — we instead mask them here.
    packed_b, counts = _sp.select_project(t, ops2, vals2, proj2,
                                          block_rows=block_rows,
                                          interpret=interpret)
    np_rows = t.shape[0]
    nb = counts.shape[0]
    # Padded tail rows are all-zero; if the predicate accepts a zero row they
    # matched spuriously. Stable compaction puts them *after* every real
    # survivor of their (last) block, so trimming the count is exact.
    rows_in_block = jnp.minimum(
        block_rows, jnp.maximum(0, n - jnp.arange(nb) * block_rows))
    zero_row = jnp.zeros((1, c), t.dtype)
    zero_match = ref.eval_predicate(zero_row, ops2[0], vals2[0])[0]
    pad_rows = (block_rows - rows_in_block).astype(jnp.int32)
    counts = counts[:, 0].astype(jnp.int32) - jnp.where(zero_match, pad_rows, 0)
    # --- stitch blocks (the paper's length-prefixed response packets) ------
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1]])
    total = jnp.sum(counts)
    blk = jnp.arange(np_rows, dtype=jnp.int32) // block_rows
    off = jnp.arange(np_rows, dtype=jnp.int32) % block_rows
    valid = off < counts[blk]
    dest = jnp.where(valid, offsets[blk] + off, np_rows)  # OOB => dropped
    out = jnp.zeros_like(packed_b).at[dest].set(packed_b, mode="drop")
    return out[:n, :a], total


# ---------------------------------------------------------------------------
# grouping
# ---------------------------------------------------------------------------
def group_aggregate(keys, values, *, n_buckets: int = 1024,
                    block_rows: int = 256, interpret: bool | None = None):
    """keys (N,) int32, values (N, V) f32 -> dict of aggregates + overflow.

    Overflow rows (bucket collisions) are returned for client-side merge,
    mirroring the paper's cuckoo-overflow contract.
    """
    if interpret is None:
        interpret = _interpret_default()
    n = keys.shape[0]
    v = values.shape[1]
    kp = _pad_to(keys.astype(jnp.int32)[:, None], 0, block_rows,
                 value=ref.KEY_SENTINEL + 1)  # sentinel+1: a real-ish key
    vp = _pad_to(values.astype(jnp.float32), 0, block_rows)
    vp = _pad_to(vp, 1, 128)
    bkey, cnt, s, mn, mx, ovf = _hg.group_aggregate(
        kp, vp, n_buckets=n_buckets,
        block_rows=block_rows, interpret=interpret)
    # Remove padded rows' contribution: padded rows all carry the same key
    # (KEY_SENTINEL+1); subtract them exactly.
    npad = kp.shape[0] - n
    if npad:
        pad_key = jnp.int32(ref.KEY_SENTINEL + 1)
        pb = ref.bucket_of(pad_key[None], n_buckets)[0]
        owned_pad = bkey[pb, 0] == pad_key
        # they contributed `npad` count and zero sums (values padded w/ 0)
        cnt = cnt.at[pb, 0].add(jnp.where(owned_pad, -npad, 0))
        empty_now = owned_pad & (cnt[pb, 0] == 0)
        bkey = bkey.at[pb, 0].set(jnp.where(empty_now, ref.KEY_SENTINEL,
                                            bkey[pb, 0]))
        # min/max may be polluted by pad zeros when the pad key owns pb; that
        # bucket is dropped client-side if empty; if the pad key collided
        # with a real key, pads are overflow rows (handled below).
    ovf = ovf[:n, 0].astype(bool)
    return dict(bucket_keys=bkey[:, 0], count=cnt[:, 0], sum=s[:, :v],
                min=mn[:, :v], max=mx[:, :v], overflow_mask=ovf)


def group_aggregate_full(keys, values, *, n_buckets: int = 1024,
                         block_rows: int = 256,
                         interpret: bool | None = None):
    """Kernel aggregation + client-side overflow merge -> exact dict result.

    This is the end-to-end paper contract: the smart memory aggregates what
    fits its hash table; collision overflow is merged in "client software".
    Returns {key: (count, sum, min, max)} over *all* keys.
    """
    res = group_aggregate(keys, values, n_buckets=n_buckets,
                          block_rows=block_rows, interpret=interpret)
    return _finalize_group_full(keys, values, res)


def _finalize_group_full(keys, values, res):
    """Finalize boundary: sync the kernel's lazy bucket outputs to the host
    and merge collision overflow in "client software" (the paper's split).
    The only host transfer in the group path lives here."""
    out: dict[int, tuple] = {}
    bkeys = np.asarray(res["bucket_keys"])
    cnts = np.asarray(res["count"])
    sums = np.asarray(res["sum"])
    mins = np.asarray(res["min"])
    maxs = np.asarray(res["max"])
    for i in range(bkeys.shape[0]):
        if bkeys[i] != ref.KEY_SENTINEL and cnts[i] > 0:
            out[int(bkeys[i])] = (int(cnts[i]), sums[i].copy(),
                                  mins[i].copy(), maxs[i].copy())
    ovf = np.asarray(res["overflow_mask"])
    kh = np.asarray(keys)[ovf]
    vh = np.asarray(values)[ovf]
    for k, row in zip(kh.tolist(), vh):
        if k in out:
            c, s, mn, mx = out[k]
            out[k] = (c + 1, s + row, np.minimum(mn, row),
                      np.maximum(mx, row))
        else:
            out[k] = (1, row.astype(np.float32).copy(), row.copy(),
                      row.copy())
    return out


def distinct(keys, *, n_buckets: int = 1024, block_rows: int = 256,
             interpret: bool | None = None):
    """DISTINCT via group_aggregate (count-only) + client-side overflow dedup."""
    vals = jnp.zeros((keys.shape[0], 1), jnp.float32)
    res = group_aggregate(keys, vals, n_buckets=n_buckets,
                          block_rows=block_rows, interpret=interpret)
    return _finalize_distinct(keys, res)


def _finalize_distinct(keys, res):
    """Finalize boundary: host-side dedup of bucket keys + overflow rows."""
    bk = np.asarray(res["bucket_keys"])
    cnt = np.asarray(res["count"])
    found = set(bk[(bk != ref.KEY_SENTINEL) & (cnt > 0)].tolist())
    ovf_keys = np.asarray(keys)[np.asarray(res["overflow_mask"])]
    found.update(ovf_keys.tolist())
    return sorted(found)


# ---------------------------------------------------------------------------
# regex
# ---------------------------------------------------------------------------
def regex_match(strings, lengths, table, accept, *,
                block_rows: int = 128, interpret: bool | None = None):
    """strings (N, L) uint8/int32; lengths (N,) i32; table (S, 256) i32;
    accept (S,) bool. Returns (N,) bool match mask."""
    if interpret is None:
        interpret = _interpret_default()
    n, l = strings.shape
    chars_t = _pad_to(strings.astype(jnp.int32).T, 1, block_rows)
    lens = _pad_to(lengths.astype(jnp.int32)[None, :], 1, block_rows)
    s = table.shape[0]
    table_t = table.astype(jnp.float32).T                     # (256, S)
    acc = accept.astype(jnp.float32)[None, :]                 # (1, S)
    out = _dfa.dfa_match(chars_t, lens, table_t, acc,
                         block_rows=block_rows, interpret=interpret)
    return out[:n].astype(bool)


# ---------------------------------------------------------------------------
# encryption
# ---------------------------------------------------------------------------
def crypt(data_u32, key2_u32, nonce: int, *, interpret: bool | None = None):
    """data (N,) uint32; key (2,) uint32; involutive CTR cipher."""
    if interpret is None:
        interpret = _interpret_default()
    n = data_u32.shape[0]
    cols = 128
    x = _pad_to(data_u32.astype(jnp.uint32)[None, :], 1, 256 * cols)
    x = x.reshape(-1, cols)
    key = jnp.array([[int(key2_u32[0]), int(key2_u32[1]), nonce & 0xFFFFFFFF,
                      0]], dtype=jnp.uint32)
    y = _ctr.ctr_crypt(x, key, interpret=interpret)
    return y.reshape(-1)[:n]


# ---------------------------------------------------------------------------
# far-KV decode attention
# ---------------------------------------------------------------------------
def decode_attention(q, k, v, lengths, *, scale: float | None = None,
                     block_kv: int = 256, interpret: bool | None = None):
    """q (B, Hq, D); k/v (B, S, Hkv, D); lengths (B,).

    Returns unnormalized partials (o (B,Hq,D) f32, m (B,Hq), l (B,Hq)) for
    cross-shard merging with ref.merge_partials.
    """
    if interpret is None:
        interpret = _interpret_default()
    b, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    if scale is None:
        scale = 1.0 / float(np.sqrt(d))
    gp = max(8, g)
    dp = ((d + 127) // 128) * 128
    sp = ((s + block_kv - 1) // block_kv) * block_kv
    qk = jnp.zeros((b, hkv, gp, dp), q.dtype)
    qk = qk.at[:, :, :g, :d].set(q.reshape(b, hkv, g, d))
    kt = jnp.zeros((b, hkv, sp, dp), k.dtype)
    kt = kt.at[:, :, :s, :d].set(jnp.swapaxes(k, 1, 2))
    vt = jnp.zeros((b, hkv, sp, dp), v.dtype)
    vt = vt.at[:, :, :s, :d].set(jnp.swapaxes(v, 1, 2))
    lens = lengths.astype(jnp.int32)[:, None]
    o, m, l = _dec.decode_attention(qk, kt, vt, lens, scale=float(scale),
                                    block_kv=block_kv, interpret=interpret)
    o = o[:, :, :g, :d].reshape(b, hq, d)
    m = m[:, :, :g, 0].reshape(b, hq)
    l = l[:, :, :g, 0].reshape(b, hq)
    return o, m, l


# ---------------------------------------------------------------------------
# small-table join
# ---------------------------------------------------------------------------
def hash_join(probe_keys, build_keys, build_vals, *, block_rows: int = 256,
              interpret: bool | None = None):
    """probe_keys (N,) i32; build_keys (K,) i32 UNIQUE; build_vals (K,V) f32.

    Inner join against a small build table resident in VMEM (paper
    §Conclusions future work). Returns (joined (N, V), hit (N,) bool).
    """
    if interpret is None:
        interpret = _interpret_default()
    if not isinstance(build_keys, jax.core.Tracer):
        bk = np.asarray(build_keys)
        if len(np.unique(bk)) != len(bk):
            raise ValueError(
                "build keys must be unique for a small-table join")
    n = probe_keys.shape[0]
    k, v = build_vals.shape
    if k == 0:      # empty co-partitioned build shard: nothing matches
        return (jnp.zeros((n, v), jnp.float32), jnp.zeros((n,), bool))
    pk = _pad_to(probe_keys.astype(jnp.int32)[:, None], 0, block_rows,
                 value=ref.KEY_SENTINEL)        # sentinel never matches
    bkp = _pad_to(build_keys.astype(jnp.int32)[:, None], 0, 8,
                  value=ref.KEY_SENTINEL + 1)   # distinct pad key
    bvp = _pad_to(_pad_to(build_vals.astype(jnp.float32), 0, 8), 1, 128)
    joined, hit = _hj.hash_join(pk, bkp, bvp, block_rows=block_rows,
                                interpret=interpret)
    return joined[:n, :v], hit[:n, 0].astype(bool)


# ---------------------------------------------------------------------------
# XLA-native lowerings (fused request path off-TPU)
# ---------------------------------------------------------------------------
# The fused pipeline executable (core/pipeline.py) uses these when the
# Pallas kernels would run in interpret mode: same operator contracts as the
# kernels above (asserted against kernels/ref.py by tests/test_fused_path.py)
# but lowered to plain XLA ops, which on CPU are ~50x faster than emulating
# the MXU datapath. No tile padding or layout transforms are needed, so the
# traced program stays glue-free.

def select_project_xla(table, sel_ops, sel_vals, proj_mask, valid=None):
    """ref.select_project semantics + an optional row-validity mask.

    table (N, A) f32; sel_ops (A,) i32; sel_vals/proj_mask (A,) f32;
    valid (N,) bool or None. Returns (packed (N, A), count scalar i32):
    surviving valid rows stably compacted to the front, dropped columns
    zeroed, tail zero-filled.
    """
    mask = ref.eval_predicate(table, jnp.asarray(sel_ops),
                              jnp.asarray(sel_vals))
    if valid is not None:
        mask = mask & valid
    projected = jnp.where(jnp.asarray(proj_mask)[None, :].astype(bool),
                          table, 0)
    order = jnp.argsort(~mask, stable=True)
    packed = jnp.where(mask[order][:, None], projected[order], 0)
    return packed, jnp.sum(mask.astype(jnp.int32))


def hash_join_xla(probe_keys, build_keys, build_vals):
    """kernels.hash_join contract via sorted lookup (no VMEM hash table).

    probe_keys (N,) i32; build_keys (K,) i32 unique; build_vals (K, V) f32.
    Returns (joined (N, V) — matched build row or zeros, hit (N,) bool).
    K may be 0 (an empty co-partitioned build shard): nothing matches.
    """
    if build_keys.shape[0] == 0:
        n = probe_keys.shape[0]
        return (jnp.zeros((n, build_vals.shape[1]), jnp.float32),
                jnp.zeros((n,), bool))
    order = jnp.argsort(build_keys)
    sk = build_keys[order]
    sv = build_vals[order]
    idx = jnp.clip(jnp.searchsorted(sk, probe_keys), 0, sk.shape[0] - 1)
    hit = sk[idx] == probe_keys
    joined = jnp.where(hit[:, None], sv[idx], 0.0)
    return joined.astype(jnp.float32), hit
