"""Streaming selection + projection + packing kernel (paper §5.2-5.3, §5.5).

TPU adaptation of Farview's bump-in-the-wire filter pipeline:
  * the pallas grid streams row blocks HBM->VMEM (the AXI-stream analogue),
  * the predicate is evaluated on the VPU over the whole block at once
    (Farview's "vectorized model": lanes = parallel selection engines),
  * compaction ("packing") is a permutation *matmul* on the MXU: survivors
    are moved to the front of the block with P @ rows where
    P[i, j] = (prefix_sum(mask)[j]-1 == i) & mask[j],
  * per-block survivor counts are emitted alongside — these are the
    length-prefixed RDMA response packets of the paper's sender unit.

Blocks are (rows=256, cols=128) f32 tiles: cols padded to one lane-width,
rows a multiple of the 8-sublane f32 tile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref

DEFAULT_BLOCK_ROWS = 256


def _kernel(table_ref, ops_ref, vals_ref, proj_ref, packed_ref, count_ref):
    rows = table_ref[...]                                    # (R, C) f32
    ops = ops_ref[...]                                       # (1, C) i32
    vals = vals_ref[...]                                     # (1, C) f32
    proj = proj_ref[...]                                     # (1, C) f32

    # --- predicate (VPU) ---------------------------------------------------
    per_col = jnp.where(
        ops == ref.OP_LT, rows < vals,
        jnp.where(ops == ref.OP_LE, rows <= vals,
                  jnp.where(ops == ref.OP_GT, rows > vals,
                            jnp.where(ops == ref.OP_GE, rows >= vals,
                                      jnp.where(ops == ref.OP_EQ, rows == vals,
                                                jnp.where(ops == ref.OP_NE,
                                                          rows != vals,
                                                          True))))))
    mask = jnp.all(per_col, axis=1)                          # (R,)

    # --- projection (annotate columns, paper's projection_flags) -----------
    projected = rows * proj                                  # zero dropped cols

    # --- packing: compaction as a permutation matmul (MXU) ------------------
    r = rows.shape[0]
    pos = jnp.cumsum(mask.astype(jnp.int32)) - 1             # (R,)
    iota = jax.lax.broadcasted_iota(jnp.int32, (r, r), 0)     # out row index
    perm = ((pos[None, :] == iota) & mask[None, :]).astype(jnp.float32)
    packed = jax.lax.dot(perm, projected.astype(jnp.float32),
                         precision=jax.lax.Precision.HIGHEST)

    packed_ref[...] = packed.astype(packed_ref.dtype)
    count_ref[...] = jnp.sum(mask.astype(jnp.int32)).reshape(1, 1)


@functools.partial(jax.jit,
                   static_argnames=("block_rows", "interpret"))
def select_project(table: jnp.ndarray, sel_ops: jnp.ndarray,
                   sel_vals: jnp.ndarray, proj_mask: jnp.ndarray,
                   *, block_rows: int = DEFAULT_BLOCK_ROWS,
                   interpret: bool = True):
    """Per-block packed survivors + per-block counts.

    table: (N, C) f32, N % block_rows == 0, C % 128 == 0 (wrapper pads).
    sel_ops: (1, C) int32 opcodes; sel_vals/proj_mask: (1, C) f32.
    Returns: packed (N, C) f32 (block-local compaction), counts (nb, 1) i32.
    """
    n, c = table.shape
    assert n % block_rows == 0 and c % 128 == 0, (n, c)
    nb = n // block_rows
    grid = (nb,)
    packed, counts = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, c), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, c), table.dtype),
            jax.ShapeDtypeStruct((nb, 1), jnp.int32),
        ],
        interpret=interpret,
    )(table, sel_ops, sel_vals, proj_mask)
    return packed, counts
