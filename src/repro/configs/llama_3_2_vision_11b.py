"""llama-3.2-vision-11b [vlm] — cross-attn image layers
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]. Vision tower is a STUB:
input_specs() supplies projected patch embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="llama-3.2-vision-11b", family="vlm",
    n_layers=40, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=128256,
    cross_every=5, n_image_tokens=1600,
    tie_embeddings=False, rope_theta=500000.0,
)
