"""Config dataclasses: model architecture, shapes, train/serve settings."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    arch: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0           # 0 -> d_model // n_heads
    act: str = "silu"

    # attention flavor
    attn_pattern: str = "full"  # full | gemma2_alt | cross_every
    window: int = 0             # sliding window (gemma2 local layers)
    softcap_attn: float = 0.0
    softcap_logits: float = 0.0
    rope_theta: float = 10000.0
    tie_embeddings: bool = True
    scale_embed: bool = False   # gemma: x *= sqrt(d)

    # vlm
    cross_every: int = 0        # a cross-attn layer every k layers
    n_image_tokens: int = 0

    # audio (musicgen): frontend supplies embeddings
    embed_input: bool = False

    # moe
    n_experts: int = 0
    top_k: int = 0
    d_expert: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # ssm / hybrid / xlstm
    ssm_state: int = 0
    ssm_expand: int = 2
    slstm_every: int = 0        # xlstm: every k-th block is sLSTM
    shared_attn_every: int = 0  # zamba2: shared attn block every k mamba layers

    # numerics / structure
    param_dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    kv_chunk: int = 1024        # flash-attention KV chunk
    ssm_chunk: int = 256
    ce_chunk: int = 1024        # chunked cross-entropy sequence chunk
    remat: bool = True
    remat_policy: str = "nothing"   # nothing | dots_no_batch | dots

    # sub-quadratic? (drives long_500k applicability)
    subquadratic: bool = False

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class Shape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                   # train | prefill | decode


# the assigned input-shape set (identical for all 10 LM archs)
SHAPES: dict[str, Shape] = {
    "train_4k": Shape("train_4k", 4096, 256, "train"),
    "prefill_32k": Shape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": Shape("decode_32k", 32768, 128, "decode"),
    "long_500k": Shape("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    grad_clip: float = 1.0
    microbatch: int = 0             # 0 = no grad accumulation
    grad_compression: str = "none"  # none | int8_ef
    checkpoint_every: int = 200
    checkpoint_dir: str = "/tmp/repro_ckpt"
    seed: int = 0


@dataclass(frozen=True)
class ServeConfig:
    kv_mode: str = "far"            # far | naive | local
    max_seq: int = 4096
    batch: int = 8
    kv_dtype: str = "bfloat16"


def smoke_config(cfg: ModelConfig) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests."""
    kw = dict(
        n_layers=min(cfg.n_layers, 4),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads
        else 4,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        param_dtype="float32",
        kv_chunk=64,
        ssm_chunk=32,
        window=min(cfg.window, 64) if cfg.window else 0,
        n_image_tokens=32 if cfg.n_image_tokens else 0,
    )
    if cfg.n_experts:
        kw.update(n_experts=8, top_k=2, d_expert=64)
    if cfg.ssm_state:
        kw.update(ssm_state=16)
    if cfg.family == "hybrid":
        kw.update(n_layers=4, shared_attn_every=2)
    if cfg.family == "ssm":
        kw.update(n_layers=4, slstm_every=max(cfg.slstm_every, 0) and 4)
    if cfg.cross_every:
        kw.update(cross_every=2, n_layers=4)
    if cfg.attn_pattern == "gemma2_alt":
        kw.update(n_layers=4)
    return cfg.replace(**kw)
