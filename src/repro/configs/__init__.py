"""Config registry: --arch <id> -> ModelConfig, + input_specs per shape.

`input_specs(cfg, shape)` returns ShapeDtypeStruct stand-ins for every model
input (weak-type-correct, shardable, no device allocation) — the dry-run
lowers against these; smoke tests/examples materialize real arrays of the
same shapes (reduced).
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.base import (ModelConfig, ServeConfig, Shape, SHAPES,
                                TrainConfig, smoke_config)

_MODULES = {
    "xlstm-125m": "xlstm_125m",
    "gemma2-9b": "gemma2_9b",
    "granite-3-2b": "granite_3_2b",
    "yi-6b": "yi_6b",
    "granite-3-8b": "granite_3_8b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "musicgen-large": "musicgen_large",
    "llama-3.2-vision-11b": "llama_3_2_vision_11b",
    "zamba2-2.7b": "zamba2_2_7b",
}

ARCHS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; choose from {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def cell_is_applicable(cfg: ModelConfig, shape: Shape) -> tuple[bool, str]:
    """long_500k requires sub-quadratic attention (DESIGN.md §5)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, ("skipped: pure full-attention arch at 500k context "
                       "(quadratic prefill); run for ssm/hybrid only")
    return True, ""


def input_specs(cfg: ModelConfig, shape: Shape, *,
                dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStructs for one (arch x shape) cell's step function inputs."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        specs: dict = {}
        if cfg.embed_input:
            specs["embeds"] = sds((b, s, cfg.d_model), dtype)
        else:
            specs["tokens"] = sds((b, s), i32)
        if cfg.n_image_tokens:
            specs["image_embeds"] = sds((b, cfg.n_image_tokens, cfg.d_model),
                                        dtype)
        if shape.kind == "train":
            specs["labels"] = sds((b, s), i32)
        return specs
    # decode: one new token against a cache of seq_len
    specs = {}
    if cfg.embed_input:
        specs["embeds"] = sds((b, 1, cfg.d_model), dtype)
    else:
        specs["tokens"] = sds((b, 1), i32)
    return specs


def decode_cache_specs(cfg: ModelConfig, shape: Shape,
                       kv_dtype=jnp.bfloat16) -> dict:
    """ShapeDtypeStructs of the decode cache for one cell (no allocation)."""
    from repro.models.lm import LM
    lm = LM(cfg)
    shapes = jax.eval_shape(
        lambda: lm.init_cache(shape.global_batch, shape.seq_len, kv_dtype))
    return shapes
