"""zamba2-2.7b [hybrid] — Mamba2 + shared attn blocks
[arXiv:2411.15242; hf]. 54 Mamba2 layers; one SHARED transformer block
(weights reused) applied every 6 layers (9 applications)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000,
    ssm_state=64, shared_attn_every=6,
    tie_embeddings=True, subquadratic=True,
)
