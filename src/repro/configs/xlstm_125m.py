"""xlstm-125m [ssm] — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, slstm_every=4,          # (3 mLSTM + 1 sLSTM) x 3 groups
    tie_embeddings=True, subquadratic=True,
)
