"""qwen3-moe-30b-a3b [moe] — 128 experts top-8 [hf:Qwen/Qwen3-30B-A3B; hf].
The assigned d_ff=768 is the per-expert FFN width."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=0, vocab=151936,
    n_experts=128, top_k=8, d_expert=768,
    tie_embeddings=False, rope_theta=1000000.0,
)
