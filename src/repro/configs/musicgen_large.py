"""musicgen-large [audio] — decoder-only over EnCodec tokens
[arXiv:2306.05284; hf]. Frontend is a STUB: input_specs() supplies
precomputed frame embeddings (B, S, d_model)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="musicgen-large", family="audio",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=2048, act="gelu",
    embed_input=True, tie_embeddings=False,
)
