"""gemma2-9b [dense] — local+global alternating, logit softcap
[arXiv:2408.00118; hf]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="gemma2-9b", family="dense",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, head_dim=256,
    d_ff=14336, vocab=256000, act="gelu",
    attn_pattern="gemma2_alt", window=4096,
    softcap_attn=50.0, softcap_logits=30.0,
    scale_embed=True, tie_embeddings=True,
)
