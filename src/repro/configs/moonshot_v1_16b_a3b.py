"""moonshot-v1-16b-a3b [moe] — kimi/moonlight, 64e top-6
[hf:moonshotai/Moonlight-16B-A3B; hf]. d_ff=1408 is per-expert width."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=0, vocab=163840,
    n_experts=64, top_k=6, d_expert=1408,
    tie_embeddings=False,
)
