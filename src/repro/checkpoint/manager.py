"""Checkpointing: atomic, async, elastic-restore.

Layout:  <dir>/step_<N>/  arrays.npz (flattened pytree)  +  manifest.json
  * atomic: written to step_<N>.tmp, fsync'd, then os.rename (a crashed
    writer never corrupts the latest checkpoint),
  * async: `save_async` snapshots to host RAM synchronously (cheap) and
    writes in a background thread so the train loop never blocks on disk,
  * elastic: arrays are saved *unsharded* (gathered); restore re-shards onto
    whatever mesh the new job has (N->M hosts), which is what makes elastic
    re-mesh (runtime/fault.py) a pure restart-path operation,
  * retention: keep_last prunes old steps (the preempt checkpoint is always
    kept).
"""
from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


_EMPTY = "__empty_dict__"


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        if not tree:                   # preserve empty subtrees ({} leaves)
            out[f"{prefix}{_EMPTY}"] = np.zeros((0,), np.int8)
            return out
        for k, v in sorted(tree.items()):
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: dict):
    root: dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        cur = root
        for p in parts[:-1]:
            cur = cur.setdefault(p, {})
        cur[parts[-1]] = val

    def fix(node):
        if isinstance(node, dict):
            keys = list(node.keys())
            if keys == [_EMPTY]:
                return {}
            if keys and all(k.isdigit() for k in keys):
                return tuple(fix(node[str(i)]) for i in range(len(keys)))
            return {k: fix(v) for k, v in node.items()}
        return node

    return fix(root)


class CheckpointManager:
    def __init__(self, directory: str, *, keep_last: int = 3):
        self.dir = directory
        self.keep_last = keep_last
        os.makedirs(directory, exist_ok=True)
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    # ------------------------------------------------------------------ save
    def _write(self, step: int, host_tree: dict, meta: dict):
        tmp = os.path.join(self.dir, f"step_{step}.tmp")
        final = os.path.join(self.dir, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **host_tree)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump({"step": step, **meta}, f)
        with open(os.path.join(tmp, "manifest.json")) as f:
            os.fsync(f.fileno())
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)
        self._prune()

    def _prune(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep_last] if self.keep_last else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    def save(self, step: int, tree, meta: dict | None = None,
             *, asynchronous: bool = False):
        """Snapshot to host memory now; write to disk (maybe in background)."""
        flat = _flatten(tree)
        host = {k: np.asarray(v) for k, v in flat.items()}  # device->host sync
        meta = dict(meta or {})
        # npz can't round-trip ml_dtypes (bf16 -> void); store them as u16
        # raw bits + a dtype sidecar in the manifest.
        dtypes = {}
        for k, v in host.items():
            if v.dtype.name == "bfloat16":
                dtypes[k] = "bfloat16"
                host[k] = v.view(np.uint16)
        meta["_dtypes"] = dtypes
        if asynchronous:
            self.wait()
            self._thread = threading.Thread(
                target=self._write_guard, args=(step, host, meta), daemon=True)
            self._thread.start()
        else:
            self._write(step, host, meta)

    def _write_guard(self, step, host, meta):
        try:
            self._write(step, host, meta)
        except Exception as e:  # surfaced on next wait()
            self._error = e

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    # --------------------------------------------------------------- restore
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "manifest.json")):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None = None, *, shardings=None):
        """Load a checkpoint; optionally place leaves per a shardings tree
        (elastic restore: the saved arrays are unsharded, so any target mesh
        works)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            return None, None
        path = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(path, "manifest.json")) as f:
            meta = json.load(f)
        npz = np.load(os.path.join(path, "arrays.npz"))
        flat = {k: npz[k] for k in npz.files}
        for k, dt in meta.get("_dtypes", {}).items():
            if dt == "bfloat16" and k in flat:
                import ml_dtypes
                flat[k] = flat[k].view(ml_dtypes.bfloat16)
        tree = _unflatten(flat)
        if shardings is not None:
            flat_s = _flatten(shardings)
            placed = {k: (jax.device_put(v, flat_s[k])
                          if not k.endswith(_EMPTY) and k in flat_s else v)
                      for k, v in _flatten(tree).items()}
            tree = _unflatten(placed)
        return tree, meta
