"""Pipeline compiler: operator IR -> fused near-data executable (paper §5.1).

`compile_pipeline(schema, pipeline)` lowers the operator list onto the Pallas
kernels and returns a callable `(rows, n_valid) -> PipelineResult`. Compiled
executables are cached by pipeline signature — the analogue of Farview's
precompiled partial bitstreams: "reconfiguring a dynamic region" is a cache
lookup + dispatch, and like the paper's ms-scale swap it never disturbs other
clients' pipelines.

The executable also returns the response byte count (`shipped_bytes`), i.e.
the paper's network traffic after push-down — benchmarks and the far-KV
roofline both read it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import operators as op_ir
from repro.core.regex import compile_regex
from repro.core.table import FTable, WORD_BYTES
from repro.kernels import ops as kops
from repro.kernels import ref as kref


@dataclass
class PipelineResult:
    kind: str                       # "rows" | "groups" | "mask"
    rows: jnp.ndarray | None = None         # packed surviving rows
    count: jnp.ndarray | int | None = None
    groups: dict | None = None              # group-by / distinct output
    mask: jnp.ndarray | None = None         # regex match mask
    shipped_bytes: int = 0          # paper: bytes sent over the network
    read_bytes: int = 0             # bytes pulled from pool DRAM


_CACHE: dict = {}


def compile_pipeline(schema: FTable, pipeline: tuple,
                     *, interpret: bool | None = None) -> Callable:
    pipeline = op_ir.validate_pipeline(tuple(pipeline))
    key = (schema.name, tuple(c.name for c in schema.columns),
           op_ir.signature(pipeline), interpret)
    if key in _CACHE:
        return _CACHE[key]

    # --- resolve static plan -------------------------------------------------
    sel_ops = np.zeros((schema.n_cols or 1,), np.int32)
    sel_vals = np.zeros((schema.n_cols or 1,), np.float32)
    proj_mask = np.ones((schema.n_cols or 1,), np.float32)
    proj_cols: list[int] | None = None
    smart = False
    regex_tbl = None
    group: op_ir.GroupBy | None = None
    distinct: op_ir.Distinct | None = None
    crypt_pre: op_ir.Crypt | None = None
    crypt_post: op_ir.Crypt | None = None
    join: op_ir.JoinSmall | None = None
    has_select = False

    for op in pipeline:
        if isinstance(op, op_ir.Project):
            proj_cols = [schema.col_index(c) for c in op.cols]
            proj_mask = np.zeros((schema.n_cols,), np.float32)
            proj_mask[proj_cols] = 1.0
        elif isinstance(op, op_ir.SmartAddress):
            proj_cols = [schema.col_index(c) for c in op.cols]
            smart = True
        elif isinstance(op, op_ir.Select):
            has_select = True
            for p in op.predicates:
                i = schema.col_index(p.col)
                sel_ops[i] = op_ir.OPS[p.op]
                sel_vals[i] = p.value
        elif isinstance(op, op_ir.RegexMatch):
            regex_tbl = compile_regex(op.pattern)
        elif isinstance(op, op_ir.JoinSmall):
            join = op
        elif isinstance(op, op_ir.GroupBy):
            group = op
        elif isinstance(op, op_ir.Distinct):
            distinct = op
        elif isinstance(op, op_ir.Crypt):
            if op.when == "pre":
                crypt_pre = op
            else:
                crypt_post = op
        elif isinstance(op, op_ir.Pack):
            pass

    if join is not None and (group is not None or distinct is not None):
        raise ValueError("JoinSmall composes with select/project only")

    def run(rows: jnp.ndarray, lengths: jnp.ndarray | None = None,
            build: tuple | None = None) -> PipelineResult:
        """rows: (N, row_words) f32 for word tables, or (N, W) uint8 strings.
        build: (build_keys (K,), build_vals (K, Vb)) for JoinSmall —
        resolved from the pool by the client (the memory node "reads the
        small table into on-chip memory")."""
        read_bytes = int(np.prod(rows.shape)) * (
            1 if schema.str_width else WORD_BYTES)

        # -- pre-decrypt (data at rest is encrypted; cipher on read stream) --
        if crypt_pre is not None:
            flat = rows.reshape(-1)
            if schema.str_width:
                u32 = flat.astype(jnp.uint32)
            else:
                u32 = jnp.asarray(flat, jnp.float32).view(jnp.uint32)
            dec = kops.crypt(u32, np.array(crypt_pre.key, np.uint32),
                             crypt_pre.nonce, interpret=interpret)
            rows = (dec.view(jnp.float32).reshape(rows.shape)
                    if not schema.str_width
                    else dec.astype(jnp.uint8).reshape(rows.shape))

        # -- regex path (string tables) --------------------------------------
        if regex_tbl is not None:
            table, accept = regex_tbl
            mask = kops.regex_match(rows, lengths, jnp.asarray(table),
                                    jnp.asarray(accept), interpret=interpret)
            shipped = int(mask.shape[0])  # 1 byte/row decision + matched rows
            return PipelineResult(kind="mask", mask=mask,
                                  shipped_bytes=shipped,
                                  read_bytes=read_bytes)

        # -- smart addressing already narrowed columns ------------------------
        work = rows
        if smart and proj_cols is not None:
            # caller passed full rows; emulate column-granular DRAM reads
            work = rows[:, np.asarray(proj_cols)]
            read_bytes = work.shape[0] * len(proj_cols) * WORD_BYTES
            eff_sel_ops = sel_ops[np.asarray(proj_cols)]
            eff_sel_vals = sel_vals[np.asarray(proj_cols)]
            eff_proj = np.ones((len(proj_cols),), np.float32)
        else:
            eff_sel_ops, eff_sel_vals, eff_proj = sel_ops, sel_vals, proj_mask

        # -- small-table join (paper future work): append matched build
        # values + a hit column, expressed as extra predicate/projection
        # columns so the fused select_project kernel does the packing ------
        if join is not None:
            if build is None:
                raise ValueError("JoinSmall needs build=(keys, vals)")
            bkeys, bvals = build
            pkeys = jnp.rint(work[:, schema.col_index(join.probe_key)]
                             ).astype(jnp.int32)
            joined, hit = kops.hash_join(pkeys, jnp.asarray(bkeys),
                                         jnp.asarray(bvals),
                                         interpret=interpret)
            nb = joined.shape[1]
            work = jnp.concatenate(
                [work, joined, hit[:, None].astype(jnp.float32)], axis=1)
            eff_sel_ops = np.concatenate(
                [eff_sel_ops, np.zeros(nb, np.int32),
                 np.asarray([op_ir.OPS["=="]], np.int32)])
            eff_sel_vals = np.concatenate(
                [eff_sel_vals, np.zeros(nb, np.float32),
                 np.asarray([1.0], np.float32)])
            eff_proj = np.concatenate(
                [eff_proj, np.ones(nb, np.float32),
                 np.zeros(1, np.float32)])      # keep build cols, drop hit
            has_join = True
        else:
            has_join = False

        # -- selection + projection + packing (fused kernel) ------------------
        if has_select or has_join or proj_cols is not None or (
                group is None and distinct is None):
            packed, count = kops.select_project(
                work, jnp.asarray(eff_sel_ops), jnp.asarray(eff_sel_vals),
                jnp.asarray(eff_proj), interpret=interpret)
        else:
            packed, count = work, work.shape[0]

        # -- grouping ----------------------------------------------------------
        if group is not None or distinct is not None:
            if group is not None:
                kcol = schema.col_index(group.key)
                vcols = [schema.col_index(c) for c in group.values]
                nb = group.n_buckets
            else:
                kcol = schema.col_index(distinct.cols[0])
                vcols = [kcol]
                nb = distinct.n_buckets
            keys = jnp.rint(work[:, kcol]).astype(jnp.int32)
            vals = work[:, np.asarray(vcols)]
            if has_select:
                # grouping consumes only selected rows: mask via +sentinel key
                m = kref.eval_predicate(work, jnp.asarray(eff_sel_ops),
                                        jnp.asarray(eff_sel_vals))
                keys = jnp.where(m, keys, kref.KEY_SENTINEL + 1)
                vals = jnp.where(m[:, None], vals, 0)
            res = kops.group_aggregate(keys, vals, n_buckets=nb,
                                       interpret=interpret)
            res["drop_key"] = kref.KEY_SENTINEL + 1 if has_select else None
            # the paper's collision buffer: overflow rows ship to the client
            # for software post-aggregation
            ovf = np.asarray(res.pop("overflow_mask"))
            ovf_keys = np.asarray(keys)[ovf]
            keep = ovf_keys != kref.KEY_SENTINEL + 1
            res["ovf_keys"] = ovf_keys[keep]
            res["ovf_vals"] = np.asarray(vals)[ovf][keep]
            ship = (nb * (2 + 4 * len(vcols)) * WORD_BYTES
                    + int(keep.sum()) * (1 + len(vcols)) * WORD_BYTES)
            return PipelineResult(kind="groups", groups=res,
                                  shipped_bytes=ship, read_bytes=read_bytes)

        # -- post-encrypt + pack ----------------------------------------------
        if crypt_post is not None:
            u32 = packed.reshape(-1).view(jnp.uint32)
            enc = kops.crypt(u32, np.array(crypt_post.key, np.uint32),
                             crypt_post.nonce, interpret=interpret)
            packed = enc.view(jnp.float32).reshape(packed.shape)

        ncols_out = (len(proj_cols) if (proj_cols is not None and smart)
                     else int(np.sum(eff_proj)))
        try:
            shipped = int(count) * ncols_out * WORD_BYTES
        except (jax.errors.TracerArrayConversionError, TypeError):
            shipped = None      # traced under jit; caller accounts post-hoc
        return PipelineResult(kind="rows", rows=packed, count=count,
                              shipped_bytes=shipped, read_bytes=read_bytes)

    _CACHE[key] = run
    return run


def cache_info() -> int:
    return len(_CACHE)


def clear_cache() -> None:
    _CACHE.clear()
