"""Pipeline compiler: operator IR -> ONE fused jitted executable (paper §5.1).

`compile_pipeline(schema, pipeline)` returns a `CompiledPipeline` whose
whole request path — pool-page gather, pre-decrypt, join probe, fused
select/project/pack, group-aggregate, post-encrypt, and response byte
accounting — is a single traced program per (layout, signature): the
analogue of Farview's one RDMA verb triggering the full bump-in-the-wire
pipeline with no CPU round-trips mid-stream.

Entry points:

  pipe(rows[, lengths][, build])                  rows already materialized
  pipe.run_pages(buf, pages, n_valid[, build])    fused gather: the
      executable consumes pool pages directly (FarPool.gather_rows read
      path); `n_valid` is a *traced* scalar masking the tail.
  pipe.run_pages_batched(buf, pages, n_valid[, build])   stacked
      multi-client dispatch: pages (B, P), n_valid (B,) — one vmapped
      executable per scheduling round, results split per client. Page
      lists may be bucket-padded with the pool null page (n_valid masks
      each request's tail); a shared join build table is broadcast.
  pipe.run_strings_batched(strings, lengths, n_valid)    stacked string /
      regex dispatch over a (B, n, w) byte tensor with per-request
      lengths — the DFA/crypt body vmapped over the round's clients.

Every entry point takes an optional `row_ids` operand (traced, one
original-table row index per local row) for cluster partition dispatch:
a pre-Crypt addresses its CTR keystream by those ORIGINAL offsets (a node
holding a row subset of one encrypted table decrypts exactly), and
rows-kind results thread the ids through the packing, returning survivors'
ids as `PipelineResult.sel_ids` — what the client-side scatter-gather
merge sorts on to restore single-node row order byte-identically.

All entry points return a lazy `PipelineResult`: device arrays plus traced
count/byte scalars. `PipelineResult.finalize()` is the ONLY sync point —
it materializes Python-int counts, extracts group-overflow rows, and fires
accounting callbacks. Benchmarks call it inside the timed closure; the
dispatch itself never blocks.

Operator lowering is backend-aware: on TPU the Pallas kernels run inside
the trace (their pad/layout glue becomes part of the traced program); off
TPU — where Pallas would run in interpret mode, emulating the MXU datapath
at ~50x cost — the same operators lower to the XLA-native `*_xla`/ref
implementations, which tests assert byte-identical.

Compiled executables are cached by (schema layout, pipeline signature) —
the analogue of Farview's precompiled partial bitstreams: "reconfiguring a
dynamic region" is a cache lookup + dispatch, and like the paper's
ms-scale swap it never disturbs other clients' pipelines. A repeated
signature at the same shape performs zero retraces (`CompiledPipeline
.traces` counts them; tests/test_fused_path.py regression-checks it).
"""
from __future__ import annotations

import threading
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import operators as op_ir
from repro.core import pool as fpool
from repro.core.regex import compile_regex
from repro.core.table import FTable, WORD_BYTES
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.kernels import tier as ktier

_DROP_KEY = int(kref.KEY_SENTINEL) + 1   # masked-row group key (never in data)


class PipelineResult:
    """Lazy response handle: device arrays + traced count/byte scalars.

    `finalize()` is the only synchronization point — it converts traced
    scalars to Python ints, extracts the group-overflow collision buffer
    (the paper's client-side software merge input), and fires accounting
    callbacks (QPair / pool byte counters). Scalar properties
    (`count`, `groups`, `shipped_bytes`) finalize on first access so
    existing callers keep working; `rows` / `mask` hand back the raw device
    arrays without forcing a sync.
    """

    def __init__(self, kind: str, *, rows=None, count=None, groups=None,
                 mask=None, shipped_bytes=0, read_bytes=0, sel_ids=None,
                 _raw: dict | None = None, _meta: dict | None = None):
        self.kind = kind                # "rows" | "groups" | "mask"
        self.read_bytes = read_bytes    # static: bytes pulled from pool DRAM
        self._rows = rows
        self._count = count
        self._groups = groups
        self._mask = mask
        self._shipped = shipped_bytes
        self._ids = sel_ids             # survivors' original row ids, or None
        self._raw = _raw                # unfinalized executable payload
        self._meta = _meta or {}
        self._callbacks: list[Callable] = []

    # ------------------------------------------------------- raw device views
    @property
    def rows(self):
        if self._raw is not None and "rows" in self._raw:
            return self._raw["rows"]
        return self._rows

    @property
    def mask(self):
        if self._raw is not None and "mask" in self._raw:
            return self._raw["mask"]
        return self._mask

    # ----------------------------------------------------- sync-on-first-read
    @property
    def count(self):
        self.finalize()
        return self._count

    @property
    def groups(self):
        self.finalize()
        return self._groups

    @property
    def shipped_bytes(self):
        self.finalize()
        return self._shipped

    @property
    def sel_ids(self):
        """Survivors' original row ids (np.int64, len == count) when the
        request was dispatched with explicit `row_ids` (cluster partitions);
        None otherwise. The client-side scatter-gather merge sorts on these
        to restore the single-node row order byte-identically."""
        self.finalize()
        return self._ids

    def on_finalize(self, cb: Callable) -> None:
        """Run `cb(self)` once the response is materialized (accounting)."""
        if self._raw is None:
            cb(self)
        else:
            self._callbacks.append(cb)

    def finalize(self) -> "PipelineResult":
        """Materialize the response — the request path's only sync point.

        Converts the traced count/shipped scalars to Python ints, slices
        the survivor-id column (`sel_ids`) and the packed group-overflow
        collision rows out of the raw executable payload, and fires the
        deferred accounting callbacks (QPair / pool byte counters).
        Idempotent and cheap after the first call; everything before it —
        dispatch, stacking, even the cluster's scatter — is free of host
        synchronization. Benchmarks call it inside the timed region so
        they measure completed work, never async dispatch."""
        if self._raw is not None:
            raw, self._raw = self._raw, None
            if self.kind == "rows":
                self._rows = raw["rows"]
                self._count = int(raw["count"])
                self._shipped = int(raw["shipped"])
                if "ids" in raw:
                    self._ids = np.rint(np.asarray(
                        raw["ids"][: self._count])).astype(np.int64)
            elif self.kind == "mask":
                self._mask = raw["mask"]
                self._shipped = int(raw["shipped"])
            else:
                self._finalize_groups(raw)
        if self._callbacks:
            cbs, self._callbacks = self._callbacks, []
            for cb in cbs:
                cb(self)
        return self

    def _finalize_groups(self, raw: dict) -> None:
        # the paper's collision buffer: overflow rows ship to the client
        # for software post-aggregation. The executable already packed them
        # to the front of ovf_keys/ovf_vals (device-side compaction), so
        # only the `ovf_count` collision rows cross to the host — never the
        # partition-sized key/value arrays.
        n_ovf = int(raw["ovf_count"])
        self._groups = dict(
            bucket_keys=raw["bucket_keys"], count=raw["count"],
            sum=raw["sum"], min=raw["min"], max=raw["max"],
            drop_key=self._meta.get("drop_key"),
            ovf_keys=np.asarray(raw["ovf_keys"][:n_ovf]),
            ovf_vals=np.asarray(raw["ovf_vals"][:n_ovf]))
        self._shipped = int(raw["shipped"])


class CompiledPipeline:
    """One fused jit executable per (schema layout, pipeline signature)."""

    def __init__(self, schema: FTable, pipeline: tuple,
                 interpret: bool | None, tiered: bool = False):
        pipeline = op_ir.validate_pipeline(tuple(pipeline))
        self.signature = op_ir.signature(pipeline)
        # tiered executables take the pool's decode descriptors as an extra
        # operand and fuse the cold-page decompress into the same dispatch
        # (kernels/tier.py); `tiered` is part of the compile-cache key, so
        # flat-DRAM pipelines keep their exact pre-tiering trace.
        self.tiered = bool(tiered)
        # interpret=True means "no real Pallas backend": lower the operators
        # to XLA-native implementations instead of emulating the MXU.
        self.interpret = (interpret if interpret is not None
                          else jax.default_backend() != "tpu")
        self.traces = 0          # trace-time counter (cache-regression tests)
        self._cols = tuple(c.name for c in schema.columns)
        self._n_cols = len(self._cols)
        self._str_width = schema.str_width

        # --- resolve static plan (one-time, off the hot path) ---------------
        a = self._n_cols or 1
        self.sel_ops = np.zeros((a,), np.int32)
        self.sel_vals = np.zeros((a,), np.float32)
        self.proj_mask = np.ones((a,), np.float32)
        self.proj_cols: list[int] | None = None
        self.smart = False
        self.regex_tbl = None
        self.group: op_ir.GroupBy | None = None
        self.distinct: op_ir.Distinct | None = None
        self.crypt_pre: op_ir.Crypt | None = None
        self.crypt_post: op_ir.Crypt | None = None
        self.join: op_ir.JoinSmall | None = None
        self.has_select = False

        for op in pipeline:
            if isinstance(op, op_ir.Project):
                self.proj_cols = [self._col(c) for c in op.cols]
                self.proj_mask = np.zeros((self._n_cols,), np.float32)
                self.proj_mask[self.proj_cols] = 1.0
            elif isinstance(op, op_ir.SmartAddress):
                self.proj_cols = [self._col(c) for c in op.cols]
                self.smart = True
            elif isinstance(op, op_ir.Select):
                self.has_select = True
                for p in op.predicates:
                    i = self._col(p.col)
                    self.sel_ops[i] = op_ir.OPS[p.op]
                    self.sel_vals[i] = p.value
            elif isinstance(op, op_ir.RegexMatch):
                self.regex_tbl = compile_regex(op.pattern)
            elif isinstance(op, op_ir.JoinSmall):
                self.join = op
            elif isinstance(op, op_ir.GroupBy):
                self.group = op
            elif isinstance(op, op_ir.Distinct):
                self.distinct = op
            elif isinstance(op, op_ir.Crypt):
                if op.when == "pre":
                    self.crypt_pre = op
                else:
                    self.crypt_post = op
            elif isinstance(op, op_ir.Pack):
                pass

        if self.join is not None and (self.group is not None
                                      or self.distinct is not None):
            raise ValueError("JoinSmall composes with select/project only")

        self.kind = ("mask" if self.regex_tbl is not None else
                     "groups" if (self.group is not None
                                  or self.distinct is not None) else "rows")

        # --- the fused executables (shape-specialized lazily by jit) --------
        # Bound methods on purpose: every attribute the entries read is
        # assigned exactly once, above, and never reassigned after __init__,
        # so the traced capture cannot go stale.
        # farlint: ok jit-closure -- captured attrs are write-once (__init__)
        self._jit_rows = jax.jit(self._rows_entry)
        # farlint: ok jit-closure -- captured attrs are write-once (__init__)
        self._jit_pages = jax.jit(self._pages_entry,
                                  static_argnames=("n_rows", "row_words",
                                                   "page_words"))
        # farlint: ok jit-closure -- captured attrs are write-once (__init__)
        self._jit_strings = jax.jit(self._strings_entry)

    def _col(self, name: str) -> int:
        try:
            return self._cols.index(name)
        except ValueError:
            raise KeyError(f"no column {name!r}") from None

    @property
    def response_width(self) -> int:
        """Column count of the packed rows-kind response buffer: narrowed
        to the projection under smart addressing, otherwise the full table
        width plus (for joins) the appended build columns and the zeroed
        hit column. The single source of truth for response shape — the
        scatter-gather merge uses it to build empty results that match
        what `_body` would have packed."""
        if self.smart and self.proj_cols is not None:
            return len(self.proj_cols)
        width = self._n_cols
        if self.join is not None:
            width += len(self.join.build_cols) + 1
        return width

    # ------------------------------------------------------------ public API
    def __call__(self, rows, lengths=None, build=None,
                 row_ids=None) -> PipelineResult:
        """Compatibility path: rows already materialized (offload engine,
        string tables). Still one fused traced program. `row_ids` (optional,
        (n,) i32) are the rows' indices in the original un-partitioned
        table: they key the positional CTR keystream and ride the packing
        as survivor ids (see _body)."""
        rows = jnp.asarray(rows)
        n = int(rows.shape[0])
        payload = self._jit_rows(
            rows, None if lengths is None else jnp.asarray(lengths),
            self._as_build(build), self._as_ids(row_ids))
        if self._columnar_read():
            read_bytes = n * len(self.proj_cols) * WORD_BYTES
        else:
            read_bytes = int(np.prod(rows.shape)) * (
                1 if self._str_width else WORD_BYTES)
        return self._wrap(payload, read_bytes)

    def run_pages(self, buf, pages, n_valid, build=None, *,
                  n_rows: int, row_words: int,
                  row_ids=None, tier=None, page_words: int | None = None,
                  read_bytes: int | None = None) -> PipelineResult:
        """The fused request verb: ONE dispatch does page gather + pipeline.

        buf: pool buffer (n_pages, page_words); pages: (P,) page ids;
        n_valid: traced row-validity scalar (rows >= n_valid are masked);
        row_ids: optional (n_rows,) original-table row indices (partition
        dispatch — keystream offsets + survivor-id packing). On a tiered
        pipeline, `tier` is the pool's decode-descriptor tuple
        (`FarPool.tier_desc`) and `page_words` the static frame width: the
        cold-page decompress fuses into the SAME dispatch. `read_bytes`
        overrides the logical read accounting with the physical
        (compressed) bytes the tiered gather actually pulls.
        """
        payload = self._jit_pages(
            buf, jnp.asarray(pages, jnp.int32),
            jnp.asarray(n_valid, jnp.int32), self._as_build(build),
            self._as_ids(row_ids), self._as_tier(tier),
            n_rows=n_rows, row_words=row_words, page_words=page_words)
        return self._wrap(payload,
                          self._pages_read_bytes(n_rows, row_words)
                          if read_bytes is None else read_bytes)

    def run_pages_batched(self, buf, pages, n_valid, build=None, *,
                          n_rows: int, row_words: int,
                          row_ids=None, tier=None,
                          page_words: int | None = None,
                          read_bytes: list[int] | None = None
                          ) -> list[PipelineResult]:
        """Stacked multi-client dispatch: pages (B, P), n_valid (B,).

        One vmapped executable serves the whole scheduling round; the
        payload is split back into per-client lazy results. `n_rows` is the
        round's shape bucket — per-request tables may be smaller; their page
        lists are padded (pool null page) and their tails masked by
        `n_valid`. A shared join `build=(keys, vals)` operand is broadcast
        (closed over, not vmapped) across the stack. Read/shipped byte
        accounting is per-request: padded rows are never billed (read bytes
        come from each request's `n_valid`, shipped bytes from traced
        counts that already exclude masked rows), and each request's row /
        mask arrays are sliced back to its own length.
        """
        pages = jnp.asarray(pages, jnp.int32)
        nv = np.asarray(n_valid, np.int64)
        payload = self._jit_pages(
            buf, pages, jnp.asarray(n_valid, jnp.int32),
            self._as_build(build), self._as_ids(row_ids),
            self._as_tier(tier),
            n_rows=n_rows, row_words=row_words, page_words=page_words)
        return [self._wrap(self._split(payload, b, int(nv[b])),
                           self._pages_read_bytes(int(nv[b]), row_words)
                           if read_bytes is None else read_bytes[b])
                for b in range(int(pages.shape[0]))]

    def run_strings_batched(self, strings, lengths, n_valid, *,
                            widths=None, row_ids=None) -> list[PipelineResult]:
        """Stacked string/regex dispatch: strings (B, n, w) uint8 bytes,
        lengths (B, n) int32, n_valid (B,) valid-row counts.

        The DFA/crypt body is vmapped over the stack — one executable per
        scheduling round regardless of how many clients submitted. Rows
        past a request's `n_valid` (bucket padding) are masked out of the
        match mask and excluded from shipped/read accounting; `widths`
        (per-request pre-padding byte widths) keeps read accounting exact
        under width bucketing.
        """
        strings = jnp.asarray(strings, jnp.uint8)
        nv = np.asarray(n_valid, np.int64)
        payload = self._jit_strings(
            strings, jnp.asarray(lengths, jnp.int32),
            jnp.asarray(n_valid, jnp.int32), self._as_ids(row_ids))
        w = int(strings.shape[2])
        ws = (np.full((strings.shape[0],), w, np.int64) if widths is None
              else np.asarray(widths, np.int64))
        return [self._wrap(self._split(payload, b, int(nv[b])),
                           int(nv[b]) * int(ws[b]))
                for b in range(int(strings.shape[0]))]

    @staticmethod
    def _split(payload: dict, b: int, nv: int) -> dict:
        """Request b's slice of a stacked payload. Row-shaped arrays are cut
        back to the request's own length so bucket padding is invisible to
        the client (packed survivors always fit: count <= nv)."""
        out = {}
        for k, v in payload.items():
            v = v[b]
            if k in ("rows", "mask", "ovf_keys", "ovf_vals", "ids"):
                v = v[:nv]      # packed fronts always fit: count <= nv
            out[k] = v
        return out

    # -------------------------------------------------------------- internals
    @staticmethod
    def _as_ids(row_ids):
        return None if row_ids is None else jnp.asarray(row_ids, jnp.int32)

    def _as_tier(self, tier):
        if (tier is None) == self.tiered:
            raise ValueError("tiered pipelines take a tier descriptor "
                             "operand; flat pipelines take none")
        return tier

    @property
    def read_cols(self) -> tuple[int, ...] | None:
        """Column indices a column-granular gather touches, or None when
        the plan reads full rows — what the tiered dispatch passes to
        `FarPool.tier_read_bytes` so physical billing matches the gather."""
        return tuple(self.proj_cols) if self._columnar_read() else None

    @staticmethod
    def _as_build(build):
        if build is None:
            return None
        bkeys = jnp.asarray(build[0], jnp.int32)
        # the uniqueness contract is checked here, eagerly, because inside
        # the traced body the keys are Tracers and the check would be a
        # silent no-op (hash_join_xla picks an arbitrary duplicate)
        if not isinstance(bkeys, jax.core.Tracer):
            # The traced path (Tracer) skips this branch, so the eager
            # sync only happens once at build registration.
            # farlint: ok host-sync -- deliberate eager uniqueness check
            bknp = np.asarray(bkeys)
            if len(np.unique(bknp)) != len(bknp):
                raise ValueError(
                    "build keys must be unique for a small-table join")
        return (bkeys, jnp.asarray(build[1], jnp.float32))

    def _columnar_read(self) -> bool:
        """True when the plan actually gathers column-granular (a
        pre-decrypt forces full-row reads: the CTR keystream is positional
        over the row) — the read accounting must match the gather."""
        return (self.smart and self.proj_cols is not None
                and self.crypt_pre is None and self.regex_tbl is None)

    def _pages_read_bytes(self, n_rows: int, row_words: int) -> int:
        if self._columnar_read():
            # column-granular DRAM reads (paper §5.2, Fig. 7)
            return n_rows * len(self.proj_cols) * WORD_BYTES
        return n_rows * row_words * WORD_BYTES

    def _wrap(self, payload: dict, read_bytes: int) -> PipelineResult:
        # drop_key is always published: select masking AND n_valid tail
        # masking both remap dropped rows to _DROP_KEY, and real keys can
        # never collide with it (ingest enforces |key| < 2^24).
        meta = ({"drop_key": _DROP_KEY} if self.kind == "groups" else None)
        return PipelineResult(self.kind, read_bytes=read_bytes,
                              _raw=payload, _meta=meta)

    def _rows_entry(self, rows, lengths, build, row_ids):
        return self._body(rows, lengths, None, build, row_ids, narrowed=False)

    def _strings_entry(self, strings, lengths, n_valid, row_ids):
        # stacked (B, n, w) byte tensor: vmap the whole DFA/crypt body
        if row_ids is None:
            def one(s, ln, nv):
                return self._body(s, ln, nv, None, None, narrowed=False)
            return jax.vmap(one)(strings, lengths, n_valid)

        def one(s, ln, nv, ids):
            return self._body(s, ln, nv, None, ids, narrowed=False)
        return jax.vmap(one)(strings, lengths, n_valid, row_ids)

    def _pages_entry(self, buf, pages, n_valid, build, row_ids, tier, *,
                     n_rows, row_words, page_words):
        if pages.ndim == 2:                     # stacked multi-client round
            # `build` is closed over, not vmapped: the round shares ONE
            # join build table, broadcast across the stacked probes.
            # `tier` (when present) is a stacked descriptor tuple and maps
            # with the pages — each request decodes its own cold planes
            # inside the same vmapped body.
            if row_ids is None:
                def one(pg, nv, tr):
                    return self._gather_run(buf, pg, nv, build, None,
                                            n_rows, row_words, tr,
                                            page_words)
                if tier is None:
                    return jax.vmap(lambda pg, nv: one(pg, nv, None)
                                    )(pages, n_valid)
                return jax.vmap(one)(pages, n_valid, tier)

            def one(pg, nv, ids, tr):
                return self._gather_run(buf, pg, nv, build, ids,
                                        n_rows, row_words, tr, page_words)
            if tier is None:
                return jax.vmap(lambda pg, nv, ids: one(pg, nv, ids, None)
                                )(pages, n_valid, row_ids)
            return jax.vmap(one)(pages, n_valid, row_ids, tier)
        return self._gather_run(buf, pages, n_valid, build, row_ids,
                                n_rows, row_words, tier, page_words)

    def _gather_run(self, buf, pages, n_valid, build, row_ids,
                    n_rows, row_words, tier=None, page_words=None):
        if self._columnar_read():
            if tier is not None:
                work = ktier.gather_columns_tiered(
                    buf, tier, n_rows, row_words, tuple(self.proj_cols),
                    page_words)
            else:
                work = fpool.gather_columns(buf, pages, n_rows, row_words,
                                            tuple(self.proj_cols))
            return self._body(work, None, n_valid, build, row_ids,
                              narrowed=True)
        if tier is not None:
            rows = ktier.gather_rows_tiered(buf, tier, n_rows, row_words,
                                            page_words)
        else:
            rows = fpool.gather_rows(buf, pages, n_rows, row_words)
        return self._body(rows, None, n_valid, build, row_ids,
                          narrowed=False)

    def _body(self, work, lengths, n_valid, build, row_ids, *,
              narrowed: bool):
        """The whole request pipeline as one traced program."""
        self.traces += 1                         # trace-time side effect only
        xla = self.interpret                     # lowering choice (static)
        n = work.shape[0]
        valid = (None if n_valid is None
                 else jnp.arange(n, dtype=jnp.int32) < n_valid)

        # -- pre-decrypt (data at rest is encrypted; cipher on read stream) --
        if self.crypt_pre is not None:
            key = np.asarray(self.crypt_pre.key, np.uint32)
            nonce = self.crypt_pre.nonce
            flat = work.reshape(-1)
            if self._str_width:
                u32 = flat.astype(jnp.uint32)
            else:
                u32 = jnp.asarray(flat, jnp.float32).view(jnp.uint32)
            if row_ids is not None:
                # partitioned dispatch: this node holds a row *subset* of
                # one encrypted table, so each row's keystream position is
                # its offset in the ORIGINAL row-major flattening, not the
                # local one. Gathered keystream goes through the pure-jnp
                # reference cipher (backend-agnostic; the Pallas kernel
                # assumes a contiguous stream).
                w = work.shape[-1]
                idx = (row_ids.astype(jnp.uint32)[:, None] * jnp.uint32(w)
                       + jnp.arange(w, dtype=jnp.uint32)[None, :]).reshape(-1)
                dec = kref.ctr_crypt(u32, jnp.asarray(key), nonce, idx=idx)
            elif xla:
                dec = kref.ctr_crypt(u32, jnp.asarray(key), nonce)
            else:
                dec = kops.crypt(u32, key, nonce, interpret=False)
            work = (dec.view(jnp.float32).reshape(work.shape)
                    if not self._str_width
                    else dec.astype(jnp.uint8).reshape(work.shape))

        # -- regex path (string tables) --------------------------------------
        if self.regex_tbl is not None:
            table, accept = self.regex_tbl
            if xla:
                mask = kref.dfa_match(work, lengths, jnp.asarray(table),
                                      jnp.asarray(accept))
            else:
                mask = kops.regex_match(work, lengths, jnp.asarray(table),
                                        jnp.asarray(accept), interpret=False)
            if valid is not None:
                mask = mask & valid
                # 1 byte/row decision for *valid* rows only (bucket padding
                # must not inflate the response accounting)
                return {"mask": mask,
                        "shipped": jnp.sum(valid.astype(jnp.int32))}
            # 1 byte/row decision + matched rows
            return {"mask": mask, "shipped": jnp.int32(n)}

        # -- smart addressing narrows columns (unless gathered narrowed) -----
        if self.smart and self.proj_cols is not None:
            if not narrowed:
                work = work[:, np.asarray(self.proj_cols)]
            eff_sel_ops = self.sel_ops[np.asarray(self.proj_cols)]
            eff_sel_vals = self.sel_vals[np.asarray(self.proj_cols)]
            eff_proj = np.ones((len(self.proj_cols),), np.float32)
        else:
            eff_sel_ops = self.sel_ops
            eff_sel_vals = self.sel_vals
            eff_proj = self.proj_mask

        # -- small-table join: matched build values + a hit column,
        # expressed as extra predicate/projection columns so the fused
        # select/project does the packing ------------------------------------
        has_join = self.join is not None
        if has_join:
            if build is None:
                raise ValueError("JoinSmall needs build=(keys, vals)")
            bkeys, bvals = build
            pkeys = jnp.rint(work[:, self._col(self.join.probe_key)]
                             ).astype(jnp.int32)
            if xla:
                joined, hit = kops.hash_join_xla(pkeys, bkeys, bvals)
            else:
                joined, hit = kops.hash_join(pkeys, bkeys, bvals,
                                             interpret=False)
            nb = joined.shape[1]
            work = jnp.concatenate(
                [work, joined, hit[:, None].astype(jnp.float32)], axis=1)
            eff_sel_ops = np.concatenate(
                [eff_sel_ops, np.zeros(nb, np.int32),
                 np.asarray([op_ir.OPS["=="]], np.int32)])
            eff_sel_vals = np.concatenate(
                [eff_sel_vals, np.zeros(nb, np.float32),
                 np.asarray([1.0], np.float32)])
            eff_proj = np.concatenate(
                [eff_proj, np.ones(nb, np.float32),
                 np.zeros(1, np.float32)])      # keep build cols, drop hit

        # -- grouping ---------------------------------------------------------
        if self.group is not None or self.distinct is not None:
            return self._group_body(work, eff_sel_ops, eff_sel_vals, valid,
                                    xla)

        # response width BEFORE any bookkeeping columns are appended
        ncols_out = (len(self.proj_cols)
                     if (self.proj_cols is not None and self.smart)
                     else int(np.sum(eff_proj)))

        # -- survivor-id column: partitioned dispatch threads each row's
        # original-table index through the packing (predicate-skipped,
        # projection-kept), so the client-side gather can splice partials
        # back into single-node row order. Split off before the response
        # encrypt — ids are transport metadata, not response payload. -------
        if row_ids is not None:
            work = jnp.concatenate(
                [work, row_ids.astype(jnp.float32)[:, None]], axis=1)
            eff_sel_ops = np.concatenate(
                [eff_sel_ops, np.zeros(1, np.int32)])
            eff_sel_vals = np.concatenate(
                [eff_sel_vals, np.zeros(1, np.float32)])
            eff_proj = np.concatenate([eff_proj, np.ones(1, np.float32)])

        # -- selection + projection + packing (fused) -------------------------
        if xla:
            packed, count = kops.select_project_xla(
                work, eff_sel_ops, eff_sel_vals, eff_proj, valid)
        else:
            if valid is not None:
                # validity as an extra ==1 predicate column through the kernel
                work_v = jnp.concatenate(
                    [work, valid.astype(jnp.float32)[:, None]], axis=1)
                ops_v = np.concatenate(
                    [eff_sel_ops, np.asarray([op_ir.OPS["=="]], np.int32)])
                vals_v = np.concatenate(
                    [eff_sel_vals, np.asarray([1.0], np.float32)])
                proj_v = np.concatenate([eff_proj, np.zeros(1, np.float32)])
                packed, count = kops.select_project(
                    work_v, jnp.asarray(ops_v), jnp.asarray(vals_v),
                    jnp.asarray(proj_v), interpret=False)
                packed = packed[:, :-1]
            else:
                packed, count = kops.select_project(
                    work, jnp.asarray(eff_sel_ops),
                    jnp.asarray(eff_sel_vals), jnp.asarray(eff_proj),
                    interpret=False)

        ids_packed = None
        if row_ids is not None:
            ids_packed = packed[:, -1]
            packed = packed[:, :-1]

        # -- post-encrypt + pack ----------------------------------------------
        if self.crypt_post is not None:
            key = np.asarray(self.crypt_post.key, np.uint32)
            u32 = packed.reshape(-1).view(jnp.uint32)
            if xla:
                enc = kref.ctr_crypt(u32, jnp.asarray(key),
                                     self.crypt_post.nonce)
            else:
                enc = kops.crypt(u32, key, self.crypt_post.nonce,
                                 interpret=False)
            packed = enc.view(jnp.float32).reshape(packed.shape)

        shipped = count.astype(jnp.int32) * np.int32(ncols_out * WORD_BYTES)
        out = {"rows": packed, "count": count, "shipped": shipped}
        if ids_packed is not None:
            out["ids"] = ids_packed
        return out

    def _group_body(self, work, eff_sel_ops, eff_sel_vals, valid, xla):
        if self.group is not None:
            kcol = self._col(self.group.key)
            vcols = [self._col(c) for c in self.group.values]
            nb = self.group.n_buckets
        else:
            kcol = self._col(self.distinct.cols[0])
            vcols = [kcol]
            nb = self.distinct.n_buckets
        keys = jnp.rint(work[:, kcol]).astype(jnp.int32)
        vals = work[:, np.asarray(vcols)]
        # grouping consumes only selected+valid rows: mask via sentinel key
        m = None
        if self.has_select:
            m = kref.eval_predicate(work, jnp.asarray(eff_sel_ops),
                                    jnp.asarray(eff_sel_vals))
        if valid is not None:
            m = valid if m is None else (m & valid)
        if m is not None:
            keys = jnp.where(m, keys, _DROP_KEY)
            vals = jnp.where(m[:, None], vals, 0)
        if xla:
            res = kref.group_aggregate(keys, vals, nb)
        else:
            res = kops.group_aggregate(keys, vals, n_buckets=nb,
                                       interpret=False)
        ovf = res["overflow_mask"]
        keep = ovf & (keys != _DROP_KEY)
        keep_cnt = jnp.sum(keep.astype(jnp.int32))
        # compact (keys, values) collision partial: overflow rows packed to
        # the front IN the traced program (stable two-way partition via the
        # composite-key sort), so the response ships B buckets + the actual
        # collision rows — the host never touches partition-sized arrays
        order, _ = kref.sort_by_bucket((~keep).astype(jnp.int32), 2)
        shipped = (np.int32(nb * (2 + 4 * len(vcols)) * WORD_BYTES)
                   + keep_cnt * np.int32((1 + len(vcols)) * WORD_BYTES))
        return {"bucket_keys": res["bucket_keys"], "count": res["count"],
                "sum": res["sum"], "min": res["min"], "max": res["max"],
                "ovf_keys": keys[order], "ovf_vals": vals[order],
                "ovf_count": keep_cnt, "shipped": shipped}


_CACHE: dict = {}                # guarded-by: _CACHE_LOCK
_CACHE_LOCK = threading.Lock()   # cluster nodes flush from parallel threads


def compile_pipeline(schema: FTable, pipeline: tuple,
                     *, interpret: bool | None = None,
                     tiered: bool = False) -> CompiledPipeline:
    """Fetch (or build) the fused executable for (schema layout, signature).

    The key deliberately excludes the table *name*: two clients running the
    same pipeline over same-layout tables share one executable, which is
    what lets the node's scheduler coalesce them into a stacked dispatch.
    `interpret` is normalized to its resolved boolean before keying, so
    `interpret=None` (auto) and an explicit matching bool share the entry.
    `tiered=True` keys a SEPARATE executable whose gather takes the pool's
    decode descriptors and inflates cold pages in-dispatch — flat tables
    never pay for the decode arithmetic, and flipping a table's tier flips
    which cached executable serves it (a cache lookup, like any other
    "partial reconfiguration").
    """
    pipeline = op_ir.validate_pipeline(tuple(pipeline))
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    # str_width enters the key only as string-vs-word: the traced program
    # never bakes the width in (shapes are jit-specialized per call), so
    # different-width string tables share one executable — which is what
    # lets the scheduler width-bucket stacked regex rounds.
    key = (tuple((c.name, c.dtype) for c in schema.columns),
           bool(schema.str_width), op_ir.signature(pipeline), interpret,
           bool(tiered))
    # One build per key under concurrent flushes. The whole get-or-build
    # runs under the lock: the old lock-free fast path read the dict while
    # parallel drains were inserting, and a racing reader could see a
    # half-initialized slot. Construction is cheap (jit wrapper creation;
    # tracing happens at first call), so serializing builds costs nothing.
    with _CACHE_LOCK:
        pipe = _CACHE.get(key)
        if pipe is None:
            pipe = _CACHE[key] = CompiledPipeline(schema, pipeline,
                                                  interpret, tiered)
    return pipe


def cache_info() -> int:
    with _CACHE_LOCK:
        return len(_CACHE)


def clear_cache() -> None:
    with _CACHE_LOCK:
        _CACHE.clear()
