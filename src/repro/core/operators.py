"""Logical operator IR for Farview pipelines (paper §3.1, §5).

A pipeline is an ordered list of operator descriptors, validated against the
canonical stage order of Fig. 4:

    [Crypt(decrypt)] -> Project|SmartAddress -> [Select|RegexMatch]
        -> [Distinct|GroupBy] -> [Crypt(encrypt)] -> Pack (implicit)

Descriptors are hashable; their tuple is the pipeline *signature* — the key
of the compiled-executable cache in pipeline.py, which plays the role of the
paper's precompiled partial bitstreams for the dynamic regions.
"""
from __future__ import annotations

from dataclasses import dataclass

# comparison ops (shared codes with kernels/ref.py)
OPS = {"<": 1, "<=": 2, ">": 3, ">=": 4, "==": 5, "!=": 6}


@dataclass(frozen=True)
class Project:
    """Return a subset of columns (paper §5.2 'Projection')."""
    cols: tuple[str, ...]


@dataclass(frozen=True)
class SmartAddress:
    """Column-granular reads from the pool (paper §5.2 'Smart addressing').

    Instead of streaming whole rows and projecting in the pipeline, issue
    per-column reads. Beneficial when row_words >> len(cols) (Fig. 7)."""
    cols: tuple[str, ...]


@dataclass(frozen=True)
class Predicate:
    col: str
    op: str        # one of OPS
    value: float


@dataclass(frozen=True)
class Select:
    """AND of per-column predicates (paper §5.3 'Predicate selection')."""
    predicates: tuple[Predicate, ...]


@dataclass(frozen=True)
class RegexMatch:
    """Filter byte-string rows by a regex (paper §5.3)."""
    pattern: str


@dataclass(frozen=True)
class JoinSmall:
    """Inner join against a SMALL pool-resident build table (the paper's
    stated future work, §Conclusions): the memory node reads the build
    table into on-chip memory once and matches the probe stream against
    it. Build keys must be unique. Matched probe rows survive; the build's
    value columns are appended to the response."""
    probe_key: str
    build_table: str               # name of the build FTable in the pool
    build_key: str
    build_cols: tuple              # value columns appended on match


@dataclass(frozen=True)
class Distinct:
    """DISTINCT over key column(s) (paper §5.4)."""
    cols: tuple[str, ...]
    n_buckets: int = 1024


@dataclass(frozen=True)
class GroupBy:
    """GROUP BY key with aggregates over value columns (paper §5.4)."""
    key: str
    values: tuple[str, ...]
    aggs: tuple[str, ...] = ("count", "sum")   # of count/sum/min/max/avg
    n_buckets: int = 1024


@dataclass(frozen=True)
class Crypt:
    """CTR-mode stream cipher on the data path (paper §5.5)."""
    key: tuple[int, int]
    nonce: int
    when: str = "pre"   # "pre" = decrypt data read from pool; "post" = encrypt response


@dataclass(frozen=True)
class Pack:
    """Length-prefixed response packing (paper §5.5) — implicit, kept for
    signature completeness when explicitly requested."""


STAGE_ORDER = {
    Crypt: 0,          # pre-crypt
    SmartAddress: 1,
    Project: 1,
    Select: 2,
    RegexMatch: 2,
    JoinSmall: 2,      # joins compose with selection, before grouping
    Distinct: 3,
    GroupBy: 3,
    Pack: 5,
}


def validate_pipeline(pipeline: tuple) -> tuple:
    """Check stage ordering; returns the pipeline unchanged."""
    last = -1
    n_reads = 0
    for op in pipeline:
        stage = STAGE_ORDER[type(op)]
        if isinstance(op, Crypt):
            stage = 0 if op.when == "pre" else 4
        if stage < last:
            raise ValueError(
                f"operator {op} out of pipeline order (stage {stage} after "
                f"{last}) — canonical order is decrypt->project->select->"
                f"group->encrypt->pack")
        last = stage
        if isinstance(op, (Project, SmartAddress)):
            n_reads += 1
    if n_reads > 1:
        raise ValueError("at most one Project/SmartAddress per pipeline")
    return pipeline


def signature(pipeline: tuple) -> tuple:
    """Hashable pipeline identity (the 'bitstream id' of a dynamic region)."""
    return tuple(pipeline)


# ------------------------------------------------------- scheduler helpers
def pow2_bucket(n: int) -> int:
    """Smallest power of two >= n (>= 1): the shape bucket a request lands
    in. Bucketing trades <2x padded work for executable reuse — every
    request in a bucket runs at the bucket's shape, so K different-sized
    tables cost ONE trace instead of K."""
    return 1 << max(0, int(n) - 1).bit_length()


def shape_bucket(n: int) -> int:
    """Quarter-octave pad target: smallest m * 2^e >= n with m in 5..8
    (powers of two below 8 for tiny n). Four steps per octave caps the
    padded-work overhead at 1.25x where pow2 rounding pays up to 2x —
    hash partitions land at n/k + eps rows and a pow2 target rounds
    nearly half the dispatch back to waste.

    This is the PAD target only, never the COALESCING key: requests
    still group by `pow2_bucket` (one batch per octave) and the batch
    pads to the quarter-octave rung of its largest member, so a bucket
    costs at most four traced shapes instead of one — a bounded retrace
    price for an unbounded per-dispatch row saving."""
    n = max(1, int(n))
    if n <= 8:
        return pow2_bucket(n)       # the ladder degenerates below m=5
    step = 1 << ((n - 1).bit_length() - 3)      # octave top is 8 * step
    return -(-n // step) * step


def has_crypt_pre(pipeline: tuple) -> bool:
    """True if the pipeline decrypts the read stream. The CTR keystream is
    positional over the row-major flattening, so width padding would shift
    byte positions — string requests with a pre-crypt bucket on exact
    width (row padding appends whole rows and is keystream-safe)."""
    return any(isinstance(o, Crypt) and o.when == "pre" for o in pipeline)


def join_small_of(pipeline: tuple) -> JoinSmall | None:
    """The pipeline's join descriptor, if any. The cluster's scatter needs
    it up front: a partitioned probe may only dispatch when every owning
    node can resolve the named build table locally (replicated copy or
    co-partitioned shard)."""
    for o in pipeline:
        if isinstance(o, JoinSmall):
            return o
    return None


def crypt_post_of(pipeline: tuple) -> Crypt | None:
    """The response-encryption descriptor, if any. The cluster merge needs
    it: per-node responses are each encrypted with a keystream starting at
    position 0, so a byte-identical merged response is rebuilt client-side
    (decrypt partials, splice, re-encrypt at merged positions)."""
    for o in pipeline:
        if isinstance(o, Crypt) and o.when == "post":
            return o
    return None
