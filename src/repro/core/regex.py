"""Regex -> NFA -> DFA compiler (host-side, feeds kernels/dfa_match.py).

Farview integrates an FPGA regex library [42]; the DFA is built offline and
loaded into the operator. We mirror that split: this module compiles a
pattern into an int32 (S, 256) transition table + accept vector, which the
dfa_match kernel executes at "line rate" (cost independent of pattern
complexity — exactly the paper's claim, which holds here too since the DFA
table shape is what enters the kernel, not the pattern).

Supported syntax: literals, '.', escapes, character classes [a-z0-9^...],
grouping (), alternation |, quantifiers * + ?.
Semantics: `search` (unanchored, like SQL LIKE '%..%' / RE2 partial match):
the DFA is built for the pattern with a `.*` self-loop on the start state
and *absorbing* accept states, so "ever matched" == "final state accepting".
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

ALPHA = 256
EPS = -1


@dataclass
class _NfaState:
    edges: list = field(default_factory=list)  # (char_set frozenset | None=eps, target)


class _Parser:
    """Recursive-descent regex parser producing an NFA fragment."""

    def __init__(self, pattern: str):
        self.p = pattern
        self.i = 0
        self.states: list[_NfaState] = []

    def _new(self) -> int:
        self.states.append(_NfaState())
        return len(self.states) - 1

    def _edge(self, a: int, b: int, chars):
        self.states[a].edges.append((chars, b))

    def peek(self):
        return self.p[self.i] if self.i < len(self.p) else None

    def eat(self):
        c = self.p[self.i]
        self.i += 1
        return c

    # fragment = (start, end)
    def parse(self):
        frag = self.alternation()
        if self.i != len(self.p):
            raise ValueError(f"trailing chars in regex at {self.i}: {self.p}")
        return frag

    def alternation(self):
        frags = [self.concat()]
        while self.peek() == "|":
            self.eat()
            frags.append(self.concat())
        if len(frags) == 1:
            return frags[0]
        s, e = self._new(), self._new()
        for fs, fe in frags:
            self._edge(s, fs, None)
            self._edge(fe, e, None)
        return s, e

    def concat(self):
        frags = []
        while self.peek() is not None and self.peek() not in "|)":
            frags.append(self.quantified())
        if not frags:
            s = self._new()
            return s, s
        s, e = frags[0]
        for fs, fe in frags[1:]:
            self._edge(e, fs, None)
            e = fe
        return s, e

    def quantified(self):
        frag = self.atom()
        while self.peek() in ("*", "+", "?"):
            q = self.eat()
            fs, fe = frag
            s, e = self._new(), self._new()
            self._edge(s, fs, None)
            self._edge(fe, e, None)
            if q in ("*", "?"):
                self._edge(s, e, None)
            if q in ("*", "+"):
                self._edge(fe, fs, None)
            frag = (s, e)
        return frag

    def atom(self):
        c = self.peek()
        if c == "(":
            self.eat()
            frag = self.alternation()
            if self.peek() != ")":
                raise ValueError("unbalanced paren")
            self.eat()
            return frag
        if c == "[":
            return self.char_class()
        if c == ".":
            self.eat()
            return self.char_frag(frozenset(range(ALPHA)))
        if c == "\\":
            self.eat()
            lit = self.eat()
            mapped = {"n": "\n", "t": "\t", "r": "\r",
                      "d": None, "w": None, "s": None}
            if lit == "d":
                return self.char_frag(frozenset(ord(x) for x in "0123456789"))
            if lit == "w":
                cs = set(range(ord("a"), ord("z") + 1))
                cs |= set(range(ord("A"), ord("Z") + 1))
                cs |= set(range(ord("0"), ord("9") + 1)) | {ord("_")}
                return self.char_frag(frozenset(cs))
            if lit == "s":
                return self.char_frag(frozenset(ord(x) for x in " \t\n\r\f\v"))
            ch = mapped.get(lit)
            return self.char_frag(frozenset({ord(ch if ch else lit)}))
        if c is None:
            raise ValueError("unexpected end of regex")
        self.eat()
        return self.char_frag(frozenset({ord(c)}))

    def char_frag(self, chars):
        s, e = self._new(), self._new()
        self._edge(s, e, chars)
        return s, e

    def char_class(self):
        self.eat()  # '['
        negate = False
        if self.peek() == "^":
            negate = True
            self.eat()
        chars: set[int] = set()
        while self.peek() != "]":
            c = self.eat()
            if c is None:
                raise ValueError("unterminated char class")
            if c == "\\":
                c = self.eat()
            if self.peek() == "-" and self.p[self.i + 1:self.i + 2] != "]":
                self.eat()
                hi = self.eat()
                chars.update(range(ord(c), ord(hi) + 1))
            else:
                chars.add(ord(c))
        self.eat()  # ']'
        if negate:
            chars = set(range(ALPHA)) - chars
        return self.char_frag(frozenset(chars))


def compile_regex(pattern: str, *, search: bool = True,
                  max_states: int = 64):
    """Compile pattern -> (table (S,256) int32, accept (S,) bool).

    search=True gives unanchored (substring) semantics with absorbing accept
    states; search=False anchors at ^...$ (full match).
    """
    parser = _Parser(pattern)
    start, end = parser.parse()
    nfa = parser.states

    # epsilon closures
    def eclose(states: frozenset[int]) -> frozenset[int]:
        stack, seen = list(states), set(states)
        while stack:
            s = stack.pop()
            for chars, t in nfa[s].edges:
                if chars is None and t not in seen:
                    seen.add(t)
                    stack.append(t)
        return frozenset(seen)

    start_set = eclose(frozenset({start}))
    dfa_index: dict[frozenset, int] = {start_set: 0}
    rows: list[np.ndarray] = []
    accepts: list[bool] = []
    work = [start_set]

    while work:
        cur = work.pop(0)
        idx = dfa_index[cur]
        is_acc = end in cur
        while len(rows) <= idx:
            rows.append(np.zeros((ALPHA,), np.int32))
            accepts.append(False)
        accepts[idx] = is_acc
        if search and is_acc:
            # absorbing accept state: all chars self-loop
            rows[idx] = np.full((ALPHA,), idx, np.int32)
            continue
        # group targets by char
        per_char: list[set[int]] = [set() for _ in range(ALPHA)]
        for s in cur:
            for chars, t in nfa[s].edges:
                if chars is None:
                    continue
                for ch in chars:
                    per_char[ch].add(t)
        if search:
            # '.*' prefix: start states always reachable
            base = start_set
        else:
            base = frozenset()
        row = np.zeros((ALPHA,), np.int32)
        cache: dict[frozenset, int] = {}
        for ch in range(ALPHA):
            tgt = frozenset(per_char[ch])
            key = tgt
            if key in cache:
                row[ch] = cache[key]
                continue
            nxt = eclose(tgt) | base if search else eclose(tgt)
            if search:
                nxt = eclose(frozenset(nxt))
            if not nxt:
                nxt = base if search else frozenset()
            if not nxt:
                # dead state: map to a dedicated dead state (reuse state 0 if
                # anchored-dead semantics needed). Create explicit dead state.
                nxt = frozenset({-2})
            if nxt not in dfa_index:
                if len(dfa_index) >= max_states:
                    raise ValueError(
                        f"DFA exceeds max_states={max_states} for {pattern!r}")
                dfa_index[nxt] = len(dfa_index)
                if nxt != frozenset({-2}):
                    work.append(nxt)
            row[ch] = dfa_index[nxt]
            cache[key] = row[ch]
        rows[idx] = row

    n = len(dfa_index)
    table = np.zeros((n, ALPHA), np.int32)
    accept = np.zeros((n,), bool)
    for st, idx in dfa_index.items():
        if idx < len(rows):
            table[idx] = rows[idx]
            accept[idx] = accepts[idx] if idx < len(accepts) else False
        if st == frozenset({-2}):
            table[idx] = idx  # dead state self-loops
            accept[idx] = False
    return table, accept
