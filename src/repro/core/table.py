"""FTable: fixed-width row-format table schema (paper §4.2, §6.1).

The paper's evaluation tables are 8 attributes x 8 bytes, row format. We keep
the row format and the attribute count but use 4-byte words as the attribute
cell (f32 / int32), matching the f32 MXU datapath of the kernels; the
8-byte-attribute layout maps onto two words (documented adaptation,
DESIGN.md §6.5). Integer columns must stay within +-2^24 to survive the f32
packing matmul exactly; the DB layer enforces this at ingest.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

WORD_BYTES = 4
INT_EXACT_LIMIT = 1 << 24


@dataclass(frozen=True)
class Column:
    name: str
    dtype: str = "f32"  # "f32" | "i32" | "str" (string tables: bytes rows)


@dataclass
class FTable:
    """Schema + placement handle for a table living in a FarPool."""
    name: str
    columns: tuple[Column, ...]
    n_rows: int = 0
    # string tables: fixed width per row, stored 1 byte per cell
    str_width: int = 0
    # placement (filled by FarPool.alloc_table)
    table_id: int = -1
    pages: tuple[int, ...] = field(default_factory=tuple)

    @property
    def n_cols(self) -> int:
        return len(self.columns)

    @property
    def row_words(self) -> int:
        if self.str_width:
            return (self.str_width + WORD_BYTES - 1) // WORD_BYTES
        return self.n_cols

    @property
    def n_words(self) -> int:
        return self.n_rows * self.row_words

    @property
    def n_bytes(self) -> int:
        return self.n_words * WORD_BYTES

    def col_index(self, name: str) -> int:
        for i, c in enumerate(self.columns):
            if c.name == name:
                return i
        raise KeyError(f"no column {name!r} in table {self.name!r}")

    def encode(self, arrays: dict[str, np.ndarray]) -> np.ndarray:
        """dict of column arrays -> (n_rows, n_cols) f32 word matrix."""
        cols = []
        for c in self.columns:
            a = np.asarray(arrays[c.name])
            if c.dtype == "i32":
                if np.any(np.abs(a) >= INT_EXACT_LIMIT):
                    raise ValueError(
                        f"int column {c.name} exceeds f32-exact range 2^24")
                cols.append(a.astype(np.float32))
            else:
                cols.append(a.astype(np.float32))
        mat = np.stack(cols, axis=1)
        if self.n_rows and mat.shape[0] != self.n_rows:
            raise ValueError("row count mismatch")
        return mat

    def decode(self, mat: np.ndarray) -> dict[str, np.ndarray]:
        out = {}
        for i, c in enumerate(self.columns):
            col = np.asarray(mat[:, i])
            out[c.name] = (np.rint(col).astype(np.int32)
                           if c.dtype == "i32" else col)
        return out


def string_table(name: str, strings: list[bytes], width: int) -> tuple:
    """Build an FTable + (n, width) uint8 matrix + lengths for byte strings."""
    ft = FTable(name=name, columns=(Column("bytes", "str"),),
                n_rows=len(strings), str_width=width)
    mat = np.zeros((len(strings), width), np.uint8)
    lens = np.zeros((len(strings),), np.int32)
    for i, s in enumerate(strings):
        b = s[:width]
        mat[i, :len(b)] = np.frombuffer(b, np.uint8)
        lens[i] = len(b)
    return ft, mat, lens
