"""FarPool: the disaggregated buffer pool (paper §4.4 memory stack).

A paged, device-resident u32/f32 word buffer with:
  * 2 MiB naturally-aligned pages (paper's MMU page size),
  * a host-side page table mapping (table_id, extent) -> pages — the TLB
    analogue (the paper's TLB "holds all mappings"; so does this dict),
  * striped allocation across shards — the paper's multi-channel DRAM
    interleaving, which is what makes vectorized selection (Fig. 8c) and
    smart addressing (Fig. 7) pay off,
  * capacity accounting + quota per client.

The read path is device-resident: `gather_rows` / `gather_columns` are pure
functions of `(buf, pages)` that are safe to call *inside* a jitted
program, so the fused request executable (core/pipeline.py) consumes pages
directly — one compiled program does gather + operators, with no separate
`read_table` dispatch on the hot path.

On a multi-device mesh the page axis is sharded over the pool axis
(`NamedSharding(mesh, P("model"))`), so page p lives on device
p // (n_pages / n_shards); the round-robin-across-chunks allocator below
stripes consecutive table extents across devices, like the paper's MMU
stripes consecutive addresses across DRAM channels.
"""
from __future__ import annotations

import functools
import math
import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.table import FTable, WORD_BYTES
from repro.distributed import compress as pagec
from repro.kernels import tier as ktier

PAGE_BYTES = 2 * 1024 * 1024


# ---------------------------------------------------------------- read path
def gather_rows(buf: jnp.ndarray, pages: jnp.ndarray, n_rows: int,
                row_words: int) -> jnp.ndarray:
    """Device-resident page gather -> (n_rows, row_words) f32.

    Pure in (buf, pages); n_rows/row_words are static shapes. Safe inside a
    traced program — the fused pipeline executable calls this directly so
    the pool read is part of the same compiled dispatch.
    """
    flat = buf[pages].reshape(-1)
    return flat[: n_rows * row_words].reshape(n_rows, row_words)


def gather_columns(buf: jnp.ndarray, pages: jnp.ndarray, n_rows: int,
                   row_words: int, col_idx: tuple[int, ...]) -> jnp.ndarray:
    """Smart addressing (paper §5.2) as a device-resident strided gather:
    only the projected columns' words leave DRAM. Returns (n_rows, k)."""
    flat = buf[pages].reshape(-1)
    base = jnp.arange(n_rows, dtype=jnp.int32) * row_words
    return jnp.stack([flat[base + c] for c in col_idx], axis=1)


@functools.partial(jax.jit, static_argnames=("n_rows", "row_words"))
def _gather_rows_jit(buf, pages, *, n_rows, row_words):
    return gather_rows(buf, pages, n_rows, row_words)


@functools.partial(jax.jit, static_argnames=("n_rows", "row_words", "col_idx"))
def _gather_columns_jit(buf, pages, *, n_rows, row_words, col_idx):
    return gather_columns(buf, pages, n_rows, row_words, col_idx)


@functools.partial(jax.jit,
                   static_argnames=("n_rows", "row_words", "page_words"))
def _gather_rows_tiered_jit(buf, tier, *, n_rows, row_words, page_words):
    return ktier.gather_rows_tiered(buf, tier, n_rows, row_words, page_words)


@functools.partial(jax.jit, static_argnames=("n_rows", "row_words", "col_idx",
                                             "page_words"))
def _gather_columns_tiered_jit(buf, tier, *, n_rows, row_words, col_idx,
                               page_words):
    return ktier.gather_columns_tiered(buf, tier, n_rows, row_words, col_idx,
                                       page_words)


@dataclass
class TableTier:
    """Per-table tiering state: the per-page tier bit plus the decode
    descriptors the fused pipeline consumes (kernels/tier.py layout).

    `phys` tracks where each LOGICAL page lives NOW — its original raw
    page while hot, or the shared cold frame holding its compressed
    stream after demotion (`FTable.pages` keeps the logical view; every
    pool read/write path consults this entry first). Word tables demote
    page-granular through the bit-packed plane codec; string tables
    demote extent-granular through the block codec (`blob_*`) because
    their dispatch path reads the byte sideband, not pool words."""
    C: int                        # codec plane count == row_words
    is_str: bool
    n_words: np.ndarray           # (P,)  logical words per page
    cold: np.ndarray              # (P,)  bool — THE per-page tier bit
    phys: np.ndarray              # (P,)  int32 raw page | cold frame
    mode: np.ndarray              # (P,C) int32 plane modes (RAW rows = hot)
    width: np.ndarray             # (P,C) int32 packed bits per value
    base: np.ndarray              # (P,C) uint32 delta bases
    dictoff: np.ndarray           # (P,C) int32 FRAME-relative dict words
    bitoff: np.ndarray            # (P,C) int32 FRAME-relative plane bits
    counts: np.ndarray            # (P,C) int64 values per plane
    dictlen: np.ndarray           # (P,C) int32 dict words per plane
    span: np.ndarray              # (P,2) int32 (word off, words) in frame
    crc: np.ndarray               # (P,)  uint32 page codec CRC
    frames: dict[int, set[int]] = field(default_factory=dict)
    hits: deque = field(default_factory=deque)   # promotion hysteresis
    blob: tuple[int, ...] = ()    # str extent: frames holding block stream
    blob_len: int = 0             # str extent: encoded byte length

    @classmethod
    def fresh(cls, ft: FTable, page_words: int) -> "TableTier":
        P = len(ft.pages)
        C = ft.row_words
        n_words = np.minimum(
            page_words,
            np.maximum(0, ft.n_words - np.arange(P, dtype=np.int64)
                       * page_words)).astype(np.int64)
        k = np.arange(ft.n_words, dtype=np.int64)
        counts = np.zeros((P, C), np.int64)
        np.add.at(counts, (k // page_words, k % C), 1)
        return cls(C=C, is_str=bool(ft.str_width), n_words=n_words,
                   cold=np.zeros((P,), bool),
                   phys=np.asarray(ft.pages, np.int32),
                   mode=np.full((P, C), pagec.MODE_RAW, np.int32),
                   width=np.ones((P, C), np.int32),
                   base=np.zeros((P, C), np.uint32),
                   dictoff=np.zeros((P, C), np.int32),
                   bitoff=np.zeros((P, C), np.int32),
                   counts=counts,
                   dictlen=np.zeros((P, C), np.int32),
                   span=np.zeros((P, 2), np.int32),
                   crc=np.zeros((P,), np.uint32))


@dataclass
class PoolStats:
    bytes_read: int = 0
    bytes_written: int = 0
    bytes_shipped: int = 0          # over-the-network response bytes
    requests: int = 0

    @classmethod
    def aggregate(cls, stats: "list[PoolStats]") -> "PoolStats":
        """Cluster-wide roll-up of per-node pool counters."""
        out = cls()
        for s in stats:
            out.bytes_read += s.bytes_read
            out.bytes_written += s.bytes_written
            out.bytes_shipped += s.bytes_shipped
            out.requests += s.requests
        return out


class FarPool:
    """Disaggregated memory node: paged word buffer + page table."""

    def __init__(self, capacity_bytes: int, *, page_bytes: int = PAGE_BYTES,
                 n_shards: int = 1, sharding: jax.sharding.Sharding | None = None,
                 promote_after: int = 3, promote_window: float = 60.0):
        if capacity_bytes % page_bytes:
            raise ValueError("capacity must be page-aligned")
        self.page_bytes = page_bytes
        self.page_words = page_bytes // WORD_BYTES
        self.n_pages = capacity_bytes // page_bytes
        if self.n_pages % n_shards:
            raise ValueError("pages must divide shards")
        self.n_shards = n_shards
        self.chunk = self.n_pages // n_shards     # pages per shard
        # pinned all-zeros pages past the allocatable range: the scheduler
        # pads bucketed page lists with `null_page` so different-sized
        # tables can share a stacked executable (tail rows read zeros and
        # are masked by n_valid). Never allocated, never written. n_shards
        # extra pages keep the page axis divisible by the shard count for
        # device_put with a page-axis sharding; note the pad rows sit at
        # the buffer tail, so under a real multi-shard sharding each
        # device boundary shifts by up to n_shards-1 pages relative to
        # the allocator's p // chunk map (no sharded multi-shard caller
        # exists yet; revisit placement before wiring one up).
        self.null_page = self.n_pages
        buf = jnp.zeros((self.n_pages + n_shards, self.page_words),
                        jnp.float32)
        if sharding is not None:
            buf = jax.device_put(buf, sharding)
        self.buf = buf
        # free lists per shard chunk — striping allocates round-robin chunks.
        # deques: alloc pops left, free appends right — O(1) either end
        # (a plain list.pop(0) is O(n) and quadratic over an alloc storm).
        self._free: list[deque[int]] = [
            deque(range(s * self.chunk, (s + 1) * self.chunk))
            for s in range(n_shards)]
        self._next_table_id = 0
        self.page_table: dict[int, tuple[int, ...]] = {}  # the "TLB"
        self.stats = PoolStats()
        # ----- memory tiering (docs/tiering.md) -----------------------------
        # promotion hysteresis: a cold table promotes after `promote_after`
        # accesses inside a `promote_window`-second window, so a single
        # cold scan runs fused-decompressed instead of thrashing the tier
        # bit, while genuinely re-hot tables come back raw.
        self.promote_after = promote_after
        self.promote_window = promote_window
        self._tier: dict[int, TableTier] = {}     # table_id -> tier entry
        self._tier_dev: dict[int, tuple] = {}     # device descriptor cache
        self._logical: dict[int, int] = {}        # table_id -> logical bytes
        self.tier_stats = {"demoted_pages": 0, "promoted_pages": 0,
                           "incompressible_pages": 0}

    # ------------------------------------------------------------------ mgmt
    @property
    def free_pages(self) -> int:
        return sum(len(f) for f in self._free)

    def alloc_table(self, ft: FTable) -> FTable:
        n_pages = max(1, math.ceil(ft.n_bytes / self.page_bytes))
        if n_pages > self.free_pages:
            raise MemoryError(
                f"pool exhausted: need {n_pages} pages, have {self.free_pages}")
        # round-robin striping across shards, skipping exhausted shards
        # (shard-exhaustion fallback: remaining shards keep serving).
        pages: list[int] = []
        s = 0
        while len(pages) < n_pages:
            free = self._free[s % self.n_shards]
            if free:
                pages.append(free.popleft())
            s += 1
        ft.table_id = self._next_table_id
        self._next_table_id += 1
        ft.pages = tuple(pages)
        self.page_table[ft.table_id] = ft.pages
        self._logical[ft.table_id] = ft.n_bytes
        return ft

    def free_table(self, ft: FTable) -> None:
        te = self._tier.pop(ft.table_id, None)
        self._tier_dev.pop(ft.table_id, None)
        self._logical.pop(ft.table_id, None)
        self.page_table.pop(ft.table_id, None)
        if te is None:
            pages = ft.pages
        else:
            # cold pages' original raw frames were freed at demotion: give
            # back the shared cold frames + the still-hot pages' raw frames
            pages = list(te.frames) + list(te.blob) + [
                int(te.phys[p]) for p in range(len(te.cold))
                if not te.cold[p]]
        for p in pages:
            self._free[p // self.chunk].append(p)
        ft.pages = ()
        ft.table_id = -1

    # ------------------------------------------------------------------- I/O
    def write_table(self, ft: FTable, words: np.ndarray) -> None:
        """words: (n_rows, row_words) f32 (or bitcast-compatible)."""
        if ft.table_id in self._tier:
            # writes land on raw pages only: promote first (a written table
            # is hot by definition; the heat ledger will re-demote later)
            self.promote_table(ft)
        flat = jnp.asarray(words, jnp.float32).reshape(-1)
        n_pages = len(ft.pages)
        padded = jnp.zeros((n_pages * self.page_words,), jnp.float32)
        padded = padded.at[:flat.shape[0]].set(flat)
        pages = jnp.asarray(ft.pages, jnp.int32)
        self.buf = self.buf.at[pages].set(
            padded.reshape(n_pages, self.page_words))
        self.stats.bytes_written += int(flat.shape[0]) * WORD_BYTES

    def gather_rows(self, pages, n_rows: int, row_words: int) -> jnp.ndarray:
        """Device-resident read path (no accounting): one jitted gather."""
        return _gather_rows_jit(self.buf, jnp.asarray(pages, jnp.int32),
                                n_rows=n_rows, row_words=row_words)

    def read_table(self, ft: FTable) -> jnp.ndarray:
        """Full-table RDMA read -> (n_rows, row_words) f32.

        A tiered table decodes in the SAME dispatch (word pages) or via
        the host block codec (string extents) — byte-identical to the raw
        read — and bills the PHYSICAL bytes actually pulled from DRAM
        (compressed for cold pages), per the tiering accounting contract."""
        te = self._tier.get(ft.table_id)
        if te is None:
            rows = self.gather_rows(ft.pages, ft.n_rows, ft.row_words)
            self.stats.bytes_read += ft.n_bytes
            return rows
        self.stats.bytes_read += self.tier_read_bytes(ft)
        if te.is_str:
            return jnp.asarray(self._str_extent_words(ft, te).reshape(
                ft.n_rows, ft.row_words).view(np.float32))
        return _gather_rows_tiered_jit(
            self.buf, self.tier_desc(ft), n_rows=ft.n_rows,
            row_words=ft.row_words, page_words=self.page_words)

    def read_rows(self, ft: FTable, row_idx) -> jnp.ndarray:
        """Row-subset read -> (len(row_idx), row_words) f32.

        Gathers only the selected rows' words through the page table
        (page-indirect addressing, same mechanism as `gather_columns`), so
        a partition-migration step that moves K rows off a node reads K
        rows' worth of DRAM — not the whole extent. `row_idx` are LOCAL
        row positions within this table. Bills exactly the subset."""
        if ft.table_id in self._tier:
            # migration copies read row subsets then usually free the
            # source — promote rather than teach the subset path to decode
            self.promote_table(ft)
        row_idx = np.asarray(row_idx, np.int64)
        if row_idx.size == 0:
            return jnp.zeros((0, ft.row_words), jnp.float32)
        pages = np.asarray(ft.pages, np.int64)
        w = (row_idx[:, None] * ft.row_words
             + np.arange(ft.row_words, dtype=np.int64)[None, :])
        vals = self.buf[jnp.asarray(pages[w // self.page_words], jnp.int32),
                        jnp.asarray(w % self.page_words, jnp.int32)]
        self.stats.bytes_read += int(row_idx.size) * ft.row_words * WORD_BYTES
        return vals

    def read_columns(self, ft: FTable, col_idx: list[int]) -> jnp.ndarray:
        """Smart addressing (paper §5.2): per-column strided reads so only
        the projected columns' words leave DRAM. Returns (n_rows, k).

        On a tiered table only the projected columns' PLANES are unpacked
        (cold) or strided (hot); billing follows the physical bytes."""
        te = self._tier.get(ft.table_id)
        if te is not None and not te.is_str:
            out = _gather_columns_tiered_jit(
                self.buf, self.tier_desc(ft), n_rows=ft.n_rows,
                row_words=ft.row_words, col_idx=tuple(col_idx),
                page_words=self.page_words)
            self.stats.bytes_read += self.tier_read_bytes(ft, col_idx)
            return out
        out = _gather_columns_jit(self.buf, jnp.asarray(ft.pages, jnp.int32),
                                  n_rows=ft.n_rows, row_words=ft.row_words,
                                  col_idx=tuple(col_idx))
        self.stats.bytes_read += ft.n_rows * len(col_idx) * WORD_BYTES
        return out

    def local_rows(self, ft: FTable, shard: int) -> jnp.ndarray:
        """Rows whose pages live on `shard` (for near-data offload)."""
        if ft.table_id in self._tier:
            self.promote_table(ft)      # near-data offload wants raw pages
        own = [p for p in ft.pages if p // self.chunk == shard]
        if not own:
            return jnp.zeros((0, ft.row_words), jnp.float32)
        pages = jnp.asarray(own, jnp.int32)
        flat = self.buf[pages].reshape(-1)
        rows = flat.reshape(-1, ft.row_words)
        return rows

    # -------------------------------------------------- tiering (hot / cold)
    def is_tiered(self, ft: FTable) -> bool:
        """True while any of the table's pages are cold (an entry exists).
        A fully re-promoted table drops its entry and is indistinguishable
        from one that was never demoted."""
        return ft.table_id in self._tier

    def tier_bits(self, ft: FTable) -> np.ndarray:
        """The per-page tier bit: (P,) bool, True = cold (compressed)."""
        te = self._tier.get(ft.table_id)
        if te is None:
            return np.zeros((len(ft.pages),), bool)
        return te.cold.copy()

    def _alloc_frame(self) -> int:
        for free in self._free:
            if free:
                return free.popleft()
        raise MemoryError("pool exhausted: no free frame for tiering")

    def _page_words_u32(self, page: int, n: int) -> np.ndarray:
        # farlint: ok host-sync -- demote/promote are background paths
        return np.asarray(self.buf[page])[:n].view(np.uint32)

    def _write_frame_words(self, frame: int, off: int,
                           words_u32: np.ndarray) -> None:
        self.buf = self.buf.at[frame, off:off + words_u32.size].set(
            jnp.asarray(words_u32.view(np.float32)))

    def demote_table(self, ft: FTable, page_idx=None) -> int:
        """Compress pages of `ft` in place (cold tier). Returns the number
        of pages demoted; each one's raw frame goes back to the free list
        (net capacity gain = raw pages freed - cold frames allocated).
        Incompressible pages keep their raw frame and a raw tier bit.
        String tables demote extent-granular through the block codec."""
        if ft.table_id < 0:
            raise ValueError(f"table {ft.name!r} is not allocated")
        if ft.str_width:
            return self._demote_str(ft)
        te = self._tier.get(ft.table_id)
        if te is None:
            te = TableTier.fresh(ft, self.page_words)
        targets = (range(len(te.cold)) if page_idx is None else page_idx)
        plans: list[tuple[int, pagec.PagePlan]] = []
        for p in targets:
            if te.cold[p]:
                continue
            words = self._page_words_u32(int(te.phys[p]), int(te.n_words[p]))
            plan = pagec.encode_word_page(
                words, te.C, phase=(p * self.page_words) % te.C,
                page_words=self.page_words)
            if plan is None:
                self.tier_stats["incompressible_pages"] += 1
                continue                    # tier bit stays raw, loudly so
            plans.append((p, plan))

        frame, off = -1, self.page_words    # force a fresh frame first
        demoted = 0
        for p, plan in plans:
            m = plan.stream_words
            if off + m > self.page_words:
                if self.free_pages == 0:
                    break                   # partial demotion: no room left
                frame, off = self._alloc_frame(), 0
                te.frames[frame] = set()
            self._write_frame_words(frame, off, plan.stream)
            te.phys[p] = frame
            te.mode[p] = plan.modes
            te.width[p] = plan.widths
            te.base[p] = plan.base
            te.dictoff[p] = np.where(plan.dictoff >= 0,
                                     plan.dictoff + off, 0)
            te.bitoff[p] = plan.bitoff + off * 32
            te.dictlen[p] = plan.dictlen
            te.span[p] = (off, m)
            te.crc[p] = np.uint32(plan.crc)
            te.cold[p] = True
            te.frames[frame].add(p)
            off += m
            # the page's raw frame is free the moment its stream is placed
            raw = int(ft.pages[p])
            self._free[raw // self.chunk].append(raw)
            demoted += 1
        if te.cold.any():
            self._tier[ft.table_id] = te
            self._tier_dev.pop(ft.table_id, None)
        self.tier_stats["demoted_pages"] += demoted
        return demoted

    def promote_table(self, ft: FTable, page_idx=None) -> int:
        """Decompress cold pages back to raw frames (CRC-verified host
        decode; raises `PageCodecError` on corruption instead of restoring
        wrong bytes). A fully-hot table drops its tier entry and
        `ft.pages`/the page table reflect the new raw placement."""
        te = self._tier.get(ft.table_id)
        if te is None:
            return 0
        if te.is_str:
            return self._promote_str(ft)
        targets = (range(len(te.cold)) if page_idx is None else page_idx)
        promoted = 0
        for p in targets:
            if not te.cold[p]:
                continue
            off, m = int(te.span[p, 0]), int(te.span[p, 1])
            frame = int(te.phys[p])
            stream = self._page_words_u32(frame, off + m)[off:].copy()
            plan = pagec.PagePlan(
                n_words=int(te.n_words[p]),
                phase=(p * self.page_words) % te.C,
                modes=te.mode[p].copy(), widths=te.width[p].copy(),
                base=te.base[p].copy(),
                dictoff=np.where(te.dictlen[p] > 0,
                                 te.dictoff[p] - off, -1).astype(np.int32),
                bitoff=(te.bitoff[p] - off * 32).astype(np.int32),
                dictlen=te.dictlen[p].copy(), stream=stream,
                crc=int(te.crc[p]))
            words = pagec.decode_word_page(plan, te.C)
            raw = self._alloc_frame()
            padded = np.zeros((self.page_words,), np.uint32)
            padded[:words.size] = words
            self._write_frame_words(raw, 0, padded)
            te.frames[frame].discard(p)
            if not te.frames[frame]:        # last resident left: frame free
                del te.frames[frame]
                self._free[frame // self.chunk].append(frame)
            te.phys[p] = raw
            te.cold[p] = False
            te.mode[p] = pagec.MODE_RAW
            te.width[p] = 1
            te.base[p] = 0
            te.dictoff[p] = 0
            te.bitoff[p] = 0
            te.dictlen[p] = 0
            promoted += 1
        ft.pages = tuple(int(x) for x in te.phys)
        self.page_table[ft.table_id] = ft.pages
        if not te.cold.any():
            del self._tier[ft.table_id]     # fully hot: transparent again
        self._tier_dev.pop(ft.table_id, None)
        self.tier_stats["promoted_pages"] += promoted
        return promoted

    def _demote_str(self, ft: FTable) -> int:
        te = self._tier.get(ft.table_id)
        if te is not None:
            return 0                        # already cold (all-or-nothing)
        te = TableTier.fresh(ft, self.page_words)
        raw = b"".join(
            self._page_words_u32(int(p), int(te.n_words[i])).tobytes()
            for i, p in enumerate(ft.pages))
        enc = pagec.encode_blocks(raw)
        enc_words = (len(enc) + WORD_BYTES - 1) // WORD_BYTES
        k = max(1, math.ceil(enc_words / self.page_words))
        if k >= len(ft.pages):
            self.tier_stats["incompressible_pages"] += len(ft.pages)
            return 0                        # no capacity win: stay raw
        frames = [self._alloc_frame() for _ in range(k)]
        padded = np.zeros((k * self.page_words,), np.uint32)
        padded[:enc_words] = np.frombuffer(
            enc.ljust(enc_words * WORD_BYTES, b"\0"), np.uint32)
        for i, f in enumerate(frames):
            self._write_frame_words(
                f, 0, padded[i * self.page_words:(i + 1) * self.page_words])
        for p in ft.pages:
            self._free[int(p) // self.chunk].append(int(p))
        te.cold[:] = True
        te.phys[:] = -1
        te.blob = tuple(frames)
        te.blob_len = len(enc)
        self._tier[ft.table_id] = te
        self.tier_stats["demoted_pages"] += len(ft.pages)
        return len(ft.pages)

    def _promote_str(self, ft: FTable) -> int:
        te = self._tier.pop(ft.table_id)
        self._tier_dev.pop(ft.table_id, None)
        words = self._str_extent_words(ft, te)
        pages = [self._alloc_frame() for _ in range(len(te.cold))]
        for i, p in enumerate(pages):
            chunk = words[i * self.page_words:(i + 1) * self.page_words]
            padded = np.zeros((self.page_words,), np.uint32)
            padded[:chunk.size] = chunk
            self._write_frame_words(p, 0, padded)
        for f in te.blob:
            self._free[f // self.chunk].append(f)
        ft.pages = tuple(pages)
        self.page_table[ft.table_id] = ft.pages
        self.tier_stats["promoted_pages"] += len(pages)
        return len(pages)

    def _str_extent_words(self, ft: FTable, te: TableTier) -> np.ndarray:
        """Decode a cold string extent's block stream -> logical u32 words
        (CRC-verified; typed `PageCodecError` on corruption)."""
        enc = b"".join(self._page_words_u32(f, self.page_words).tobytes()
                       for f in te.blob)[:te.blob_len]
        raw = pagec.decode_blocks(enc)
        out = np.zeros((ft.n_words,), np.uint32)
        got = np.frombuffer(raw, np.uint32)
        out[:got.size] = got
        return out

    def note_access(self, ft: FTable) -> bool:
        """Record a request touching `ft`; promote when the hysteresis
        threshold trips (`promote_after` hits within `promote_window`
        seconds). String extents promote on FIRST access — their dispatch
        path needs raw pages, so staying cold has no fused-decode discount.
        Returns True when the access triggered a promotion."""
        te = self._tier.get(ft.table_id)
        if te is None:
            return False
        if te.is_str:
            self._promote_str(ft)
            return True
        now = time.monotonic()
        te.hits.append(now)
        while te.hits and te.hits[0] < now - self.promote_window:
            te.hits.popleft()
        if len(te.hits) >= self.promote_after:
            self.promote_table(ft)
            return True
        return False

    def tier_desc(self, ft: FTable) -> tuple:
        """The table's decode descriptors as device operands (the tuple
        kernels/tier.py consumes), cached per table until the next
        demote/promote flips them."""
        cached = self._tier_dev.get(ft.table_id)
        if cached is not None:
            return cached
        te = self._tier.get(ft.table_id)
        if te is None or te.is_str:
            raise ValueError(f"table {ft.name!r} has no word-tier entry")
        desc = (jnp.asarray(te.phys, jnp.int32),
                jnp.asarray(te.mode, jnp.int32),
                jnp.asarray(te.width, jnp.int32),
                jnp.asarray(te.base, jnp.uint32),
                jnp.asarray(te.dictoff, jnp.int32),
                jnp.asarray(te.bitoff, jnp.int32))
        self._tier_dev[ft.table_id] = desc
        return desc

    def tier_desc_padded(self, ft: FTable, n_pages: int) -> tuple:
        """Host-side descriptor tuple padded to `n_pages` rows with the
        null descriptor (mode RAW + the pinned null page): what a batched
        scheduling round stacks so different-sized tiered tables share one
        bucket executable — padding pages read zeros, exactly like the
        flat path's null-page padding."""
        te = self._tier.get(ft.table_id)
        if te is None or te.is_str:
            raise ValueError(f"table {ft.name!r} has no word-tier entry")
        out = ktier.null_descriptor(n_pages, te.C, self.null_page)
        P = len(te.cold)
        src = (te.phys, te.mode, te.width, te.base, te.dictoff, te.bitoff)
        for dst, s in zip(out, src):
            dst[:P] = s
        return out

    def tier_read_bytes(self, ft: FTable, col_idx=None) -> int:
        """PHYSICAL bytes a full read of `ft` (optionally only `col_idx`
        columns) pulls from DRAM: raw pages bill their logical words, cold
        pages their packed plane words + dictionaries — the 'compressed
        bytes on the wire' half of the tiering accounting contract."""
        te = self._tier.get(ft.table_id)
        if te is None:
            if col_idx is None:
                return ft.n_bytes
            return ft.n_rows * len(col_idx) * WORD_BYTES
        if te.is_str:
            blob_words = (te.blob_len + WORD_BYTES - 1) // WORD_BYTES
            return blob_words * WORD_BYTES
        cols = (np.arange(te.C) if col_idx is None
                else np.asarray(col_idx, np.int64))
        total = 0
        for p in range(len(te.cold)):
            if te.cold[p]:
                if col_idx is None:
                    total += int(te.span[p, 1])
                else:
                    bits = te.counts[p, cols] * te.width[p, cols]
                    total += int(np.sum((bits + 31) // 32
                                        + te.dictlen[p, cols]))
            else:
                total += int(np.sum(te.counts[p, cols]))
        return total * WORD_BYTES

    def tier_summary(self) -> dict:
        """Capacity accounting for the hierarchy: resident logical bytes
        vs the physical frames holding them, plus the effective-capacity
        multiplier the benchmark guards (logical bytes the pool serves per
        byte of DRAM it actually occupies)."""
        logical = sum(self._logical.values())
        used_pages = self.n_pages - self.free_pages
        physical = used_pages * self.page_bytes
        cold_pages = sum(int(te.cold.sum()) for te in self._tier.values())
        return dict(self.tier_stats, cold_pages=cold_pages,
                    logical_bytes=logical, physical_bytes=physical,
                    effective_capacity=(logical / physical
                                        if physical else 0.0))
