"""FarPool: the disaggregated buffer pool (paper §4.4 memory stack).

A paged, device-resident u32/f32 word buffer with:
  * 2 MiB naturally-aligned pages (paper's MMU page size),
  * a host-side page table mapping (table_id, extent) -> pages — the TLB
    analogue (the paper's TLB "holds all mappings"; so does this dict),
  * striped allocation across shards — the paper's multi-channel DRAM
    interleaving, which is what makes vectorized selection (Fig. 8c) and
    smart addressing (Fig. 7) pay off,
  * capacity accounting + quota per client.

The read path is device-resident: `gather_rows` / `gather_columns` are pure
functions of `(buf, pages)` that are safe to call *inside* a jitted
program, so the fused request executable (core/pipeline.py) consumes pages
directly — one compiled program does gather + operators, with no separate
`read_table` dispatch on the hot path.

On a multi-device mesh the page axis is sharded over the pool axis
(`NamedSharding(mesh, P("model"))`), so page p lives on device
p // (n_pages / n_shards); the round-robin-across-chunks allocator below
stripes consecutive table extents across devices, like the paper's MMU
stripes consecutive addresses across DRAM channels.
"""
from __future__ import annotations

import functools
import math
from collections import deque
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.table import FTable, WORD_BYTES

PAGE_BYTES = 2 * 1024 * 1024


# ---------------------------------------------------------------- read path
def gather_rows(buf: jnp.ndarray, pages: jnp.ndarray, n_rows: int,
                row_words: int) -> jnp.ndarray:
    """Device-resident page gather -> (n_rows, row_words) f32.

    Pure in (buf, pages); n_rows/row_words are static shapes. Safe inside a
    traced program — the fused pipeline executable calls this directly so
    the pool read is part of the same compiled dispatch.
    """
    flat = buf[pages].reshape(-1)
    return flat[: n_rows * row_words].reshape(n_rows, row_words)


def gather_columns(buf: jnp.ndarray, pages: jnp.ndarray, n_rows: int,
                   row_words: int, col_idx: tuple[int, ...]) -> jnp.ndarray:
    """Smart addressing (paper §5.2) as a device-resident strided gather:
    only the projected columns' words leave DRAM. Returns (n_rows, k)."""
    flat = buf[pages].reshape(-1)
    base = jnp.arange(n_rows, dtype=jnp.int32) * row_words
    return jnp.stack([flat[base + c] for c in col_idx], axis=1)


@functools.partial(jax.jit, static_argnames=("n_rows", "row_words"))
def _gather_rows_jit(buf, pages, *, n_rows, row_words):
    return gather_rows(buf, pages, n_rows, row_words)


@functools.partial(jax.jit, static_argnames=("n_rows", "row_words", "col_idx"))
def _gather_columns_jit(buf, pages, *, n_rows, row_words, col_idx):
    return gather_columns(buf, pages, n_rows, row_words, col_idx)


@dataclass
class PoolStats:
    bytes_read: int = 0
    bytes_written: int = 0
    bytes_shipped: int = 0          # over-the-network response bytes
    requests: int = 0

    @classmethod
    def aggregate(cls, stats: "list[PoolStats]") -> "PoolStats":
        """Cluster-wide roll-up of per-node pool counters."""
        out = cls()
        for s in stats:
            out.bytes_read += s.bytes_read
            out.bytes_written += s.bytes_written
            out.bytes_shipped += s.bytes_shipped
            out.requests += s.requests
        return out


class FarPool:
    """Disaggregated memory node: paged word buffer + page table."""

    def __init__(self, capacity_bytes: int, *, page_bytes: int = PAGE_BYTES,
                 n_shards: int = 1, sharding: jax.sharding.Sharding | None = None):
        if capacity_bytes % page_bytes:
            raise ValueError("capacity must be page-aligned")
        self.page_bytes = page_bytes
        self.page_words = page_bytes // WORD_BYTES
        self.n_pages = capacity_bytes // page_bytes
        if self.n_pages % n_shards:
            raise ValueError("pages must divide shards")
        self.n_shards = n_shards
        self.chunk = self.n_pages // n_shards     # pages per shard
        # pinned all-zeros pages past the allocatable range: the scheduler
        # pads bucketed page lists with `null_page` so different-sized
        # tables can share a stacked executable (tail rows read zeros and
        # are masked by n_valid). Never allocated, never written. n_shards
        # extra pages keep the page axis divisible by the shard count for
        # device_put with a page-axis sharding; note the pad rows sit at
        # the buffer tail, so under a real multi-shard sharding each
        # device boundary shifts by up to n_shards-1 pages relative to
        # the allocator's p // chunk map (no sharded multi-shard caller
        # exists yet; revisit placement before wiring one up).
        self.null_page = self.n_pages
        buf = jnp.zeros((self.n_pages + n_shards, self.page_words),
                        jnp.float32)
        if sharding is not None:
            buf = jax.device_put(buf, sharding)
        self.buf = buf
        # free lists per shard chunk — striping allocates round-robin chunks.
        # deques: alloc pops left, free appends right — O(1) either end
        # (a plain list.pop(0) is O(n) and quadratic over an alloc storm).
        self._free: list[deque[int]] = [
            deque(range(s * self.chunk, (s + 1) * self.chunk))
            for s in range(n_shards)]
        self._next_table_id = 0
        self.page_table: dict[int, tuple[int, ...]] = {}  # the "TLB"
        self.stats = PoolStats()

    # ------------------------------------------------------------------ mgmt
    @property
    def free_pages(self) -> int:
        return sum(len(f) for f in self._free)

    def alloc_table(self, ft: FTable) -> FTable:
        n_pages = max(1, math.ceil(ft.n_bytes / self.page_bytes))
        if n_pages > self.free_pages:
            raise MemoryError(
                f"pool exhausted: need {n_pages} pages, have {self.free_pages}")
        # round-robin striping across shards, skipping exhausted shards
        # (shard-exhaustion fallback: remaining shards keep serving).
        pages: list[int] = []
        s = 0
        while len(pages) < n_pages:
            free = self._free[s % self.n_shards]
            if free:
                pages.append(free.popleft())
            s += 1
        ft.table_id = self._next_table_id
        self._next_table_id += 1
        ft.pages = tuple(pages)
        self.page_table[ft.table_id] = ft.pages
        return ft

    def free_table(self, ft: FTable) -> None:
        for p in self.page_table.pop(ft.table_id, ()):
            self._free[p // self.chunk].append(p)
        ft.pages = ()
        ft.table_id = -1

    # ------------------------------------------------------------------- I/O
    def write_table(self, ft: FTable, words: np.ndarray) -> None:
        """words: (n_rows, row_words) f32 (or bitcast-compatible)."""
        flat = jnp.asarray(words, jnp.float32).reshape(-1)
        n_pages = len(ft.pages)
        padded = jnp.zeros((n_pages * self.page_words,), jnp.float32)
        padded = padded.at[:flat.shape[0]].set(flat)
        pages = jnp.asarray(ft.pages, jnp.int32)
        self.buf = self.buf.at[pages].set(
            padded.reshape(n_pages, self.page_words))
        self.stats.bytes_written += int(flat.shape[0]) * WORD_BYTES

    def gather_rows(self, pages, n_rows: int, row_words: int) -> jnp.ndarray:
        """Device-resident read path (no accounting): one jitted gather."""
        return _gather_rows_jit(self.buf, jnp.asarray(pages, jnp.int32),
                                n_rows=n_rows, row_words=row_words)

    def read_table(self, ft: FTable) -> jnp.ndarray:
        """Full-table RDMA read -> (n_rows, row_words) f32."""
        rows = self.gather_rows(ft.pages, ft.n_rows, ft.row_words)
        self.stats.bytes_read += ft.n_bytes
        return rows

    def read_rows(self, ft: FTable, row_idx) -> jnp.ndarray:
        """Row-subset read -> (len(row_idx), row_words) f32.

        Gathers only the selected rows' words through the page table
        (page-indirect addressing, same mechanism as `gather_columns`), so
        a partition-migration step that moves K rows off a node reads K
        rows' worth of DRAM — not the whole extent. `row_idx` are LOCAL
        row positions within this table. Bills exactly the subset."""
        row_idx = np.asarray(row_idx, np.int64)
        if row_idx.size == 0:
            return jnp.zeros((0, ft.row_words), jnp.float32)
        pages = np.asarray(ft.pages, np.int64)
        w = (row_idx[:, None] * ft.row_words
             + np.arange(ft.row_words, dtype=np.int64)[None, :])
        vals = self.buf[jnp.asarray(pages[w // self.page_words], jnp.int32),
                        jnp.asarray(w % self.page_words, jnp.int32)]
        self.stats.bytes_read += int(row_idx.size) * ft.row_words * WORD_BYTES
        return vals

    def read_columns(self, ft: FTable, col_idx: list[int]) -> jnp.ndarray:
        """Smart addressing (paper §5.2): per-column strided reads so only
        the projected columns' words leave DRAM. Returns (n_rows, k)."""
        out = _gather_columns_jit(self.buf, jnp.asarray(ft.pages, jnp.int32),
                                  n_rows=ft.n_rows, row_words=ft.row_words,
                                  col_idx=tuple(col_idx))
        self.stats.bytes_read += ft.n_rows * len(col_idx) * WORD_BYTES
        return out

    def local_rows(self, ft: FTable, shard: int) -> jnp.ndarray:
        """Rows whose pages live on `shard` (for near-data offload)."""
        own = [p for p in ft.pages if p // self.chunk == shard]
        if not own:
            return jnp.zeros((0, ft.row_words), jnp.float32)
        pages = jnp.asarray(own, jnp.int32)
        flat = self.buf[pages].reshape(-1)
        rows = flat.reshape(-1, ft.row_words)
        return rows
