"""FarCluster: a pool sharded across N FViewNodes with scatter-gather verbs.

The paper's premise is one large disaggregated pool serving many small
processing nodes, and its evaluation scales to multiple Farview instances.
This module is that scale-out: a `FarCluster` owns N independent
`FViewNode`s and presents the same verb surface as a single node —

    open_connection(cluster)            -> ClusterQP (one QPair per node)
    alloc_table_mem(cqp, ft)            -> ClusterTable (client-side
                                           partition map; no node traffic)
    table_write / table_read            -> row scatter / ordered gather
    farview_request(cqp, ct, pipeline)  -> merged PipelineResult
    submit_request / flush              -> async scatter-gather

Partitioning is decided client-side at `alloc_table_mem` time
(`distributed.sharding.partition_rows`): contiguous `range` blocks
(default), key-`hash` (co-locates equal keys for joins/group-bys), or the
`skew`-aware greedy balancer that places key-groups largest-first on the
least-loaded node. The map is pure metadata — nodes never talk to each
other, exactly like the paper's one-sided RDMA model.

A Farview verb against a partitioned table scatters: each owning node runs
the SAME fused `CompiledPipeline` over its local partition (select/project,
regex, crypt, join probe, partial group-aggregate) and keeps its own
bucket-batched scheduler — partition requests from many cluster clients
coalesce per node into stacked executables just like solo requests do. The
client then gathers and merges partials (`offload._merge` /
`merge_group_partials`) byte-identically to a single-node dispatch:

  * rows kind: survivors splice in original row order (each partition
    dispatch threads `row_ids` through the packing and gets them back as
    `sel_ids`), then pad to the solo-shaped (n_rows, width) buffer; a
    post-crypt response is decrypted per-node, spliced, and re-encrypted
    at merged keystream positions;
  * mask kind (regex): per-partition decisions scatter back to original
    row positions via the partition map;
  * groups kind: compact per-node partials (bucket tables + packed
    collision rows) merge in ONE device-side segment-reduce dispatch
    (offload.merge_groups_device) — the paper's client software merge,
    generalized from overflow buffers to node partials and pushed back
    onto the device.

Pre-crypt works on any partition because the CTR keystream is addressed by
ORIGINAL row offsets (`row_ids`), not local ones — a node holding rows
{3, 17, 40} of an encrypted table decrypts each with the keystream slice it
was encrypted under.

Small join build tables take one of two layouts: `replicate=True` (a copy
in every node's pool — the classic broadcast join, works against any probe
partitioning, costs N× the write traffic and footprint) or
`co_partition=<probe ClusterTable>` (build rows placed by the PROBE's
key rule so each node joins purely against its local shard — ONE copy
cluster-wide). `co_partition=` falls back to replication automatically
when the probe carries no key rule (range/replicated); dispatching a join
whose build is partitioned but NOT co-partitioned with the probe is
refused (it would silently drop matches).

Scatter dispatch is genuinely concurrent: `flush()` drains each node's
scheduler in its own thread (nodes are independent; XLA releases the GIL),
which is what the scale-out benchmark (`bench_cluster_scaleout`) measures.
Per-node read/shipped accounting stays on each node's QPair/pool; the
ClusterQP and `cluster.stats` expose the aggregate.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core import client as fv
from repro.core import operators as op_ir
from repro.core.pipeline import PipelineResult
from repro.core.pool import PoolStats
from repro.core.table import FTable, INT_EXACT_LIMIT
from repro.distributed.sharding import (CoPartition, co_partition_spec,
                                        partition_rows)


@dataclass
class ClusterTable:
    """A logical table + its client-side partition map."""
    schema: FTable                  # the un-partitioned table (schema, n_rows)
    parts: list                     # per-node FTable handle (None = no rows)
    part_rows: list                 # per-node original-row index arrays
    partitioner: str
    replicated: bool = False        # full copy on every node (join builds)
    co_spec: CoPartition | None = None  # key->node rule (key partitioners);
    #                                     what a co-partitioned build reuses

    @property
    def name(self) -> str:
        return self.schema.name

    @property
    def n_rows(self) -> int:
        return self.schema.n_rows


class ClusterQP:
    """One logical connection = one QPair on every node.

    Byte counters are aggregates of the per-node QPairs (reading them
    settles each node — the same lazy-accounting contract as a solo QPair);
    `requests` counts cluster verbs, `qps[i].requests` per-node dispatches.
    """

    def __init__(self, cluster: "FarCluster", qps: list):
        self.cluster = cluster
        self.qps = qps
        self.requests = 0

    @property
    def bytes_shipped(self) -> int:
        return sum(qp.bytes_shipped for qp in self.qps)

    @property
    def bytes_read_pool(self) -> int:
        return sum(qp.bytes_read_pool for qp in self.qps)


class ClusterPending:
    """A scattered Farview verb awaiting its gather."""

    def __init__(self, cluster: "FarCluster", ctable: ClusterTable,
                 pipeline: tuple, pends: list, part_rows: list):
        self.cluster = cluster
        self.ctable = ctable
        self.pipeline = pipeline
        self.pends = pends          # per-node PendingRequests (owners only)
        self.part_rows = part_rows  # aligned original-row indices

    def wait(self) -> PipelineResult:
        """Flush every involved node and merge the partials."""
        flush_err: Exception | None = None
        try:
            self.cluster.flush()
        except Exception as e:      # may belong to another verb's partial
            flush_err = e
        partials = []
        for pend in self.pends:
            if pend.error is not None:
                raise pend.error
            if pend.result is None:             # never dispatched
                raise flush_err or fv.FarviewError(
                    "cluster partial was not dispatched")
            partials.append(pend.result)
        if self.ctable.replicated:
            # served whole from node 0: the partial IS the solo-shaped
            # response — merging would only rebuild (and for a post-crypt,
            # redundantly decrypt + re-encrypt) a byte-identical copy
            return partials[0]
        return fv.merge_group_partials(
            self.ctable.schema, self.pipeline, partials,
            n_rows=self.ctable.n_rows, part_rows=self.part_rows)


class FarCluster:
    """N smart memory nodes + client-side scatter-gather dispatch."""

    def __init__(self, n_nodes: int, capacity_bytes: int = 64 * 2**20, *,
                 n_regions: int = 6, interpret: bool | None = None,
                 partitioner: str = "range", parallel: bool = True):
        if n_nodes < 1:
            raise ValueError("a cluster needs at least one node")
        self.nodes = [fv.FViewNode(capacity_bytes, n_regions=n_regions,
                                   interpret=interpret)
                      for _ in range(n_nodes)]
        self.partitioner = partitioner
        self.parallel = parallel and n_nodes > 1
        self.catalog: dict[str, ClusterTable] = {}  # name -> cluster handle

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    @property
    def dispatches(self) -> int:
        """Total stacked-executable launches across the cluster."""
        return sum(node.dispatches for node in self.nodes)

    @property
    def stats(self) -> PoolStats:
        return PoolStats.aggregate([node.pool.stats for node in self.nodes])

    # ----------------------------------------------------------- connections
    def open_connection(self) -> ClusterQP:
        qps = []
        try:
            for node in self.nodes:
                qps.append(node.open_connection())
        except fv.FarviewError:
            for qp, node in zip(qps, self.nodes):
                node.close_connection(qp)
            raise
        return ClusterQP(self, qps)

    def close_connection(self, cqp: ClusterQP) -> None:
        """Close the per-node QPairs; each node cancels the connection's
        still-queued partition requests (their `wait()` raises)."""
        for node, qp in zip(self.nodes, cqp.qps):
            node.close_connection(qp)

    # ---------------------------------------------------------------- memory
    def alloc_table_mem(self, cqp: ClusterQP, ft: FTable, *,
                        replicate: bool = False,
                        partitioner: str | None = None,
                        keys: np.ndarray | None = None,
                        co_partition: "ClusterTable | None" = None,
                        ) -> ClusterTable:
        """Partition (or replicate) a table across the nodes' pools.

        The partition map is computed HERE, once, client-side: `keys`
        (optional, one value per row) feeds the hash/skew partitioners so
        equal-key rows co-locate. `replicate=True` puts a full copy in
        every pool — for small join build tables (broadcast join).

        `co_partition=probe_ctable` places THIS table's rows (by `keys`,
        the join-key value per row) on whichever node the probe table's
        key partitioning put that key: each node then resolves build-probe
        joins entirely locally and the build is written ONCE cluster-wide
        instead of N times. Falls back to `replicate=True` automatically
        when the referenced table carries no key rule (range-partitioned
        or replicated) — co-location is impossible there, and a silent
        partition would drop join matches."""
        if ft.n_rows >= INT_EXACT_LIMIT:
            # row ids ride the fused packing as an f32 column (the same
            # exactness budget the DB enforces for i32 data at ingest);
            # ids >= 2^24 would round and silently break the merge order
            raise ValueError(
                f"cluster tables are limited to {INT_EXACT_LIMIT - 1} rows "
                "(row ids must stay f32-exact); partition the data into "
                "multiple tables")
        if co_partition is not None:
            if replicate:
                raise ValueError("co_partition and replicate are exclusive")
            spec = co_partition.co_spec
            if spec is None:        # no key rule to share: broadcast join
                return self.alloc_table_mem(cqp, ft, replicate=True)
            part_rows = partition_rows(ft.n_rows, self.n_nodes, keys=keys,
                                       co_partition=spec)
            # empty shards still allocate: every node must resolve the
            # build table by name when it joins its probe partition
            parts = self._alloc_parts(cqp, ft, [len(i) for i in part_rows],
                                      alloc_empty=True)
            return self._register(ClusterTable(
                ft, parts, part_rows, f"co[{spec.kind}]", co_spec=spec))
        if replicate:
            parts = self._alloc_parts(
                cqp, ft, [ft.n_rows] * self.n_nodes)
            all_rows = np.arange(ft.n_rows, dtype=np.int64)
            return self._register(ClusterTable(
                ft, parts, [all_rows] * self.n_nodes,
                "replicate", replicated=True))
        kind = partitioner or self.partitioner
        part_rows = partition_rows(ft.n_rows, self.n_nodes, kind, keys=keys)
        parts = self._alloc_parts(cqp, ft, [len(i) for i in part_rows])
        return self._register(ClusterTable(
            ft, parts, part_rows, kind,
            co_spec=co_partition_spec(kind, self.n_nodes, keys)))

    def _register(self, ctable: ClusterTable) -> ClusterTable:
        self.catalog[ctable.name] = ctable
        return ctable

    def _alloc_parts(self, cqp: ClusterQP, ft: FTable,
                     rows_per_node: list, *,
                     alloc_empty: bool = False) -> list:
        """Allocate one partition per node (None for zero rows, unless
        `alloc_empty` — co-partitioned build shards register even when
        empty so probe-side joins resolve the name), rolling back the
        earlier nodes' allocations if a later pool is exhausted — a
        half-scattered table would leak pages with no handle to free."""
        parts: list = []
        try:
            for qp, n in zip(cqp.qps, rows_per_node):
                if n == 0 and not alloc_empty:
                    parts.append(None)
                    continue
                part = FTable(ft.name, ft.columns, n_rows=n,
                              str_width=ft.str_width)
                fv.alloc_table_mem(qp, part)
                parts.append(part)
        except Exception:
            for qp, part in zip(cqp.qps, parts):
                if part is not None:
                    fv.free_table_mem(qp, part)
            raise
        return parts

    def free_table_mem(self, cqp: ClusterQP, ctable: ClusterTable) -> None:
        for qp, part in zip(cqp.qps, ctable.parts):
            if part is not None:
                fv.free_table_mem(qp, part)
        if self.catalog.get(ctable.name) is ctable:
            del self.catalog[ctable.name]

    def table_write(self, cqp: ClusterQP, ctable: ClusterTable,
                    words: np.ndarray) -> None:
        """Scatter the row matrix to the owning nodes (or all, if
        replicated). Rows land pre-split; nothing is written twice."""
        words = np.asarray(words)
        if ctable.replicated:
            for qp, part in zip(cqp.qps, ctable.parts):
                fv.table_write(qp, part, words)
            return
        for qp, part, idx in zip(cqp.qps, ctable.parts, ctable.part_rows):
            if part is not None:
                fv.table_write(qp, part, words[np.asarray(idx)])

    def table_read(self, cqp: ClusterQP, ctable: ClusterTable) -> jnp.ndarray:
        """Plain gather-read: fetch every partition, restore original row
        order via the partition map (ships the whole table — no push-down)."""
        if ctable.replicated:
            return fv.table_read(cqp.qps[0], ctable.parts[0])
        out = np.zeros((ctable.n_rows, ctable.schema.row_words), np.float32)
        for qp, part, idx in zip(cqp.qps, ctable.parts, ctable.part_rows):
            if part is not None:
                out[np.asarray(idx)] = np.asarray(fv.table_read(qp, part))
        return jnp.asarray(out)

    # -------------------------------------------------------------- dispatch
    def submit_request(self, cqp: ClusterQP, ctable: ClusterTable,
                       pipeline: tuple, *,
                       lengths: np.ndarray | None = None,
                       strings: np.ndarray | None = None) -> ClusterPending:
        """Scatter one Farview verb: queue a partition request on every
        owning node. Each node's bucket-batched scheduler coalesces the
        partition with whatever else is queued there — K cluster clients
        running the same pipeline still cost each node ONE stacked
        dispatch per round."""
        pipeline = op_ir.validate_pipeline(tuple(pipeline))
        strings = None if strings is None else np.asarray(strings)
        lengths = None if lengths is None else np.asarray(lengths)
        self._check_join_locality(ctable, pipeline)
        if ctable.replicated:
            # a replicated table has no partitions to scatter over: serve
            # from node 0 exactly like a solo dispatch
            pend = self.nodes[0].submit(
                cqp.qps[0], ctable.parts[0], pipeline,
                lengths=lengths, strings=strings)
            cqp.requests += 1
            return ClusterPending(self, ctable, pipeline, [pend],
                                  [ctable.part_rows[0]])
        pends, prows = [], []
        for node, qp, part, idx in zip(self.nodes, cqp.qps, ctable.parts,
                                       ctable.part_rows):
            if part is None or part.n_rows == 0:
                continue
            idx = np.asarray(idx)
            kwargs = {}
            if strings is not None:
                kwargs["strings"] = strings[idx]
                kwargs["lengths"] = lengths[idx]
            pends.append(node.submit(qp, part, pipeline,
                                     row_ids=idx.astype(np.int32), **kwargs))
            prows.append(idx)
        cqp.requests += 1
        return ClusterPending(self, ctable, pipeline, pends, prows)

    def _check_join_locality(self, ctable: ClusterTable,
                             pipeline: tuple) -> None:
        """A probe may only dispatch a join when every serving node can
        answer it from its OWN pool: a replicated build copy (broadcast
        join) or — for a partitioned probe — a shard co-partitioned with
        THIS probe (same captured CoPartition object; structural equality
        of two hash rules says nothing about which columns they hashed).
        Any other layout would silently drop matches whose build row lives
        on a different node — refuse loudly instead. A replicated probe is
        served whole from node 0, so only a replicated build (node 0 holds
        a full copy) is local there."""
        jop = op_ir.join_small_of(pipeline)
        if jop is None:
            return
        bct = self.catalog.get(jop.build_table)
        if bct is None:     # not cluster-allocated; nodes resolve (or raise)
            return
        if bct.replicated:
            return
        if (not ctable.replicated and bct.co_spec is not None
                and bct.co_spec.compatible_with(ctable.co_spec)):
            return          # build placed BY this probe's key rule
        raise fv.FarviewError(
            f"build table {jop.build_table!r} is partitioned but not "
            f"co-partitioned with probe {ctable.name!r}: allocate it with "
            "replicate=True (broadcast join) or "
            "co_partition=<probe table> (single-copy local join)")

    def flush(self) -> None:
        """Drain every node's scheduler — concurrently when `parallel`
        (nodes are independent machines; here, independent executables
        whose dispatch threads overlap). Per-node dispatch errors stay
        attached to their own requests; the first one re-raises after all
        nodes drain, like a solo node's flush."""
        pending = [node for node in self.nodes if node.has_queued]
        if not pending:
            return
        errors: list = [None] * len(pending)

        def drain(i: int, node) -> None:
            try:
                node.flush()
            except Exception as e:          # noqa: BLE001 - re-raised below
                errors[i] = e

        if self.parallel and len(pending) > 1:
            threads = [threading.Thread(target=drain, args=(i, node))
                       for i, node in enumerate(pending)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        else:
            for i, node in enumerate(pending):
                drain(i, node)
        for err in errors:
            if err is not None:
                raise err

    def settle(self) -> None:
        """Flush + finalize in-flight responses on every node."""
        try:
            self.flush()
        except Exception:
            pass                    # errors stay on their PendingRequests
        for node in self.nodes:
            node.settle()

    def farview_request(self, cqp: ClusterQP, ctable: ClusterTable,
                        pipeline: tuple, *,
                        lengths: np.ndarray | None = None,
                        strings: np.ndarray | None = None) -> PipelineResult:
        """The scatter-gather Farview verb: partition dispatch on every
        owning node, client-side merge byte-identical to a single node."""
        pend = self.submit_request(cqp, ctable, pipeline,
                                   lengths=lengths, strings=strings)
        return pend.wait()


def open_connection(cluster: FarCluster) -> ClusterQP:
    return cluster.open_connection()


def close_connection(cqp: ClusterQP) -> None:
    cqp.cluster.close_connection(cqp)
