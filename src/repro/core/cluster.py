"""FarCluster: a pool sharded across N FViewNodes with scatter-gather verbs.

The paper's premise is one large disaggregated pool serving many small
processing nodes, and its evaluation scales to multiple Farview instances.
This module is that scale-out: a `FarCluster` owns N independent
`FViewNode`s and presents the same verb surface as a single node —

    open_connection(cluster)            -> ClusterQP (one QPair per node)
    alloc_table_mem(cqp, ft)            -> ClusterTable (client-side
                                           partition map; no node traffic)
    table_write / table_read            -> row scatter / ordered gather
    farview_request(cqp, ct, pipeline)  -> merged PipelineResult
    submit_request / flush              -> async scatter-gather

Partitioning is decided client-side at `alloc_table_mem` time
(`distributed.sharding.partition_rows`): contiguous `range` blocks
(default), key-`hash` (co-locates equal keys for joins/group-bys), or the
`skew`-aware greedy balancer that places key-groups largest-first on the
least-loaded node. The map is pure metadata — nodes never talk to each
other, exactly like the paper's one-sided RDMA model.

A Farview verb against a partitioned table scatters: each owning node runs
the SAME fused `CompiledPipeline` over its local partition (select/project,
regex, crypt, join probe, partial group-aggregate) and keeps its own
bucket-batched scheduler — partition requests from many cluster clients
coalesce per node into stacked executables just like solo requests do. The
client then gathers and merges partials (`offload._merge` /
`merge_group_partials`) byte-identically to a single-node dispatch:

  * rows kind: survivors splice in original row order (each partition
    dispatch threads `row_ids` through the packing and gets them back as
    `sel_ids`), then pad to the solo-shaped (n_rows, width) buffer; a
    post-crypt response is decrypted per-node, spliced, and re-encrypted
    at merged keystream positions;
  * mask kind (regex): per-partition decisions scatter back to original
    row positions via the partition map;
  * groups kind: compact per-node partials (bucket tables + packed
    collision rows) merge in ONE device-side segment-reduce dispatch
    (offload.merge_groups_device) — the paper's client software merge,
    generalized from overflow buffers to node partials and pushed back
    onto the device.

Pre-crypt works on any partition because the CTR keystream is addressed by
ORIGINAL row offsets (`row_ids`), not local ones — a node holding rows
{3, 17, 40} of an encrypted table decrypts each with the keystream slice it
was encrypted under.

Small join build tables take one of two layouts: `replicate=True` (a copy
in every node's pool — the classic broadcast join, works against any probe
partitioning, costs N× the write traffic and footprint) or
`co_partition=<probe ClusterTable>` (build rows placed by the PROBE's
key rule so each node joins purely against its local shard — ONE copy
cluster-wide). `co_partition=` falls back to replication automatically
when the probe carries no key rule (range/replicated); dispatching a join
whose build is partitioned but NOT co-partitioned with the probe is
refused (it would silently drop matches).

Scatter dispatch is genuinely concurrent: `flush()` drains each node's
scheduler in its own thread (nodes are independent; XLA releases the GIL),
which is what the scale-out benchmark (`bench_cluster_scaleout`) measures.
Per-node read/shipped accounting stays on each node's QPair/pool; the
ClusterQP and `cluster.stats` expose the aggregate.

The partition map is kept HONEST online (PR 5): every `ClusterTable`
carries a per-node heat ledger (rows touched at scatter, bytes shipped at
gather), `check_drift` compares the observed load against the map's
balanced ideal, and `rebalance` / `auto_rebalance` live-migrate a drifted
table — moving rows through the pool read path, flipping the VERSIONED
map (in-flight verbs splice under the map they were scattered with), and
only then freeing source pages. Co-partitioned join builds are re-placed
by the re-captured key rule in the same, atomic plan. A rekeying write
(`table_write(..., keys=)`) routes rows by the captured rule so
co-location survives data rewrites; the stale-rule pile-up it can cause
is exactly what the detector flags. Full lifecycle: docs/cluster.md.
"""
from __future__ import annotations

import threading
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core import client as fv
from repro.core import operators as op_ir
from repro.core.pipeline import PipelineResult
from repro.core.pool import PoolStats
from repro.core.table import FTable, INT_EXACT_LIMIT, WORD_BYTES
from repro.distributed.rebalance import (MigrationPlan, TableHeat,
                                         detect_drift, plan_rebalance)
from repro.distributed.sharding import (CoPartition, co_partition_spec,
                                        partition_rows)


@dataclass
class ClusterTable:
    """A logical table + its client-side partition map.

    The map is *versioned*: every live-migration flip bumps `version`
    and replaces `parts` / `part_rows` wholesale. In-flight verbs are
    unaffected — a `ClusterPending` captures the map arrays it was
    scattered under, so a dispatch issued at version v still splices
    byte-identically after the table has moved on to v+1. `heat` is the
    per-node load ledger the skew-drift detector reads; `keys` is the
    CURRENT per-row partition-key column (stored client-side whenever the
    caller provides one) that a rebalance re-runs the skew-aware
    placement over."""
    schema: FTable                  # the un-partitioned table (schema, n_rows)
    parts: list                     # per-node FTable handle (None = no rows)
    part_rows: list                 # per-node original-row index arrays
    partitioner: str
    replicated: bool = False        # full copy on every node (join builds)
    co_spec: CoPartition | None = None  # key->node rule (key partitioners);
    #                                     what a co-partitioned build reuses
    keys: "np.ndarray | None" = None    # current per-row partition keys
    version: int = 0                    # bumped on every migration flip
    heat: TableHeat | None = None       # per-node load (drift detector input)

    @property
    def name(self) -> str:
        return self.schema.name

    @property
    def n_rows(self) -> int:
        return self.schema.n_rows

    @property
    def part_sizes(self) -> list:
        """Rows per node under the current map."""
        return [len(np.asarray(p)) for p in self.part_rows]


class ClusterQP:
    """One logical connection = one QPair on every node.

    Byte counters are aggregates of the per-node QPairs (reading them
    settles each node — the same lazy-accounting contract as a solo QPair);
    `requests` counts cluster verbs, `qps[i].requests` per-node dispatches.
    """

    def __init__(self, cluster: "FarCluster", qps: list):
        self.cluster = cluster
        self.qps = qps
        self.requests = 0

    @property
    def bytes_shipped(self) -> int:
        return sum(qp.bytes_shipped for qp in self.qps)

    @property
    def bytes_read_pool(self) -> int:
        return sum(qp.bytes_read_pool for qp in self.qps)


class ClusterPending:
    """A scattered Farview verb awaiting its gather.

    Captures the partition-map slices (`part_rows`) and per-node pending
    requests it was scattered under, plus the map `version` at scatter
    time: a live migration may flip the table's map while this verb is in
    flight, and the gather must splice with the OLD map's row indices —
    the ones the partitions were actually dispatched with."""

    def __init__(self, cluster: "FarCluster", ctable: ClusterTable,
                 pipeline: tuple, pends: list, part_rows: list,
                 node_ids: list):
        self.cluster = cluster
        self.ctable = ctable
        self.pipeline = pipeline
        self.pends = pends          # per-node PendingRequests (owners only)
        self.part_rows = part_rows  # aligned original-row indices
        self.node_ids = node_ids    # aligned owning-node indices
        self.version = ctable.version   # map version at scatter time
        self._merged: PipelineResult | None = None

    def wait(self) -> PipelineResult:
        """Flush every involved node and merge the partials."""
        if self._merged is not None:
            return self._merged
        flush_err: Exception | None = None
        try:
            self.cluster.flush()
        except Exception as e:      # may belong to another verb's partial
            flush_err = e
        partials = []
        for pend in self.pends:
            if pend.error is not None:
                raise pend.error
            if pend.result is None:             # never dispatched
                raise flush_err or fv.FarviewError(
                    "cluster partial was not dispatched")
            partials.append(pend.result)
        if self.ctable.replicated:
            # served whole from node 0: the partial IS the solo-shaped
            # response — merging would only rebuild (and for a post-crypt,
            # redundantly decrypt + re-encrypt) a byte-identical copy
            self._merged = partials[0]
        else:
            self._merged = fv.merge_group_partials(
                self.ctable.schema, self.pipeline, partials,
                n_rows=self.ctable.n_rows, part_rows=self.part_rows)
            # response-side heat: partials are finalized by the merge, so
            # the shipped counts are already materialized — recording them
            # here adds no synchronization (replicated tables skip it and
            # stay lazy; they have no partitions to rebalance)
            heat = self.ctable.heat
            if heat is not None:
                for node_id, p in zip(self.node_ids, partials):
                    heat.record_response(node_id, p.shipped_bytes or 0)
        return self._merged


class FarCluster:
    """N smart memory nodes behind one verb surface: client-side
    scatter-gather dispatch over per-table partition maps.

    `n_nodes` independent `FViewNode`s are created with `capacity_bytes`
    pools and `n_regions` connections each; `partitioner` sets the
    default placement rule for `alloc_table_mem` (range | hash | skew);
    `parallel=True` drains the nodes' schedulers in concurrent threads
    during `flush` (nodes are independent; XLA releases the GIL). The
    catalog maps table name -> `ClusterTable` (partition map + heat
    ledger); `check_drift` / `rebalance` / `auto_rebalance` implement the
    online skew-drift repair loop documented in docs/cluster.md. All
    merges are byte-identical to a single node holding the whole table —
    across partitioners, node counts, and live migrations."""

    def __init__(self, n_nodes: int, capacity_bytes: int = 64 * 2**20, *,
                 n_regions: int = 6, interpret: bool | None = None,
                 partitioner: str = "range", parallel: bool = True):
        if n_nodes < 1:
            raise ValueError("a cluster needs at least one node")
        self.nodes = [fv.FViewNode(capacity_bytes, n_regions=n_regions,
                                   interpret=interpret)
                      for _ in range(n_nodes)]
        self.partitioner = partitioner
        self.parallel = parallel and n_nodes > 1
        self.catalog: dict[str, ClusterTable] = {}  # name -> cluster handle

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    @property
    def dispatches(self) -> int:
        """Total stacked-executable launches across the cluster."""
        return sum(node.dispatches for node in self.nodes)

    @property
    def stats(self) -> PoolStats:
        return PoolStats.aggregate([node.pool.stats for node in self.nodes])

    # ----------------------------------------------------------- connections
    def open_connection(self) -> ClusterQP:
        qps = []
        try:
            for node in self.nodes:
                qps.append(node.open_connection())
        except fv.FarviewError:
            for qp, node in zip(qps, self.nodes):
                node.close_connection(qp)
            raise
        return ClusterQP(self, qps)

    def close_connection(self, cqp: ClusterQP) -> None:
        """Close the per-node QPairs; each node cancels the connection's
        still-queued partition requests (their `wait()` raises)."""
        for node, qp in zip(self.nodes, cqp.qps):
            node.close_connection(qp)

    # ---------------------------------------------------------------- memory
    def alloc_table_mem(self, cqp: ClusterQP, ft: FTable, *,
                        replicate: bool = False,
                        partitioner: str | None = None,
                        keys: np.ndarray | None = None,
                        co_partition: "ClusterTable | None" = None,
                        ) -> ClusterTable:
        """Partition (or replicate) a table across the nodes' pools.

        The partition map is computed HERE, once, client-side: `keys`
        (optional, one value per row) feeds the hash/skew partitioners so
        equal-key rows co-locate. `replicate=True` puts a full copy in
        every pool — for small join build tables (broadcast join).

        `co_partition=probe_ctable` places THIS table's rows (by `keys`,
        the join-key value per row) on whichever node the probe table's
        key partitioning put that key: each node then resolves build-probe
        joins entirely locally and the build is written ONCE cluster-wide
        instead of N times. Falls back to `replicate=True` automatically
        when the referenced table carries no key rule (range-partitioned
        or replicated) — co-location is impossible there, and a silent
        partition would drop join matches."""
        if ft.n_rows >= INT_EXACT_LIMIT:
            # row ids ride the fused packing as an f32 column (the same
            # exactness budget the DB enforces for i32 data at ingest);
            # ids >= 2^24 would round and silently break the merge order
            raise ValueError(
                f"cluster tables are limited to {INT_EXACT_LIMIT - 1} rows "
                "(row ids must stay f32-exact); partition the data into "
                "multiple tables")
        if co_partition is not None:
            if replicate:
                raise ValueError("co_partition and replicate are exclusive")
            spec = co_partition.co_spec
            if spec is None:        # no key rule to share: broadcast join
                return self.alloc_table_mem(cqp, ft, replicate=True)
            part_rows = partition_rows(ft.n_rows, self.n_nodes, keys=keys,
                                       co_partition=spec)
            # empty shards still allocate: every node must resolve the
            # build table by name when it joins its probe partition
            parts = self._alloc_parts(cqp, ft, [len(i) for i in part_rows],
                                      alloc_empty=True)
            return self._register(ClusterTable(
                ft, parts, part_rows, f"co[{spec.kind}]", co_spec=spec,
                keys=np.asarray(keys)))
        if replicate:
            parts = self._alloc_parts(
                cqp, ft, [ft.n_rows] * self.n_nodes)
            all_rows = np.arange(ft.n_rows, dtype=np.int64)
            return self._register(ClusterTable(
                ft, parts, [all_rows] * self.n_nodes,
                "replicate", replicated=True))
        kind = partitioner or self.partitioner
        part_rows = partition_rows(ft.n_rows, self.n_nodes, kind, keys=keys)
        parts = self._alloc_parts(cqp, ft, [len(i) for i in part_rows])
        return self._register(ClusterTable(
            ft, parts, part_rows, kind,
            co_spec=co_partition_spec(kind, self.n_nodes, keys),
            keys=None if keys is None else np.asarray(keys)))

    def _register(self, ctable: ClusterTable) -> ClusterTable:
        ctable.heat = TableHeat.zeros(self.n_nodes)
        self.catalog[ctable.name] = ctable
        return ctable

    def _alloc_parts(self, cqp: ClusterQP, ft: FTable,
                     rows_per_node: list, *,
                     alloc_empty: bool = False) -> list:
        """Allocate one partition per node (None for zero rows, unless
        `alloc_empty` — co-partitioned build shards register even when
        empty so probe-side joins resolve the name), rolling back the
        earlier nodes' allocations if a later pool is exhausted — a
        half-scattered table would leak pages with no handle to free."""
        parts: list = []
        try:
            for qp, n in zip(cqp.qps, rows_per_node):
                if n == 0 and not alloc_empty:
                    parts.append(None)
                    continue
                part = FTable(ft.name, ft.columns, n_rows=n,
                              str_width=ft.str_width)
                fv.alloc_table_mem(qp, part)
                parts.append(part)
        except Exception:
            for qp, part in zip(cqp.qps, parts):
                if part is not None:
                    fv.free_table_mem(qp, part)
            raise
        return parts

    def free_table_mem(self, cqp: ClusterQP, ctable: ClusterTable) -> None:
        for qp, part in zip(cqp.qps, ctable.parts):
            if part is not None:
                fv.free_table_mem(qp, part)
        if self.catalog.get(ctable.name) is ctable:
            del self.catalog[ctable.name]

    def table_write(self, cqp: ClusterQP, ctable: ClusterTable,
                    words: np.ndarray, *,
                    keys: np.ndarray | None = None) -> None:
        """Scatter the row matrix to the owning nodes (or all, if
        replicated). Rows land pre-split; nothing is written twice.

        `keys=` (one partition-key value per row) marks a REKEYING
        rewrite: rows are re-routed by the table's captured key->node
        rule so the co-location contract survives the new key column
        (equal keys still share a node; co-partitioned join builds placed
        by the same rule stay aligned — by construction, with no build
        migration). The routing rule itself is NOT recomputed: a key
        distribution the rule was never built for may now pile onto one
        node — which is exactly the skew drift `check_drift` observes and
        `rebalance` repairs."""
        words = np.asarray(words)
        if keys is not None:
            self._rekey(cqp, ctable, words, np.asarray(keys))
            return
        if ctable.replicated:
            for qp, part in zip(cqp.qps, ctable.parts):
                fv.table_write(qp, part, words)
            return
        for qp, part, idx in zip(cqp.qps, ctable.parts, ctable.part_rows):
            if part is not None:
                fv.table_write(qp, part, words[np.asarray(idx)])

    def _rekey(self, cqp: ClusterQP, ctable: ClusterTable,
               words: np.ndarray, keys: np.ndarray) -> None:
        """Key-routed rewrite: re-place every row by the CAPTURED rule."""
        if ctable.replicated:
            raise ValueError("a replicated table has no key routing")
        if ctable.co_spec is None:
            raise ValueError(
                f"table {ctable.name!r} is {ctable.partitioner}-partitioned "
                "with no key rule — keys= routing needs a hash/skew/"
                "co-partitioned table")
        if keys.shape[0] != ctable.n_rows:
            raise ValueError(
                f"write keys cover {keys.shape[0]} rows, "
                f"table has {ctable.n_rows}")
        owner = ctable.co_spec.owners_of(keys)
        idx = np.arange(ctable.n_rows, dtype=np.int64)
        target = [idx[owner == p] for p in range(self.n_nodes)]
        changed = any(
            len(t) != len(c) or not np.array_equal(t, np.asarray(c))
            for t, c in zip(target, ctable.part_rows))
        if changed:
            # the map moves: flip partitions to the new routing first
            # (same spec object — co-location contracts are untouched),
            # then write. Data travels once; old partitions' contents are
            # dead (the caller is overwriting every row) so they are
            # dropped, not copied.
            self._retarget(cqp, ctable, target, ctable.co_spec,
                           copy_data=False)
            # heat describes load under the map it was observed on; a
            # flip starts the ledger over so the drift detector judges
            # the NEW placement on its own traffic
            ctable.heat.reset()
        ctable.keys = keys
        for qp, part, pidx in zip(cqp.qps, ctable.parts, ctable.part_rows):
            if part is not None and part.n_rows:
                fv.table_write(qp, part, words[np.asarray(pidx)])

    def table_read(self, cqp: ClusterQP, ctable: ClusterTable) -> jnp.ndarray:
        """Plain gather-read: fetch every partition, restore original row
        order via the partition map (ships the whole table — no push-down)."""
        if ctable.replicated:
            return fv.table_read(cqp.qps[0], ctable.parts[0])
        out = np.zeros((ctable.n_rows, ctable.schema.row_words), np.float32)
        for qp, part, idx in zip(cqp.qps, ctable.parts, ctable.part_rows):
            if part is not None:
                out[np.asarray(idx)] = np.asarray(fv.table_read(qp, part))
        return jnp.asarray(out)

    # -------------------------------------------------------------- dispatch
    def submit_request(self, cqp: ClusterQP, ctable: ClusterTable,
                       pipeline: tuple, *,
                       lengths: np.ndarray | None = None,
                       strings: np.ndarray | None = None) -> ClusterPending:
        """Scatter one Farview verb: queue a partition request on every
        owning node. Each node's bucket-batched scheduler coalesces the
        partition with whatever else is queued there — K cluster clients
        running the same pipeline still cost each node ONE stacked
        dispatch per round."""
        pipeline = op_ir.validate_pipeline(tuple(pipeline))
        strings = None if strings is None else np.asarray(strings)
        lengths = None if lengths is None else np.asarray(lengths)
        self._check_join_locality(ctable, pipeline)
        if ctable.replicated:
            # a replicated table has no partitions to scatter over: serve
            # from node 0 exactly like a solo dispatch
            pend = self.nodes[0].submit(
                cqp.qps[0], ctable.parts[0], pipeline,
                lengths=lengths, strings=strings)
            cqp.requests += 1
            return ClusterPending(self, ctable, pipeline, [pend],
                                  [ctable.part_rows[0]], [0])
        pends, prows, pnodes = [], [], []
        for i, (node, qp, part, idx) in enumerate(
                zip(self.nodes, cqp.qps, ctable.parts, ctable.part_rows)):
            if part is None or part.n_rows == 0:
                continue
            idx = np.asarray(idx)
            kwargs = {}
            if strings is not None:
                kwargs["strings"] = strings[idx]
                kwargs["lengths"] = lengths[idx]
            pends.append(node.submit(qp, part, pipeline,
                                     row_ids=idx.astype(np.int32), **kwargs))
            prows.append(idx)
            pnodes.append(i)
            # scatter-side heat: the partition sizes ARE the per-node work
            # of this verb and are already client-side metadata — one
            # integer add per owning node, no device sync
            ctable.heat.record_dispatch(i, len(idx))
        cqp.requests += 1
        ctable.heat.requests += 1
        return ClusterPending(self, ctable, pipeline, pends, prows, pnodes)

    def _check_join_locality(self, ctable: ClusterTable,
                             pipeline: tuple) -> None:
        """A probe may only dispatch a join when every serving node can
        answer it from its OWN pool: a replicated build copy (broadcast
        join) or — for a partitioned probe — a shard co-partitioned with
        THIS probe (same captured CoPartition object; structural equality
        of two hash rules says nothing about which columns they hashed).
        Any other layout would silently drop matches whose build row lives
        on a different node — refuse loudly instead. A replicated probe is
        served whole from node 0, so only a replicated build (node 0 holds
        a full copy) is local there."""
        jop = op_ir.join_small_of(pipeline)
        if jop is None:
            return
        bct = self.catalog.get(jop.build_table)
        if bct is None:     # not cluster-allocated; nodes resolve (or raise)
            return
        if bct.replicated:
            return
        if (not ctable.replicated and bct.co_spec is not None
                and bct.co_spec.compatible_with(ctable.co_spec)):
            return          # build placed BY this probe's key rule
        raise fv.FarviewError(
            f"build table {jop.build_table!r} is partitioned but not "
            f"co-partitioned with probe {ctable.name!r}: allocate it with "
            "replicate=True (broadcast join) or "
            "co_partition=<probe table> (single-copy local join)")

    def flush(self) -> None:
        """Drain every node's scheduler — concurrently when `parallel`
        (nodes are independent machines; here, independent executables
        whose dispatch threads overlap). Per-node dispatch errors stay
        attached to their own requests; the first one re-raises after all
        nodes drain, like a solo node's flush."""
        pending = [node for node in self.nodes if node.has_queued]
        if not pending:
            return
        errors: list = [None] * len(pending)

        def drain(i: int, node) -> None:
            try:
                node.flush()
            except Exception as e:          # noqa: BLE001 - re-raised below
                errors[i] = e

        if self.parallel and len(pending) > 1:
            threads = [threading.Thread(target=drain, args=(i, node))
                       for i, node in enumerate(pending)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        else:
            for i, node in enumerate(pending):
                drain(i, node)
        for err in errors:
            if err is not None:
                raise err

    def settle(self) -> None:
        """Flush + finalize in-flight responses on every node."""
        try:
            self.flush()
        except Exception:
            pass                    # errors stay on their PendingRequests
        for node in self.nodes:
            node.settle()

    def farview_request(self, cqp: ClusterQP, ctable: ClusterTable,
                        pipeline: tuple, *,
                        lengths: np.ndarray | None = None,
                        strings: np.ndarray | None = None) -> PipelineResult:
        """The scatter-gather Farview verb: partition dispatch on every
        owning node, client-side merge byte-identical to a single node."""
        pend = self.submit_request(cqp, ctable, pipeline,
                                   lengths=lengths, strings=strings)
        return pend.wait()

    # ------------------------------------------------------------ rebalancing
    def check_drift(self, *, threshold: float = 1.5) -> dict:
        """Run the skew-drift detector over the catalog.

        Returns a `DriftReport` per non-replicated table: the observed
        per-node load (heat counters when the table has traffic, the
        partition sizes otherwise) against the best share a re-placement
        over the table's current keys could achieve — an inherently
        skewed but LPT-optimal table reads ~1.0 and stays put. Pure
        client-side metadata — no node traffic, no syncs (the achievable
        share costs one LPT pass over each key-partitioned table's
        keys)."""
        return {name: detect_drift(name, t.heat, t.part_sizes,
                                   keys=t.keys, threshold=threshold)
                for name, t in self.catalog.items() if not t.replicated}

    def _dependents(self, ctable: ClusterTable) -> list:
        """Tables co-partitioned BY this table's rule (join builds placed
        with `co_partition=ctable`): they share the very spec object, and
        they must move whenever the rule is re-captured."""
        if ctable.co_spec is None:
            return []
        return [t for t in self.catalog.values()
                if t is not ctable and t.co_spec is ctable.co_spec]

    def plan_table_rebalance(self, ctable: ClusterTable, *,
                             keys: np.ndarray | None = None,
                             max_step_bytes: int | None = None
                             ) -> MigrationPlan:
        """Plan (but do not execute) a rebalance — see `rebalance`."""
        if ctable.replicated:
            raise ValueError(
                f"table {ctable.name!r} is replicated; every node already "
                "holds a full copy — nothing to rebalance")
        if ctable.partitioner.startswith("co["):
            raise fv.FarviewError(
                f"table {ctable.name!r} is co-partitioned with a probe; "
                "rebalance the probe table — its plan re-places this build "
                "by the same re-captured rule")
        keys = ctable.keys if keys is None else np.asarray(keys)
        deps = self._dependents(ctable)
        return plan_rebalance(
            ctable.name, ctable.part_rows, ctable.n_rows,
            ctable.schema.row_words * WORD_BYTES, n_nodes=self.n_nodes,
            keys=keys, max_step_bytes=max_step_bytes,
            co_tables=tuple(t.name for t in deps))

    def rebalance(self, cqp: ClusterQP, ctable: ClusterTable, *,
                  keys: np.ndarray | None = None,
                  max_step_bytes: int | None = None) -> MigrationPlan:
        """Live skew-drift repair: migrate a table to a freshly-captured
        placement while serving traffic.

        The target comes from `distributed.rebalance.plan_rebalance`: the
        skew-aware LPT placement re-run over the table's CURRENT keys
        (`keys=` overrides the stored column) when it is key-partitioned,
        minimal-move row-count balancing otherwise. Execution copies the
        moving rows node-to-node through the pool read path (`table_read_
        rows` — the traffic bills like any other transfer), flips the
        versioned partition map, and only then frees the source pages;
        verbs in flight at the flip were scattered under the old map and
        still splice byte-identically (`ClusterPending` captures its map).
        Join builds co-partitioned with this table are re-placed by the
        re-captured rule in the SAME plan — atomically with the probe, so
        a local join never sees a probe row whose build row has not moved
        yet. `max_step_bytes` bounds the rows moved per map flip for
        standalone tables (co-groups always flip whole: a bounded interim
        map would break build-probe locality mid-plan). Heat counters
        reset after the flip so the detector sees post-migration traffic.
        """
        plan = self.plan_table_rebalance(ctable, keys=keys,
                                         max_step_bytes=max_step_bytes)
        deps = self._dependents(ctable)
        if plan.empty and plan.new_spec is None:
            return plan
        if deps:
            self._flip_group(cqp, ctable, plan, deps)
        elif plan.new_spec is not None:
            # stepping is safe without dependents, but the stale rule must
            # not be captured by a co_partition= alloc mid-plan: a build
            # placed by it would chase rows that already moved. Blank it;
            # co_partition= falls back to replicate (safe) until the new
            # rule lands. If a step fails, the table keeps serving
            # byte-identically from the interim map with NO key rule (the
            # truthful state: a half-moved map follows neither rule —
            # keys= rewrites are refused and co_partition= replicates);
            # a later rebalance() re-plans from the stored keys and
            # completes the migration.
            old_spec, done = ctable.co_spec, 0
            ctable.co_spec = None
            try:
                for step in plan.steps:
                    self._apply_step(cqp, ctable, step)
                    done += 1
            except Exception:
                if done == 0:
                    ctable.co_spec = old_spec   # nothing moved: still exact
                ctable.heat.reset()     # observations predate the interim map
                raise
            ctable.co_spec = plan.new_spec
            ctable.partitioner = plan.new_spec.kind
        else:
            try:
                for step in plan.steps:
                    self._apply_step(cqp, ctable, step)
            except Exception:
                ctable.heat.reset()
                raise
        if keys is not None:
            ctable.keys = np.asarray(keys)
        ctable.heat.reset()
        for t in deps:
            t.heat.reset()
        return plan

    def auto_rebalance(self, cqp: ClusterQP, *, threshold: float = 1.5,
                       max_step_bytes: int | None = None) -> dict:
        """Detector-driven sweep: rebalance every catalog table whose
        observed load imbalance exceeds `threshold`. Co-partitioned
        builds are carried by their probe's plan, never rebalanced alone.
        Returns {table name: executed MigrationPlan}."""
        out = {}
        for name, report in self.check_drift(threshold=threshold).items():
            ctable = self.catalog.get(name)
            if (ctable is None or not report.drifted
                    or ctable.partitioner.startswith("co[")):
                continue
            out[name] = self.rebalance(cqp, ctable,
                                       max_step_bytes=max_step_bytes)
        return out

    def _read_all(self, cqp: ClusterQP, ctable: ClusterTable):
        """Full original-order row matrix via the pool read path, or None
        when there is nothing to copy (string shells carry their bytes
        per-request; zero-row tables have no data)."""
        if ctable.schema.str_width or ctable.n_rows == 0:
            return None
        return np.asarray(self.table_read(cqp, ctable))

    def _flip_group(self, cqp: ClusterQP, ctable: ClusterTable,
                    plan: MigrationPlan, deps: list) -> None:
        """Atomic migration of a probe + its co-partitioned builds: one
        settle, one flip, so build-probe locality holds at every dispatch
        boundary. Work is per-NODE minimal: only partitions whose target
        index array differs are read, reallocated and rewritten — an
        unchanged node keeps its pages and never sees traffic (a fully
        unchanged table is a pure spec-object swap). Rolls back cleanly
        (old map untouched) if an affected node's pool cannot hold the
        transient old+new copies."""
        new_spec = plan.new_spec
        jobs = []           # (table, target, changed-node mask)
        for t, target in [(ctable, plan.target_part_rows)] + [
                (dep, partition_rows(dep.n_rows, self.n_nodes,
                                     keys=dep.keys, co_partition=new_spec))
                for dep in deps]:
            changed = [not np.array_equal(np.asarray(a), np.asarray(b))
                       for a, b in zip(target, t.part_rows)]
            if any(changed):
                jobs.append((t, target, changed))
            else:
                # placement already matches the re-captured rule: adopt
                # the new spec object (identity is what locality checks
                # compare) without touching a single page
                t.co_spec = new_spec
                t.partitioner = (new_spec.kind if t is ctable
                                 else f"co[{new_spec.kind}]")
        if not jobs:
            return
        # drain in-flight dispatches first: they reference the old
        # partitions' pages and resolve builds by name at dispatch time
        self.settle()
        datas = [self._read_nodes(cqp, t, changed)
                 for t, _, changed in jobs]
        news: list = []
        try:
            for t, target, changed in jobs:
                news.append(self._alloc_parts_masked(
                    cqp, t, [len(i) for i in target], changed,
                    alloc_empty=t.partitioner.startswith("co[")))
        except Exception:
            for (t, _, changed), parts in zip(jobs, news):
                for qp, part, ch in zip(cqp.qps, parts, changed):
                    if ch and part is not None:
                        fv.free_table_mem(qp, part)
            self._restore_node_catalogs(jobs)
            raise
        for (t, target, changed), words, parts in zip(jobs, datas, news):
            if words is None:
                continue
            for qp, part, idx, ch in zip(cqp.qps, parts, target, changed):
                if ch and part is not None and part.n_rows:
                    fv.table_write(qp, part, words[np.asarray(idx)])
        for (t, target, changed), parts in zip(jobs, news):
            old = t.parts
            t.parts = parts
            t.part_rows = [np.asarray(i) for i in target]
            t.version += 1
            t.co_spec = new_spec
            t.partitioner = (new_spec.kind if t is ctable
                             else f"co[{new_spec.kind}]")
            for qp, part, ch in zip(cqp.qps, old, changed):
                if ch and part is not None:
                    fv.free_table_mem(qp, part)

    def _read_nodes(self, cqp: ClusterQP, ctable: ClusterTable, changed):
        """Row matrix holding the CHANGED partitions' rows at their
        original positions (unchanged nodes' rows are neither read nor
        needed — they stay where they are). None for string shells and
        empty tables."""
        if ctable.schema.str_width or ctable.n_rows == 0:
            return None
        out = np.zeros((ctable.n_rows, ctable.schema.row_words), np.float32)
        for qp, part, idx, ch in zip(cqp.qps, ctable.parts,
                                     ctable.part_rows, changed):
            if ch and part is not None and part.n_rows:
                out[np.asarray(idx)] = np.asarray(fv.table_read(qp, part))
        return out

    def _alloc_parts_masked(self, cqp: ClusterQP, ctable: ClusterTable,
                            rows_per_node: list, changed, *,
                            alloc_empty: bool) -> list:
        """Like `_alloc_parts`, but nodes whose placement is unchanged
        keep their existing partition object (no realloc, no traffic);
        rolls back this call's own allocations on failure."""
        sch = ctable.schema
        parts: list = []
        try:
            for qp, cur, n, ch in zip(cqp.qps, ctable.parts,
                                      rows_per_node, changed):
                if not ch:
                    parts.append(cur)       # carried forward untouched
                    continue
                if n == 0 and not alloc_empty:
                    parts.append(None)
                    continue
                part = FTable(sch.name, sch.columns, n_rows=n,
                              str_width=sch.str_width)
                fv.alloc_table_mem(qp, part)
                parts.append(part)
        except Exception:
            for qp, part, ch in zip(cqp.qps, parts, changed):
                if ch and part is not None:
                    fv.free_table_mem(qp, part)
            raise
        return parts

    def _restore_node_catalogs(self, jobs) -> None:
        """Rollback helper: a failed migration alloc may have overwritten
        a node's name catalog with since-freed shards; point the entries
        back at the still-serving old partitions so join build resolution
        cannot touch freed pages."""
        for t, _ in jobs:
            for node, old in zip(self.nodes, t.parts):
                if old is not None:
                    node.tables[old.name] = old

    def _retarget(self, cqp: ClusterQP, ctable: ClusterTable,
                  target_part_rows: list, spec, *,
                  copy_data: bool = True) -> None:
        """Whole-table re-placement under an unchanged key rule (the
        rekeying write path): settle, realloc to the target sizes,
        optionally copy the old contents, flip, free."""
        self.settle()
        words = self._read_all(cqp, ctable) if copy_data else None
        try:
            parts = self._alloc_parts(
                cqp, ctable.schema, [len(i) for i in target_part_rows],
                alloc_empty=ctable.partitioner.startswith("co["))
        except Exception:
            self._restore_node_catalogs([(ctable, None)])
            raise
        if words is not None:
            for qp, part, idx in zip(cqp.qps, parts, target_part_rows):
                if part is not None and part.n_rows:
                    fv.table_write(qp, part, words[np.asarray(idx)])
        old = ctable.parts
        ctable.parts = parts
        ctable.part_rows = [np.asarray(i) for i in target_part_rows]
        ctable.version += 1
        ctable.co_spec = spec
        for qp, part in zip(cqp.qps, old):
            if part is not None:
                fv.free_table_mem(qp, part)

    def _apply_step(self, cqp: ClusterQP, ctable: ClusterTable,
                    step) -> None:
        """Execute one bounded migration step: copy `step.row_ids` from
        node `src` to node `dst` via the pool read path, rebuild the two
        affected partitions, flip the versioned map, free the old pages.
        Results stay byte-identical at every step boundary — the map
        always covers every row exactly once."""
        src, dst = step.src, step.dst
        src_rows = np.asarray(ctable.part_rows[src])
        dst_rows = np.asarray(ctable.part_rows[dst])
        moving = np.asarray(step.row_ids)
        pos = np.searchsorted(src_rows, moving)
        if (len(src_rows) == 0 or not np.all(pos < len(src_rows))
                or not np.array_equal(src_rows[np.minimum(
                    pos, len(src_rows) - 1)], moving)):
            raise fv.FarviewError(
                f"stale migration step for {ctable.name!r}: rows are no "
                "longer on the source node (re-plan against the current "
                "map version)")
        keep = np.ones(len(src_rows), bool)
        keep[pos] = False
        new_src_rows = src_rows[keep]
        merged = np.concatenate([dst_rows, moving])
        order = np.argsort(merged, kind="stable")
        new_dst_rows = merged[order]

        # in-flight dispatches hold the old partitions' pages (and joins
        # resolve build shards by name at dispatch time): drain before the
        # extents change hands
        self.settle()
        is_str = bool(ctable.schema.str_width)
        kept_words = moved_words = dst_words = None
        if not is_str:
            src_part = ctable.parts[src]
            moved_words = np.asarray(
                fv.table_read_rows(cqp.qps[src], src_part, pos))
            kept_words = np.asarray(fv.table_read_rows(
                cqp.qps[src], src_part, np.nonzero(keep)[0]))
            if ctable.parts[dst] is not None and ctable.parts[dst].n_rows:
                dst_words = np.asarray(
                    fv.table_read(cqp.qps[dst], ctable.parts[dst]))
        dmat = (moved_words if dst_words is None and moved_words is not None
                else None)
        if dst_words is not None:
            dmat = np.concatenate([dst_words, moved_words])[order]

        sch = ctable.schema
        new_src = new_dst = None
        allocd = []
        try:
            if len(new_src_rows):
                new_src = FTable(sch.name, sch.columns,
                                 n_rows=len(new_src_rows),
                                 str_width=sch.str_width)
                fv.alloc_table_mem(cqp.qps[src], new_src)
                allocd.append((src, new_src))
            new_dst = FTable(sch.name, sch.columns,
                             n_rows=len(new_dst_rows),
                             str_width=sch.str_width)
            fv.alloc_table_mem(cqp.qps[dst], new_dst)
            allocd.append((dst, new_dst))
        except Exception:
            for i, part in allocd:
                fv.free_table_mem(cqp.qps[i], part)
            self._restore_node_catalogs([(ctable, None)])
            raise
        if not is_str:
            if new_src is not None and kept_words is not None:
                fv.table_write(cqp.qps[src], new_src, kept_words)
            if dmat is not None:
                fv.table_write(cqp.qps[dst], new_dst, dmat)
        old_src, old_dst = ctable.parts[src], ctable.parts[dst]
        ctable.parts[src] = new_src
        ctable.parts[dst] = new_dst
        ctable.part_rows[src] = new_src_rows
        ctable.part_rows[dst] = new_dst_rows
        ctable.version += 1
        if old_src is not None:
            fv.free_table_mem(cqp.qps[src], old_src)
        if old_dst is not None:
            fv.free_table_mem(cqp.qps[dst], old_dst)


def open_connection(cluster: FarCluster) -> ClusterQP:
    return cluster.open_connection()


def close_connection(cqp: ClusterQP) -> None:
    cqp.cluster.close_connection(cqp)
