"""FarCluster: a pool sharded across N FViewNodes with scatter-gather verbs.

The paper's premise is one large disaggregated pool serving many small
processing nodes, and its evaluation scales to multiple Farview instances.
This module is that scale-out: a `FarCluster` owns N independent
`FViewNode`s and presents the same verb surface as a single node —

    open_connection(cluster)            -> ClusterQP (one QPair per node)
    alloc_table_mem(cqp, ft)            -> ClusterTable (client-side
                                           partition map; no node traffic)
    table_write / table_read            -> row scatter / ordered gather
    farview_request(cqp, ct, pipeline)  -> merged PipelineResult
    submit_request / flush              -> async scatter-gather

Partitioning is decided client-side at `alloc_table_mem` time
(`distributed.sharding.partition_rows`): contiguous `range` blocks
(default), key-`hash` (co-locates equal keys for joins/group-bys), or the
`skew`-aware greedy balancer that places key-groups largest-first on the
least-loaded node. The map is pure metadata — nodes never talk to each
other, exactly like the paper's one-sided RDMA model.

A Farview verb against a partitioned table scatters: each owning node runs
the SAME fused `CompiledPipeline` over its local partition (select/project,
regex, crypt, join probe, partial group-aggregate) and keeps its own
bucket-batched scheduler — partition requests from many cluster clients
coalesce per node into stacked executables just like solo requests do. The
client then gathers and merges partials (`offload._merge` /
`merge_group_partials`) byte-identically to a single-node dispatch:

  * rows kind: survivors splice in original row order (each partition
    dispatch threads `row_ids` through the packing and gets them back as
    `sel_ids`), then pad to the solo-shaped (n_rows, width) buffer; a
    post-crypt response is decrypted per-node, spliced, and re-encrypted
    at merged keystream positions;
  * mask kind (regex): per-partition decisions scatter back to original
    row positions via the partition map;
  * groups kind: compact per-node partials (bucket tables + packed
    collision rows) merge in ONE device-side segment-reduce dispatch
    (offload.merge_groups_device) — the paper's client software merge,
    generalized from overflow buffers to node partials and pushed back
    onto the device.

Pre-crypt works on any partition because the CTR keystream is addressed by
ORIGINAL row offsets (`row_ids`), not local ones — a node holding rows
{3, 17, 40} of an encrypted table decrypts each with the keystream slice it
was encrypted under.

Small join build tables take one of two layouts: `replicate=True` (a copy
in every node's pool — the classic broadcast join, works against any probe
partitioning, costs N× the write traffic and footprint) or
`co_partition=<probe ClusterTable>` (build rows placed by the PROBE's
key rule so each node joins purely against its local shard — ONE copy
cluster-wide). `co_partition=` falls back to replication automatically
when the probe carries no key rule (range/replicated); dispatching a join
whose build is partitioned but NOT co-partitioned with the probe is
refused (it would silently drop matches).

Scatter dispatch is genuinely concurrent: `flush()` drains each node's
scheduler in its own thread (nodes are independent; XLA releases the GIL),
which is what the scale-out benchmark (`bench_cluster_scaleout`) measures.
Per-node read/shipped accounting stays on each node's QPair/pool; the
ClusterQP and `cluster.stats` expose the aggregate.

The partition map is kept HONEST online (PR 5): every `ClusterTable`
carries a per-node heat ledger (rows touched at scatter, bytes shipped at
gather), `check_drift` compares the observed load against the map's
balanced ideal, and `rebalance` / `auto_rebalance` live-migrate a drifted
table — moving rows through the pool read path, flipping the VERSIONED
map (in-flight verbs splice under the map they were scattered with), and
only then freeing source pages. Co-partitioned join builds are re-placed
by the re-captured key rule in the same, atomic plan. A rekeying write
(`table_write(..., keys=)`) routes rows by the captured rule so
co-location survives data rewrites; the stale-rule pile-up it can cause
is exactly what the detector flags. Full lifecycle: docs/cluster.md.

The cluster SURVIVES node loss (PR 6). `alloc_table_mem(replicas=k)`
writes every partition to k distinct nodes (replica r of partition i on
node (i+r) mod N — the shared cyclic rule keeps a co-partitioned build's
replicas on the same nodes as its probe's, so local joins stay local
after a failover). Partition i's serving node is `ClusterTable.home[i]`
(identity until a failure moves it); extra copies live in
`ClusterTable.replicas[i]` and are registered in the holding node's
catalog under the shard alias `"{name}@p{i}"` — the plain name on node n
always means "node n's own partition n", which is what join build
resolution relies on, so a dispatch served OFF its home node rewrites
`JoinSmall.build_table` to the alias (`_localize_pipeline`). A
`HealthMonitor` (distributed/health.py) classifies per-dispatch failures
into the ALIVE → SUSPECT → DEAD lifecycle; scatter routes around DEAD
nodes up front and `ClusterPending.wait` retries dropped dispatches on
the same node (bounded backoff) or re-scatters a dead node's partitions
to the next alive copy mid-flight — byte-identically, because the merge
splice and the crypt keystream are keyed by the captured original-row
indices, not by which node answered. `heal()` is the self-healing
rebuild: promote a replica for every dead primary, re-replicate back to
k copies on the survivors, flip the versioned map once per table —
falling back to a cold-storage snapshot (`snapshot` / `restore_table`,
via checkpoint.CheckpointManager) when every copy of a partition died.
Failures themselves are injectable (`FarCluster.fault`, a FaultInjector
threaded through every node's verb path) so all of this is testable.
"""
from __future__ import annotations

import threading
import time
import warnings
from dataclasses import dataclass, replace as dc_replace

import jax.numpy as jnp
import numpy as np

from repro.core import client as fv
from repro.core import operators as op_ir
from repro.core.pipeline import PipelineResult
from repro.core.pool import PoolStats
from repro.core.table import FTable, INT_EXACT_LIMIT, WORD_BYTES
from repro.distributed.health import (DEAD, CircuitBreaker,
                                      DroppedDispatchError, FaultInjector,
                                      HealthMonitor,
                                      ReplicaUnavailableError)
from repro.distributed.rebalance import (MigrationPlan, TableHeat,
                                         detect_drift, plan_rebalance)
from repro.distributed.sharding import (CoPartition, co_partition_spec,
                                        partition_rows)


@dataclass
class ClusterTable:
    """A logical table + its client-side partition map.

    The map is *versioned*: every live-migration flip bumps `version`
    and replaces `parts` / `part_rows` wholesale. In-flight verbs are
    unaffected — a `ClusterPending` captures the map arrays it was
    scattered under, so a dispatch issued at version v still splices
    byte-identically after the table has moved on to v+1. `heat` is the
    per-node load ledger the skew-drift detector reads; `keys` is the
    CURRENT per-row partition-key column (stored client-side whenever the
    caller provides one) that a rebalance re-runs the skew-aware
    placement over."""
    schema: FTable                  # the un-partitioned table (schema, n_rows)
    parts: list                     # per-node FTable handle (None = no rows)
    part_rows: list                 # per-node original-row index arrays
    partitioner: str
    replicated: bool = False        # full copy on every node (join builds)
    co_spec: CoPartition | None = None  # key->node rule (key partitioners);
    #                                     what a co-partitioned build reuses
    keys: "np.ndarray | None" = None    # current per-row partition keys
    version: int = 0                    # bumped on every migration flip
    # per-partition epochs (PR 10): `part_version[i]` bumps whenever
    # partition i's CONTENT or placement changes — a write landing on it,
    # a migration step moving its rows, a heal promoting/restoring it.
    # The client-side PageCache stamps entries with the epoch at fill
    # time, so a flip invalidates exactly the partitions it touched and
    # nothing else (cache coherence without callbacks).
    part_version: "list[int] | None" = None
    heat: TableHeat | None = None       # per-node load (drift detector input)
    # replication (PR 6): partition i is SERVED by node `home[i]` (identity
    # until a failure promotes a replica); `replicas[i]` maps node -> the
    # extra copy it holds (registered there under the "{name}@p{i}" alias);
    # `k_replicas` is the redundancy contract heal() restores after a loss.
    home: "list[int] | None" = None
    replicas: "list[dict] | None" = None
    k_replicas: int = 1

    @property
    def name(self) -> str:
        return self.schema.name

    @property
    def n_rows(self) -> int:
        return self.schema.n_rows

    @property
    def part_sizes(self) -> list:
        """Rows per node under the current map."""
        return [len(np.asarray(p)) for p in self.part_rows]

    def bump(self, indices=None) -> None:
        """One map flip: bump the table version AND the epochs of the
        partitions it touched (all of them by default)."""
        self.version += 1
        self.bump_parts(range(len(self.parts)) if indices is None
                        else indices)

    def bump_parts(self, indices) -> None:
        """Advance the named partitions' epochs without a map flip (the
        in-place write path: placement unchanged, bytes replaced)."""
        if self.part_version is None:
            return
        for i in indices:
            self.part_version[i] += 1


class ClusterQP:
    """One logical connection = one QPair on every node.

    Byte counters are aggregates of the per-node QPairs (reading them
    settles each node — the same lazy-accounting contract as a solo QPair);
    `requests` counts cluster verbs, `qps[i].requests` per-node dispatches.
    """

    def __init__(self, cluster: "FarCluster", qps: list):
        self.cluster = cluster
        self.qps = qps
        self.requests = 0
        # client-cache accounting (PR 10): a hit is a table_read
        # partition served without touching any node; only meaningful
        # when the cluster was built with cache_bytes > 0
        self.cache_hits = 0
        self.cache_misses = 0

    @property
    def bytes_shipped(self) -> int:
        return sum(qp.bytes_shipped for qp in self.qps)

    @property
    def bytes_read_pool(self) -> int:
        return sum(qp.bytes_read_pool for qp in self.qps)


class ClusterPending:
    """A scattered Farview verb awaiting its gather.

    Captures the partition-map slices (`part_rows`) and per-node pending
    requests it was scattered under, plus the map `version` at scatter
    time: a live migration may flip the table's map while this verb is in
    flight, and the gather must splice with the OLD map's row indices —
    the ones the partitions were actually dispatched with.

    Failures resolve HERE, mid-flight (PR 6): each entry also remembers
    its partition index, serving node and payload slice, so `wait()` can
    classify a dispatch error — a `DroppedDispatchError` retries the SAME
    node with bounded exponential backoff; a `NodeDeadError` marks the
    node DEAD in the health monitor and re-scatters the partition to the
    next alive copy (primary first, then replicas in placement order),
    re-localizing the pipeline so a co-partitioned join resolves the
    build shard on the new node. The rerouted gather stays byte-identical
    because the captured row-index array keys both the merge splice and
    the crypt keystream, and a replica holds the same bytes its primary
    did. When every copy of a partition is dead the verb fails LOUDLY:
    `ReplicaUnavailableError` with redundancy (k>1), the original
    `NodeDeadError` without."""

    MAX_SAME_NODE_RETRIES = 3       # DroppedDispatch retries per node
    BACKOFF_S = 0.02                # doubled per retry, capped at 0.2 s

    def __init__(self, cluster: "FarCluster", ctable: ClusterTable,
                 pipeline: tuple, pends: list, part_rows: list,
                 node_ids: list, *, cqp=None, part_ids: list | None = None,
                 handles: list | None = None,
                 strings: "np.ndarray | None" = None,
                 lengths: "np.ndarray | None" = None,
                 deadline_at: float | None = None):
        self.cluster = cluster
        self.ctable = ctable
        self.pipeline = pipeline    # base (un-localized) pipeline
        self.pends = pends          # per-node PendingRequests (owners only)
        self.part_rows = part_rows  # aligned original-row indices
        self.node_ids = node_ids    # aligned SERVING-node indices
        self.cqp = cqp              # connection — needed to re-scatter
        self.part_ids = list(node_ids) if part_ids is None else part_ids
        self.handles = ([p.ft for p in pends] if handles is None
                        else handles)
        self.strings = strings      # full payload (re-sliced on failover)
        self.lengths = lengths
        self.version = ctable.version   # map version at scatter time
        # one deadline for the WHOLE query: every retry / failover /
        # hedge spends what remains of it, never a fresh budget
        self.deadline_at = deadline_at
        # entry k -> (node_id, handle, pend) of its in-flight hedge
        self._hedges: dict = {}
        self._merged: PipelineResult | None = None

    # ------------------------------------------------------------ deadlines
    def _remaining_s(self, *, op: str) -> float | None:
        """Seconds left of the query budget (None = unbounded); typed
        failure the moment the budget is spent — a retry or hedge never
        launches work the caller has already given up on."""
        if self.deadline_at is None:
            return None
        rem = self.deadline_at - time.monotonic()
        if rem <= 0:
            raise fv.DeadlineExceededError(
                None, op=op, detail="query budget spent across scatter legs")
        return rem

    # ------------------------------------------------------------- failover
    def _submit_to(self, k: int, node_id: int, handle, *,
                   op: str = "failover") -> "fv.PendingRequest":
        """Dispatch entry k's work onto `node_id` (no state mutation):
        shared by same-node retries, failovers and hedges."""
        cluster, ct = self.cluster, self.ctable
        idx = np.asarray(self.part_rows[k])
        kwargs = {}
        rem = self._remaining_s(op=op)
        if rem is not None:
            kwargs["deadline_s"] = rem
        if ct.replicated:
            if self.strings is not None:
                kwargs.update(strings=self.strings, lengths=self.lengths)
            return cluster.nodes[node_id].submit(
                self.cqp.qps[node_id], handle, self.pipeline, **kwargs)
        if self.strings is not None:
            kwargs.update(strings=self.strings[idx],
                          lengths=self.lengths[idx])
        lp = cluster._localize_pipeline(
            ct, self.pipeline, self.part_ids[k], node_id)
        pend = cluster.nodes[node_id].submit(
            self.cqp.qps[node_id], handle, lp,
            row_ids=idx.astype(np.int32), **kwargs)
        if ct.heat is not None:
            ct.heat.record_dispatch(node_id, len(idx))
            if node_id != ct.home[self.part_ids[k]]:
                ct.heat.record_failover(node_id, len(idx))
        return pend

    def _resubmit(self, k: int, node_id: int, handle) -> "fv.PendingRequest":
        """Re-scatter entry k onto `node_id` and drain just that node."""
        pend = self._submit_to(k, node_id, handle)
        self.pends[k] = pend
        self.node_ids[k] = node_id
        self.handles[k] = handle
        try:
            self.cluster._drain_node(node_id)
        except Exception:           # noqa: BLE001
            pass    # the error (if ours) is on the pend; the loop inspects
        return pend

    # -------------------------------------------------------------- hedging
    def _launch_hedges(self) -> int:
        """Duplicate every still-unresolved entry onto its next replica
        (react-to-slowness: the primary exceeded the hedge delay). The
        first copy to RESOLVE wins — byte-identical by construction,
        because the captured row-index array keys the merge splice and
        the crypt keystream on whichever node answers. Returns the number
        of duplicates launched this round (0 = nothing left to hedge)."""
        cluster, ct = self.cluster, self.ctable
        launched = 0
        for k in range(len(self.pends)):
            if k in self._hedges:
                continue            # one hedge per entry
            p = self.pends[k]
            if p.result is not None or p.error is not None:
                continue            # already resolved: nothing to race
            nxt = cluster._next_candidate(ct, self.part_ids[k],
                                          {self.node_ids[k]})
            if nxt is None:
                continue            # no replica to hedge onto
            try:
                hp = self._submit_to(k, nxt[0], nxt[1], op="hedge")
            except fv.DeadlineExceededError:
                break               # no budget left to spend on duplicates
            except fv.FarviewError:
                continue            # hedge is best-effort; primary stands
            self._hedges[k] = (nxt[0], nxt[1], hp)
            launched += 1
        for node_id in {n for n, _, _ in self._hedges.values()}:
            try:
                cluster._drain_node(node_id)
            except Exception:       # noqa: BLE001
                pass    # a failed hedge stays on its pend; primary stands
        return launched

    def _all_resolved(self) -> bool:
        """Every entry has an answer — its own, or a finished hedge."""
        for k, p in enumerate(self.pends):
            if p.result is not None or p.error is not None:
                continue
            h = self._hedges.get(k)
            if h is not None and h[2].result is not None:
                continue
            return False
        return True

    def _settle_entry(self, k: int,
                      flush_err: Exception | None) -> PipelineResult:
        """Entry k's partial — retrying / failing over until it resolves."""
        cluster, ct = self.cluster, self.ctable
        health = cluster.health
        pend = self.pends[k]
        tried = {self.node_ids[k]}
        retries = 0
        while True:
            hedge = self._hedges.get(k)
            if (hedge is not None and pend.result is None
                    and hedge[2].result is not None):
                # the hedge finished first (or the primary failed): adopt
                # its byte-identical partial; the loser's eventual answer
                # is discarded — ties go to the primary, checked above
                del self._hedges[k]
                self.pends[k] = pend = hedge[2]
                self.node_ids[k] = hedge[0]
                self.handles[k] = hedge[1]
                continue
            if pend.error is None:
                if pend.result is not None:
                    return pend.result
                raise flush_err or fv.FarviewError(
                    "cluster partial was not dispatched")
            err = pend.error
            node_id = self.node_ids[k]
            if isinstance(err, DroppedDispatchError):
                state = health.record_failure(node_id, err)
                if state != DEAD and retries < self.MAX_SAME_NODE_RETRIES:
                    # transient: the node is still there — same-node retry
                    time.sleep(min(self.BACKOFF_S * 2 ** retries, 0.2))
                    retries += 1
                    pend = self._resubmit(k, node_id, self.handles[k])
                    continue
            elif isinstance(err, fv.NodeDeadError):
                health.record_failure(node_id, err)
            else:
                raise err       # not a node failure (bad pipeline, closed
                #                 connection, ...): failover can't help
            if self.cqp is None:
                raise err
            nxt = cluster._next_candidate(ct, self.part_ids[k], tried)
            if nxt is None:     # redundancy exhausted — loud, never partial
                if ct.replicated or ct.k_replicas > 1:
                    raise ReplicaUnavailableError(
                        f"table {ct.name!r}: every copy of partition "
                        f"{self.part_ids[k]} is on a dead node") from err
                raise err
            tried.add(nxt[0])
            retries = 0
            pend = self._resubmit(k, nxt[0], nxt[1])

    def wait(self) -> PipelineResult:
        """Flush every involved node and merge the partials."""
        if self._merged is not None:
            return self._merged
        flush_err: Exception | None = None
        try:
            self.cluster._flush_with_hedging(self)
        except Exception as e:      # may belong to another verb's partial
            flush_err = e
        partials = [self._settle_entry(k, flush_err)
                    for k in range(len(self.pends))]
        if self.ctable.replicated:
            # served whole from node 0: the partial IS the solo-shaped
            # response — merging would only rebuild (and for a post-crypt,
            # redundantly decrypt + re-encrypt) a byte-identical copy
            self._merged = partials[0]
        else:
            self._merged = fv.merge_group_partials(
                self.ctable.schema, self.pipeline, partials,
                n_rows=self.ctable.n_rows, part_rows=self.part_rows)
            # response-side heat: partials are finalized by the merge, so
            # the shipped counts are already materialized — recording them
            # here adds no synchronization (replicated tables skip it and
            # stay lazy; they have no partitions to rebalance)
            heat = self.ctable.heat
            if heat is not None:
                for node_id, p in zip(self.node_ids, partials):
                    heat.record_response(node_id, p.shipped_bytes or 0)
        return self._merged


class FarCluster:
    """N smart memory nodes behind one verb surface: client-side
    scatter-gather dispatch over per-table partition maps.

    `n_nodes` independent `FViewNode`s are created with `capacity_bytes`
    pools and `n_regions` connections each; `partitioner` sets the
    default placement rule for `alloc_table_mem` (range | hash | skew);
    `parallel=True` drains the nodes' schedulers in concurrent threads
    during `flush` (nodes are independent; XLA releases the GIL). The
    catalog maps table name -> `ClusterTable` (partition map + heat
    ledger); `check_drift` / `rebalance` / `auto_rebalance` implement the
    online skew-drift repair loop documented in docs/cluster.md. All
    merges are byte-identical to a single node holding the whole table —
    across partitioners, node counts, and live migrations."""

    def __init__(self, n_nodes: int | None = None,
                 capacity_bytes: int = 64 * 2**20, *,
                 n_regions: int = 6, interpret: bool | None = None,
                 partitioner: str = "range", parallel: bool = True,
                 replicas: int = 1, dead_after: int = 3,
                 slow_after_s: float = 300.0,
                 hedge_after_s: float | None = None,
                 fault: FaultInjector | None = None,
                 breaker: CircuitBreaker | None = None,
                 nodes: list | None = None,
                 cache_bytes: int = 0,
                 page_bytes: int | None = None):
        # `nodes=` plugs in pre-built node handles — notably
        # `net.client.RemoteNodeHandle` transports to real `FViewServer`
        # processes (see `net.client.remote_cluster`). Anything with the
        # FViewNode duck type works; handle i must sit at cluster
        # position i so partition maps and replica placement line up.
        if nodes is not None:
            nodes = list(nodes)
            if n_nodes is None:
                n_nodes = len(nodes)
            elif n_nodes != len(nodes):
                raise ValueError(
                    f"n_nodes={n_nodes} but nodes= has {len(nodes)}")
            for i, node in enumerate(nodes):
                if node.node_id != i:
                    raise ValueError(
                        f"nodes[{i}] carries node_id {node.node_id}; "
                        "handles must be ordered by cluster position")
        if n_nodes is None:
            raise ValueError("pass n_nodes or nodes=")
        if n_nodes < 1:
            raise ValueError("a cluster needs at least one node")
        if not 1 <= replicas <= n_nodes:
            raise ValueError(
                f"replicas={replicas} needs 1..{n_nodes} (each copy of a "
                "partition must land on a distinct node)")
        # every node consults the SAME injector on every verb, so a test
        # or bench kills a node in one call and every path sees it
        self.fault = FaultInjector() if fault is None else fault
        # the breaker layers under the monitor: the monitor answers "is
        # the node gone?", the breaker "should the next attempt even be
        # made?" — every success/failure the monitor records is forwarded
        self.breaker = (CircuitBreaker(n_nodes) if breaker is None
                        else breaker)
        self.health = HealthMonitor(n_nodes, dead_after=dead_after,
                                    slow_after_s=slow_after_s,
                                    breaker=self.breaker)
        # hedge delay: a verb whose drain outlives this launches its
        # unresolved partitions on the cyclic replica (first answer
        # wins). Defaults to the monitor's slow threshold — hedging IS
        # the react-to-slowness complement of the SUSPECT strike.
        self.hedge_after_s = hedge_after_s
        # serializes flushes per node: the background drain of a hedged
        # verb, failover re-drains and ordinary cluster flushes may
        # target the same node concurrently
        self._node_locks = [threading.Lock() for _ in range(n_nodes)]
        node_kw = {} if page_bytes is None else {"page_bytes": page_bytes}
        self.nodes = nodes if nodes is not None else [
            fv.FViewNode(capacity_bytes, n_regions=n_regions,
                         interpret=interpret, node_id=i, fault=self.fault,
                         **node_kw)
            for i in range(n_nodes)]
        self.partitioner = partitioner
        self.replicas = int(replicas)   # default k for alloc_table_mem
        self.parallel = parallel and n_nodes > 1
        # Guards the shared table catalog: parallel drain threads, a
        # concurrent heal()/auto_rebalance, and alloc/free all touch it.
        # RLock because the sweeps hold it while calling helpers
        # (free_table_mem, check_drift) that take it again.
        self._lock = threading.RLock()
        self.catalog: dict[str, ClusterTable] = {}  # guarded-by: self._lock
        # client-side coherent partition cache (PR 10): opt-in by byte
        # budget. `table_read` consults it per partition, validated
        # against the partition's epoch — hits skip the network entirely,
        # and any flip (write / rebalance / heal) invalidates exactly the
        # partitions it bumped. Off (None) by default: zero overhead and
        # byte counters identical to the un-cached cluster.
        self.cache = fv.PageCache(cache_bytes) if cache_bytes else None

    @property
    def n_nodes(self) -> int:
        return len(self.nodes)

    @property
    def dispatches(self) -> int:
        """Total stacked-executable launches across the cluster."""
        return sum(node.dispatches for node in self.nodes)

    @property
    def stats(self) -> PoolStats:
        return PoolStats.aggregate([node.pool.stats for node in self.nodes])

    # ----------------------------------------------------------- connections
    def open_connection(self) -> ClusterQP:
        qps = []
        try:
            for node in self.nodes:
                qps.append(node.open_connection())
        except fv.FarviewError:
            for qp, node in zip(qps, self.nodes):
                node.close_connection(qp)
            raise
        return ClusterQP(self, qps)

    def close_connection(self, cqp: ClusterQP) -> None:
        """Close the per-node QPairs; each node cancels the connection's
        still-queued partition requests (their `wait()` raises). A DEAD
        node's QPair is skipped with a warning — the node is gone and so
        is everything bound to it; raising here would wedge a teardown
        that is already doing the right thing."""
        for node, qp in zip(self.nodes, cqp.qps):
            if not self.health.is_alive(node.node_id):
                warnings.warn(
                    f"close_connection: node {node.node_id} is dead; "
                    f"abandoning qp{qp.qp_id} without a close handshake",
                    stacklevel=2)
                continue
            node.close_connection(qp)

    # ---------------------------------------------------------------- memory
    def alloc_table_mem(self, cqp: ClusterQP, ft: FTable, *,
                        replicate: bool = False,
                        partitioner: str | None = None,
                        keys: np.ndarray | None = None,
                        co_partition: "ClusterTable | None" = None,
                        replicas: int | None = None,
                        ) -> ClusterTable:
        """Partition (or replicate) a table across the nodes' pools.

        The partition map is computed HERE, once, client-side: `keys`
        (optional, one value per row) feeds the hash/skew partitioners so
        equal-key rows co-locate. `replicate=True` puts a full copy in
        every pool — for small join build tables (broadcast join).

        `co_partition=probe_ctable` places THIS table's rows (by `keys`,
        the join-key value per row) on whichever node the probe table's
        key partitioning put that key: each node then resolves build-probe
        joins entirely locally and the build is written ONCE cluster-wide
        instead of N times. Falls back to `replicate=True` automatically
        when the referenced table carries no key rule (range-partitioned
        or replicated) — co-location is impossible there, and a silent
        partition would drop join matches.

        `replicas=k` (default: the cluster's `replicas`) writes every
        partition to k DISTINCT nodes — copy r of partition i lands on
        node (i+r) mod N, so a probe and its co-partitioned build (same
        rule, same k) keep their copies co-located and a failover join
        stays local. Extra copies cost (k-1)x the write traffic and
        footprint (`TableHeat.replica_bytes_written` itemizes it) and buy
        node-loss survival: reads fail over, `heal()` re-replicates."""
        k = self.replicas if replicas is None else int(replicas)
        if not 1 <= k <= self.n_nodes:
            raise ValueError(
                f"replicas={k} needs 1..{self.n_nodes} distinct nodes")
        if ft.n_rows >= INT_EXACT_LIMIT:
            # row ids ride the fused packing as an f32 column (the same
            # exactness budget the DB enforces for i32 data at ingest);
            # ids >= 2^24 would round and silently break the merge order
            raise ValueError(
                f"cluster tables are limited to {INT_EXACT_LIMIT - 1} rows "
                "(row ids must stay f32-exact); partition the data into "
                "multiple tables")
        if co_partition is not None:
            if replicate:
                raise ValueError("co_partition and replicate are exclusive")
            spec = co_partition.co_spec
            if spec is None:        # no key rule to share: broadcast join
                return self.alloc_table_mem(cqp, ft, replicate=True)
            part_rows = partition_rows(ft.n_rows, self.n_nodes, keys=keys,
                                       co_partition=spec)
            # empty shards still allocate: every node must resolve the
            # build table by name when it joins its probe partition
            parts = self._alloc_parts(cqp, ft, [len(i) for i in part_rows],
                                      alloc_empty=True)
            ct = self._register(ClusterTable(
                ft, parts, part_rows, f"co[{spec.kind}]", co_spec=spec,
                keys=np.asarray(keys), k_replicas=k))
            self._refresh_aliases(ct)
            self._seed_replicas(cqp, ct)
            return ct
        if replicate:
            parts = self._alloc_parts(
                cqp, ft, [ft.n_rows] * self.n_nodes)
            all_rows = np.arange(ft.n_rows, dtype=np.int64)
            return self._register(ClusterTable(
                ft, parts, [all_rows] * self.n_nodes,
                "replicate", replicated=True))
        kind = partitioner or self.partitioner
        part_rows = partition_rows(ft.n_rows, self.n_nodes, kind, keys=keys)
        parts = self._alloc_parts(cqp, ft, [len(i) for i in part_rows])
        ct = self._register(ClusterTable(
            ft, parts, part_rows, kind,
            co_spec=co_partition_spec(kind, self.n_nodes, keys),
            keys=None if keys is None else np.asarray(keys), k_replicas=k))
        self._refresh_aliases(ct)
        self._seed_replicas(cqp, ct)
        return ct

    def _register(self, ctable: ClusterTable) -> ClusterTable:
        ctable.heat = TableHeat.zeros(self.n_nodes)
        if ctable.home is None:
            ctable.home = list(range(self.n_nodes))
        if ctable.replicas is None:
            ctable.replicas = [dict() for _ in range(self.n_nodes)]
        if ctable.part_version is None:
            ctable.part_version = [0] * len(ctable.parts)
        with self._lock:
            self.catalog[ctable.name] = ctable
        return ctable

    def _alloc_parts(self, cqp: ClusterQP, ft: FTable,
                     rows_per_node: list, *,
                     alloc_empty: bool = False,
                     homes: "list[int] | None" = None) -> list:
        """Allocate one partition per node (None for zero rows, unless
        `alloc_empty` — co-partitioned build shards register even when
        empty so probe-side joins resolve the name), rolling back the
        earlier nodes' allocations if a later pool is exhausted — a
        half-scattered table would leak pages with no handle to free.
        `homes` places partition i on node homes[i] (identity default —
        non-identity only after a failover moved primaries)."""
        parts: list = []
        try:
            for i, n in enumerate(rows_per_node):
                if n == 0 and not alloc_empty:
                    parts.append(None)
                    continue
                qp = cqp.qps[i if homes is None else homes[i]]
                part = FTable(ft.name, ft.columns, n_rows=n,
                              str_width=ft.str_width)
                fv.alloc_table_mem(qp, part)
                parts.append(part)
        except Exception:
            for i, part in enumerate(parts):
                if part is not None:
                    fv.free_table_mem(
                        cqp.qps[i if homes is None else homes[i]], part)
            raise
        return parts

    # ------------------------------------------------------- replica plumbing
    def _seed_replicas(self, cqp: ClusterQP, ctable: ClusterTable) -> None:
        """Create the k-1 extra copies at alloc time (empty until the
        first `table_write` fills every copy); frees the whole table if a
        pool can't hold its share — same all-or-nothing contract as
        `_alloc_parts`."""
        if ctable.k_replicas <= 1:
            return
        try:
            self._replicate(ctable)
        except Exception:
            self.free_table_mem(cqp, ctable)
            raise

    def _replicate(self, ctable: ClusterTable, *,
                   data: "np.ndarray | None" = None) -> list:
        """Create the MISSING replica copies, cyclic placement on alive
        nodes: partition i's next copy goes to the first alive node past
        i (mod N) not already holding one. The rule is shared with
        promotion in `heal`, so a probe and its co-partitioned build
        (same rule, same k) keep co-located copies through any sequence
        of failures. `data` (the full original-order row matrix) fills
        the new copies — None at alloc time, the survivors' bytes during
        a heal. Returns [(partition, node)] created."""
        made: list = []
        if ctable.replicated or ctable.k_replicas <= 1:
            return made
        n, sch = self.n_nodes, ctable.schema
        for i, part in enumerate(ctable.parts):
            if part is None:
                continue
            need = (ctable.k_replicas - 1) - len(ctable.replicas[i])
            holders = {ctable.home[i], *ctable.replicas[i]}
            for off in range(1, n):
                if need <= 0:
                    break
                j = (i + off) % n
                if j in holders or not self.health.is_alive(j):
                    continue
                rt = FTable(sch.name, sch.columns, n_rows=part.n_rows,
                            str_width=sch.str_width)
                node = self.nodes[j]
                node.pool.alloc_table(rt)
                node.tables[f"{ctable.name}@p{i}"] = rt
                ctable.replicas[i][j] = rt
                if data is not None and part.n_rows and not sch.str_width:
                    node.pool.write_table(
                        rt, data[np.asarray(ctable.part_rows[i])])
                    if ctable.heat is not None:
                        ctable.heat.record_replica_write(
                            j, part.n_rows * sch.row_words * WORD_BYTES)
                made.append((i, j))
                need -= 1
        return made

    def _drop_replicas(self, ctable: ClusterTable) -> None:
        """Free every extra copy (pages + catalog alias) — a migration is
        about to re-place the partitions, so the copies are stale."""
        for i, reps in enumerate(ctable.replicas):
            for j, handle in list(reps.items()):
                if self.health.is_alive(j):
                    self.nodes[j].pool.free_table(handle)
                    self.nodes[j].tables.pop(f"{ctable.name}@p{i}", None)
            reps.clear()

    def _rebuild_replicas(self, cqp: ClusterQP,
                          ctable: ClusterTable) -> None:
        """Restore the k-copy contract after a migration, filling the new
        copies from the (post-flip) primaries."""
        if ctable.replicated or ctable.k_replicas <= 1:
            return
        self._replicate(ctable, data=self._read_all(cqp, ctable))

    def _refresh_aliases(self, ctable: ClusterTable) -> None:
        """Re-point every node-catalog entry for this table.

        Contract: `"{name}@p{i}"` on a node resolves partition i's copy
        there (primary or replica); the PLAIN name on node n resolves
        node n's own partition n — that is what `_resolve_build` reads
        for a join dispatched on its home node, and what
        `_localize_pipeline` relies on when it rewrites an off-home
        dispatch to the alias."""
        name = ctable.name
        for node in self.nodes:
            for i in range(len(ctable.parts)):
                node.tables.pop(f"{name}@p{i}", None)
        for i, part in enumerate(ctable.parts):
            if part is not None:
                self.nodes[ctable.home[i]].tables[f"{name}@p{i}"] = part
            for j, handle in ctable.replicas[i].items():
                self.nodes[j].tables[f"{name}@p{i}"] = handle
        for n, node in enumerate(self.nodes):
            if ctable.home[n] == n and ctable.parts[n] is not None:
                node.tables[name] = ctable.parts[n]

    # ---------------------------------------------------------- read routing
    def _serving_candidates(self, ctable: ClusterTable,
                            i: int) -> list:
        """(node, handle) candidates for partition i: the primary first,
        then replicas in cyclic placement order — DETERMINISTIC, so every
        client (and the co-partitioned build's routing) picks the same
        survivor for the same dead set."""
        cands = [(ctable.home[i], ctable.parts[i])]
        n = self.n_nodes
        for j in sorted(ctable.replicas[i], key=lambda j: (j - i) % n):
            cands.append((j, ctable.replicas[i][j]))
        return cands

    def _route(self, ctable: ClusterTable, i: int) -> tuple:
        """First alive copy of partition i whose breaker admits traffic
        (a tripped breaker skips a flapping-but-not-dead node without
        spending a timeout on it), falling back to ANY alive copy when
        every breaker is open — availability beats caution once there is
        nowhere better to go. Loud typed error when every copy is dead."""
        cands = self._serving_candidates(ctable, i)
        alive = [(n, h) for n, h in cands if self.health.is_alive(n)]
        for node_id, handle in alive:
            if self.breaker.allow(node_id):
                return node_id, handle
        if alive:
            return alive[0]
        if len(cands) > 1:
            raise ReplicaUnavailableError(
                f"table {ctable.name!r}: every copy of partition {i} "
                f"(nodes {[c[0] for c in cands]}) is on a dead node")
        raise fv.NodeDeadError(cands[0][0], op="submit")

    def _next_candidate(self, ctable: ClusterTable, part_id: int,
                        tried: set) -> "tuple | None":
        """The next alive, untried copy for a mid-flight failover —
        breaker-admitted copies first, any alive copy as the fallback."""
        if ctable.replicated:
            cands = [(j, ctable.parts[j]) for j in range(self.n_nodes)]
        else:
            cands = self._serving_candidates(ctable, part_id)
        alive = [(n, h) for n, h in cands
                 if n not in tried and self.health.is_alive(n)]
        for node_id, handle in alive:
            if self.breaker.allow(node_id):
                return node_id, handle
        return alive[0] if alive else None

    def _localize_pipeline(self, ctable: ClusterTable, pipeline: tuple,
                           part_id: int, node_id: int) -> tuple:
        """Rewrite a join's build reference for an OFF-home dispatch.

        On node n the plain build name resolves node n's own partition n;
        partition `part_id` served anywhere else must resolve the build
        through its shard alias. The home-node path returns the pipeline
        object UNCHANGED, so healthy dispatch signatures — and the
        scheduler's cross-client coalescing — are untouched."""
        if node_id == part_id:
            return pipeline
        jop = op_ir.join_small_of(pipeline)
        if jop is None:
            return pipeline
        with self._lock:
            bct = self.catalog.get(jop.build_table)
        if bct is None or bct.replicated:
            return pipeline
        alias = f"{jop.build_table}@p{part_id}"
        return tuple(dc_replace(o, build_table=alias) if o is jop else o
                     for o in pipeline)

    def free_table_mem(self, cqp: ClusterQP, ctable: ClusterTable) -> None:
        """Free every copy (primaries and replicas). Copies stranded on a
        DEAD node are skipped with a warning — their pages died with the
        node; the cluster-side handles are dropped either way."""
        name = ctable.name
        if ctable.replicated:
            copies = [(j, part) for j, part in enumerate(ctable.parts)]
        else:
            copies = [(ctable.home[i], part)
                      for i, part in enumerate(ctable.parts)]
            copies += [(j, h) for reps in ctable.replicas
                       for j, h in reps.items()]
        for j, handle in copies:
            if handle is None:
                continue
            if not self.health.is_alive(j):
                warnings.warn(
                    f"free_table_mem: node {j} is dead; dropping a copy "
                    f"of {name!r} without freeing its pages", stacklevel=2)
                continue
            fv.free_table_mem(cqp.qps[j], handle)
        if not ctable.replicated:
            for node in self.nodes:
                for i in range(len(ctable.parts)):
                    node.tables.pop(f"{name}@p{i}", None)
        if self.cache is not None:
            self.cache.drop_table(name)
        with self._lock:
            if self.catalog.get(name) is ctable:
                del self.catalog[name]

    def table_write(self, cqp: ClusterQP, ctable: ClusterTable,
                    words: np.ndarray, *,
                    keys: np.ndarray | None = None) -> None:
        """Scatter the row matrix to the owning nodes (or all, if
        replicated). Rows land pre-split; nothing is written twice.

        `keys=` (one partition-key value per row) marks a REKEYING
        rewrite: rows are re-routed by the table's captured key->node
        rule so the co-location contract survives the new key column
        (equal keys still share a node; co-partitioned join builds placed
        by the same rule stay aligned — by construction, with no build
        migration). The routing rule itself is NOT recomputed: a key
        distribution the rule was never built for may now pile onto one
        node — which is exactly the skew drift `check_drift` observes and
        `rebalance` repairs."""
        words = np.asarray(words)
        if keys is not None:
            self._rekey(cqp, ctable, words, np.asarray(keys))
            return
        if ctable.replicated:
            landed = 0
            for j, (qp, part) in enumerate(zip(cqp.qps, ctable.parts)):
                if self._write_copy(cqp, j, part, words, ctable):
                    landed += 1
            if not landed:
                raise ReplicaUnavailableError(
                    f"replicated table {ctable.name!r}: every node is dead")
            ctable.bump_parts(range(len(ctable.parts)))
            return
        self._write_parts(cqp, ctable, words)

    def _write_copy(self, cqp: ClusterQP, node_id: int, handle,
                    data: np.ndarray, ctable: ClusterTable) -> bool:
        """Write one copy; a DEAD node (known, or discovered by the write
        itself) is skipped with a warning — its bytes died with it.
        `heal` rebuilds redundancy; `revive` + rewrite refreshes a
        resurrected node."""
        if self.health.is_alive(node_id):
            try:
                fv.table_write(cqp.qps[node_id], handle, data)
                return True
            except fv.NodeDeadError as e:
                self.health.record_failure(node_id, e)
        warnings.warn(
            f"table_write: node {node_id} is dead; its copy of "
            f"{ctable.name!r} is not updated", stacklevel=3)
        return False

    def _write_parts(self, cqp: ClusterQP, ctable: ClusterTable,
                     words: np.ndarray) -> None:
        """Scatter rows to EVERY alive copy of each partition. A write
        only fails when a partition has no alive copy at all — partial
        redundancy degrades loudly (warning) but keeps serving. Every
        written partition's epoch advances: cached copies of its old
        bytes are stale the moment the first copy lands."""
        row_bytes = ctable.schema.row_words * WORD_BYTES
        for i, (part, idx) in enumerate(zip(ctable.parts,
                                            ctable.part_rows)):
            if part is None or part.n_rows == 0:
                continue
            ctable.bump_parts((i,))
            idx = np.asarray(idx)
            data = words[idx]
            copies = [(ctable.home[i], part)]
            copies += sorted(ctable.replicas[i].items())
            landed = 0
            for node_id, handle in copies:
                if not self._write_copy(cqp, node_id, handle, data, ctable):
                    continue
                landed += 1
                if node_id != ctable.home[i] and ctable.heat is not None:
                    ctable.heat.record_replica_write(
                        node_id, len(idx) * row_bytes)
            if not landed:
                raise ReplicaUnavailableError(
                    f"table {ctable.name!r}: no alive copy of partition "
                    f"{i} to write")

    def _rekey(self, cqp: ClusterQP, ctable: ClusterTable,
               words: np.ndarray, keys: np.ndarray) -> None:
        """Key-routed rewrite: re-place every row by the CAPTURED rule."""
        if ctable.replicated:
            raise ValueError("a replicated table has no key routing")
        if ctable.co_spec is None:
            raise ValueError(
                f"table {ctable.name!r} is {ctable.partitioner}-partitioned "
                "with no key rule — keys= routing needs a hash/skew/"
                "co-partitioned table")
        if keys.shape[0] != ctable.n_rows:
            raise ValueError(
                f"write keys cover {keys.shape[0]} rows, "
                f"table has {ctable.n_rows}")
        owner = ctable.co_spec.owners_of(keys)
        idx = np.arange(ctable.n_rows, dtype=np.int64)
        target = [idx[owner == p] for p in range(self.n_nodes)]
        changed = any(
            len(t) != len(c) or not np.array_equal(t, np.asarray(c))
            for t, c in zip(target, ctable.part_rows))
        if changed:
            # the map moves: flip partitions to the new routing first
            # (same spec object — co-location contracts are untouched),
            # then write. Data travels once; old partitions' contents are
            # dead (the caller is overwriting every row) so they are
            # dropped, not copied — replicas included (recreated empty
            # below, filled by the write like any other copy).
            self._drop_replicas(ctable)
            self._retarget(cqp, ctable, target, ctable.co_spec,
                           copy_data=False)
            self._replicate(ctable)
            # heat describes load under the map it was observed on; a
            # flip starts the ledger over so the drift detector judges
            # the NEW placement on its own traffic
            ctable.heat.reset()
        ctable.keys = keys
        self._write_parts(cqp, ctable, words)

    def table_read(self, cqp: ClusterQP, ctable: ClusterTable) -> jnp.ndarray:
        """Plain gather-read: fetch every partition, restore original row
        order via the partition map (ships the whole table — no
        push-down). Fails over per partition: a dead primary's rows are
        read from the first alive replica, loudly erroring only when a
        partition has no surviving copy.

        With a cluster cache (`cache_bytes > 0`) each partition is
        consulted against its CURRENT epoch first — a hit is served from
        the client copy with no node traffic (no bytes billed, because no
        bytes moved), a miss fills the cache under the epoch captured
        BEFORE the read, so a racing flip can only produce a stale stamp
        that the next lookup rejects, never a wrong-bytes hit."""
        cache = self.cache
        if ctable.replicated:
            # one whole-table entry under partition index -1; every
            # copy's epoch moves together (replicated writes bump all)
            epoch = (ctable.part_version[0]
                     if ctable.part_version else 0)
            if cache is not None:
                rows = cache.get(ctable.name, -1, epoch)
                if rows is not None:
                    cqp.cache_hits += 1
                    return jnp.asarray(rows)
                cqp.cache_misses += 1
            last: Exception | None = None
            for j in range(self.n_nodes):
                if not self.health.is_alive(j):
                    continue
                try:
                    res = fv.table_read(cqp.qps[j], ctable.parts[j])
                    if cache is not None:
                        cache.put(ctable.name, -1, epoch, np.asarray(res))
                    return res
                except fv.NodeDeadError as e:
                    self.health.record_failure(j, e)
                    last = e
            raise ReplicaUnavailableError(
                f"replicated table {ctable.name!r}: every node is dead"
            ) from last
        out = np.zeros((ctable.n_rows, ctable.schema.row_words), np.float32)
        for i, (part, idx) in enumerate(zip(ctable.parts,
                                            ctable.part_rows)):
            if part is None or part.n_rows == 0:
                continue
            idx = np.asarray(idx)
            epoch = (ctable.part_version[i]
                     if ctable.part_version else 0)
            if cache is not None:
                rows = cache.get(ctable.name, i, epoch)
                if rows is not None:
                    out[idx] = rows
                    cqp.cache_hits += 1
                    continue
                cqp.cache_misses += 1
            served, last = False, None
            for node_id, handle in self._serving_candidates(ctable, i):
                if not self.health.is_alive(node_id):
                    continue
                try:
                    rows = np.asarray(
                        fv.table_read(cqp.qps[node_id], handle))
                    out[idx] = rows
                    if cache is not None:
                        cache.put(ctable.name, i, epoch, rows)
                    served = True
                    break
                except fv.NodeDeadError as e:
                    self.health.record_failure(node_id, e)
                    last = e
            if not served:
                if ctable.k_replicas > 1:
                    raise ReplicaUnavailableError(
                        f"table {ctable.name!r}: every copy of partition "
                        f"{i} is on a dead node") from last
                raise last or fv.NodeDeadError(ctable.home[i],
                                               op="table_read")
        return jnp.asarray(out)

    # -------------------------------------------------------------- dispatch
    def submit_request(self, cqp: ClusterQP, ctable: ClusterTable,
                       pipeline: tuple, *,
                       lengths: np.ndarray | None = None,
                       strings: np.ndarray | None = None,
                       deadline_s: float | None = None) -> ClusterPending:
        """Scatter one Farview verb: queue a partition request on every
        owning node. Each node's bucket-batched scheduler coalesces the
        partition with whatever else is queued there — K cluster clients
        running the same pipeline still cost each node ONE stacked
        dispatch per round.

        `deadline_s` is the end-to-end budget for the WHOLE query: every
        scatter leg carries the remainder at its own dispatch time (over
        the wire as `deadline_ms`, re-anchored on the server's clock),
        and retries / failovers / hedges spend what is left rather than
        a fresh timeout. A spent budget fails typed
        (`DeadlineExceededError`) — never a half-run query."""
        pipeline = op_ir.validate_pipeline(tuple(pipeline))
        strings = None if strings is None else np.asarray(strings)
        lengths = None if lengths is None else np.asarray(lengths)
        self._check_join_locality(ctable, pipeline)
        deadline_at = None
        sub_kw = {}
        if deadline_s is not None:
            if deadline_s <= 0:
                raise fv.DeadlineExceededError(
                    None, op="submit", detail="budget spent before scatter")
            deadline_at = time.monotonic() + float(deadline_s)
            sub_kw["deadline_s"] = float(deadline_s)
        if ctable.replicated:
            # a replicated table has no partitions to scatter over: serve
            # whole from the first ALIVE copy (node 0 in a healthy
            # cluster) exactly like a solo dispatch
            serve = next((j for j in range(self.n_nodes)
                          if self.health.is_alive(j)), None)
            if serve is None:
                raise ReplicaUnavailableError(
                    f"replicated table {ctable.name!r}: every node is dead")
            pend = self.nodes[serve].submit(
                cqp.qps[serve], ctable.parts[serve], pipeline,
                lengths=lengths, strings=strings, **sub_kw)
            cqp.requests += 1
            return ClusterPending(self, ctable, pipeline, [pend],
                                  [ctable.part_rows[serve]], [serve],
                                  cqp=cqp, part_ids=[serve],
                                  handles=[ctable.parts[serve]],
                                  strings=strings, lengths=lengths,
                                  deadline_at=deadline_at)
        pends, prows, pnodes, pparts, phandles = [], [], [], [], []
        for i, (part, idx) in enumerate(zip(ctable.parts,
                                            ctable.part_rows)):
            if part is None or part.n_rows == 0:
                continue
            idx = np.asarray(idx)
            # route around known-DEAD nodes up front; mid-flight failures
            # re-route in ClusterPending.wait
            serve, handle = self._route(ctable, i)
            kwargs = {}
            if strings is not None:
                kwargs["strings"] = strings[idx]
                kwargs["lengths"] = lengths[idx]
            lp = self._localize_pipeline(ctable, pipeline, i, serve)
            pends.append(self.nodes[serve].submit(
                cqp.qps[serve], handle, lp,
                row_ids=idx.astype(np.int32), **kwargs, **sub_kw))
            prows.append(idx)
            pnodes.append(serve)
            pparts.append(i)
            phandles.append(handle)
            # scatter-side heat: the partition sizes ARE the per-node work
            # of this verb and are already client-side metadata — one
            # integer add per owning node, no device sync
            ctable.heat.record_dispatch(serve, len(idx))
            if serve != ctable.home[i]:
                ctable.heat.record_failover(serve, len(idx))
        cqp.requests += 1
        ctable.heat.record_request()
        return ClusterPending(self, ctable, pipeline, pends, prows, pnodes,
                              cqp=cqp, part_ids=pparts, handles=phandles,
                              strings=strings, lengths=lengths,
                              deadline_at=deadline_at)

    def _check_join_locality(self, ctable: ClusterTable,
                             pipeline: tuple) -> None:
        """A probe may only dispatch a join when every serving node can
        answer it from its OWN pool: a replicated build copy (broadcast
        join) or — for a partitioned probe — a shard co-partitioned with
        THIS probe (same captured CoPartition object; structural equality
        of two hash rules says nothing about which columns they hashed).
        Any other layout would silently drop matches whose build row lives
        on a different node — refuse loudly instead. A replicated probe is
        served whole from node 0, so only a replicated build (node 0 holds
        a full copy) is local there."""
        jop = op_ir.join_small_of(pipeline)
        if jop is None:
            return
        with self._lock:
            bct = self.catalog.get(jop.build_table)
        if bct is None:     # not cluster-allocated; nodes resolve (or raise)
            return
        if bct.replicated:
            return
        if (not ctable.replicated and bct.co_spec is not None
                and bct.co_spec.compatible_with(ctable.co_spec)):
            return          # build placed BY this probe's key rule
        raise fv.FarviewError(
            f"build table {jop.build_table!r} is partitioned but not "
            f"co-partitioned with probe {ctable.name!r}: allocate it with "
            "replicate=True (broadcast join) or "
            "co_partition=<probe table> (single-copy local join)")

    def flush(self) -> None:
        """Drain every node's scheduler — concurrently when `parallel`
        (nodes are independent machines; here, independent executables
        whose dispatch threads overlap). Per-node dispatch errors stay
        attached to their own requests; each is captured WITH its node's
        identity (`err.fv_node_id`, plus an exception note where the
        runtime supports one) instead of dying opaquely inside a worker
        thread, and the first re-raises after all nodes drain. Every
        drain doubles as a health heartbeat: a clean drain records its
        latency (slow = SUSPECT strike), an infrastructure failure
        (`NodeDeadError` / `DroppedDispatchError`) feeds the lifecycle
        state machine — request-level errors (a bad pipeline, a closed
        connection) say nothing about node health and are not strikes."""
        pending = [node for node in self.nodes if node.has_queued]
        if not pending:
            return
        errors: list = [None] * len(pending)
        drain_s: list = [0.0] * len(pending)

        def drain(i: int, node) -> None:
            t0 = time.perf_counter()
            try:
                self._drain_node(node.node_id)
            except Exception as e:          # noqa: BLE001 - re-raised below
                errors[i] = e
            finally:
                drain_s[i] = time.perf_counter() - t0

        if self.parallel and len(pending) > 1:
            threads = [threading.Thread(target=drain, args=(i, node))
                       for i, node in enumerate(pending)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
        else:
            for i, node in enumerate(pending):
                drain(i, node)
        first: Exception | None = None
        for node, err, dt in zip(pending, errors, drain_s):
            if err is None:
                self.health.heartbeat(node.node_id, dt)
                continue
            if isinstance(err, (fv.NodeDeadError, DroppedDispatchError)):
                self.health.record_failure(node.node_id, err)
            if getattr(err, "fv_node_id", None) is None:
                try:
                    err.fv_node_id = node.node_id
                    if hasattr(err, "add_note"):    # Python >= 3.11
                        err.add_note(
                            f"raised draining cluster node {node.node_id}")
                except Exception:       # noqa: BLE001 - slotted exceptions
                    pass
            if first is None:
                first = err
        if first is not None:
            raise first

    def _drain_node(self, node_id: int) -> None:
        """Flush ONE node under its drain lock — hedges, failover
        re-drains and whole-cluster flushes serialize per node."""
        with self._node_locks[node_id]:
            self.nodes[node_id].flush()

    def _flush_with_hedging(self, pending: "ClusterPending") -> None:
        """Drain the cluster for one verb, hedging its slow legs.

        The full drain runs in a background thread; every `hedge_after_s`
        (default: the health monitor's `slow_after_s` threshold) the
        still-unresolved entries of `pending` are duplicated onto their
        cyclic replicas (`ClusterPending._launch_hedges`). The moment
        every entry has an answer — its own or a finished hedge's — this
        returns and the merge proceeds; the straggler's drain keeps
        running in the background (its per-node lock serializes it
        against later flushes) and its eventual answer is discarded."""
        hedge_s = (self.health.slow_after_s if self.hedge_after_s is None
                   else self.hedge_after_s)
        if not hedge_s or hedge_s <= 0 or pending.cqp is None:
            self.flush()
            return
        box: list = [None]
        done = threading.Event()

        def drain_all() -> None:
            try:
                self.flush()
            except Exception as e:          # noqa: BLE001 - re-raised below
                box[0] = e
            finally:
                done.set()

        t = threading.Thread(target=drain_all, daemon=True,
                             name="farview-hedged-flush")
        t.start()
        t0 = time.monotonic()
        struck: set = set()
        while not done.wait(hedge_s):
            launched = pending._launch_hedges()
            if time.monotonic() - t0 >= self.health.slow_after_s:
                # the monitor's own slow threshold has passed mid-flight:
                # strike the still-unanswered primaries NOW (a hedged
                # verb may return before their drains ever report in)
                for k, p in enumerate(pending.pends):
                    nid = pending.node_ids[k]
                    if (p.result is None and p.error is None
                            and nid not in struck):
                        struck.add(nid)
                        self.health.record_failure(nid, fv.FarviewError(
                            f"node {nid}: drain exceeded the "
                            f"{self.health.slow_after_s:.2f}s slow "
                            "threshold mid-flight (hedged)"))
            if pending._all_resolved():
                return      # hedges answered; abandon the slow drain
            if not launched and not pending._hedges:
                # nothing hedgeable (no replicas / no budget): the slow
                # drain is the only path — wait it out
                done.wait()
                break
        t.join()
        if box[0] is not None:
            raise box[0]

    def settle(self) -> None:
        """Flush + finalize in-flight responses on every node."""
        try:
            self.flush()
        except Exception:
            pass                    # errors stay on their PendingRequests
        for node in self.nodes:
            node.settle()

    def farview_request(self, cqp: ClusterQP, ctable: ClusterTable,
                        pipeline: tuple, *,
                        lengths: np.ndarray | None = None,
                        strings: np.ndarray | None = None,
                        deadline_s: float | None = None) -> PipelineResult:
        """The scatter-gather Farview verb: partition dispatch on every
        owning node, client-side merge byte-identical to a single node.
        `deadline_s` bounds the WHOLE query end to end (see
        `submit_request`)."""
        pend = self.submit_request(cqp, ctable, pipeline, lengths=lengths,
                                   strings=strings, deadline_s=deadline_s)
        return pend.wait()

    # ------------------------------------------------------------ rebalancing
    def check_drift(self, *, threshold: float = 1.5) -> dict:
        """Run the skew-drift detector over the catalog.

        Returns a `DriftReport` per non-replicated table: the observed
        per-node load (heat counters when the table has traffic, the
        partition sizes otherwise) against the best share a re-placement
        over the table's current keys could achieve — an inherently
        skewed but LPT-optimal table reads ~1.0 and stays put. Pure
        client-side metadata — no node traffic, no syncs (the achievable
        share costs one LPT pass over each key-partitioned table's
        keys)."""
        with self._lock:    # snapshot; the LPT pass runs lock-free below
            tables = [(name, t) for name, t in self.catalog.items()
                      if not t.replicated]
        return {name: detect_drift(name, t.heat, t.part_sizes,
                                   keys=t.keys, threshold=threshold)
                for name, t in tables}

    def _dependents(self, ctable: ClusterTable) -> list:
        """Tables co-partitioned BY this table's rule (join builds placed
        with `co_partition=ctable`): they share the very spec object, and
        they must move whenever the rule is re-captured."""
        if ctable.co_spec is None:
            return []
        with self._lock:
            return [t for t in self.catalog.values()
                    if t is not ctable and t.co_spec is ctable.co_spec]

    def plan_table_rebalance(self, ctable: ClusterTable, *,
                             keys: np.ndarray | None = None,
                             max_step_bytes: int | None = None
                             ) -> MigrationPlan:
        """Plan (but do not execute) a rebalance — see `rebalance`."""
        if ctable.replicated:
            raise ValueError(
                f"table {ctable.name!r} is replicated; every node already "
                "holds a full copy — nothing to rebalance")
        if ctable.partitioner.startswith("co["):
            raise fv.FarviewError(
                f"table {ctable.name!r} is co-partitioned with a probe; "
                "rebalance the probe table — its plan re-places this build "
                "by the same re-captured rule")
        keys = ctable.keys if keys is None else np.asarray(keys)
        deps = self._dependents(ctable)
        return plan_rebalance(
            ctable.name, ctable.part_rows, ctable.n_rows,
            ctable.schema.row_words * WORD_BYTES, n_nodes=self.n_nodes,
            keys=keys, max_step_bytes=max_step_bytes,
            co_tables=tuple(t.name for t in deps))

    def rebalance(self, cqp: ClusterQP, ctable: ClusterTable, *,
                  keys: np.ndarray | None = None,
                  max_step_bytes: int | None = None) -> MigrationPlan:
        """Live skew-drift repair: migrate a table to a freshly-captured
        placement while serving traffic.

        The target comes from `distributed.rebalance.plan_rebalance`: the
        skew-aware LPT placement re-run over the table's CURRENT keys
        (`keys=` overrides the stored column) when it is key-partitioned,
        minimal-move row-count balancing otherwise. Execution copies the
        moving rows node-to-node through the pool read path (`table_read_
        rows` — the traffic bills like any other transfer), flips the
        versioned partition map, and only then frees the source pages;
        verbs in flight at the flip were scattered under the old map and
        still splice byte-identically (`ClusterPending` captures its map).
        Join builds co-partitioned with this table are re-placed by the
        re-captured rule in the SAME plan — atomically with the probe, so
        a local join never sees a probe row whose build row has not moved
        yet. `max_step_bytes` bounds the rows moved per map flip for
        standalone tables (co-groups always flip whole: a bounded interim
        map would break build-probe locality mid-plan). Heat counters
        reset after the flip so the detector sees post-migration traffic.
        """
        dead = self.health.dead_nodes()
        if dead:
            raise fv.FarviewError(
                f"cluster has dead nodes {dead}: run heal() (and revive or "
                "replace the nodes) before rebalancing — the balancer "
                "places over every node slot")
        plan = self.plan_table_rebalance(ctable, keys=keys,
                                         max_step_bytes=max_step_bytes)
        deps = self._dependents(ctable)
        if plan.empty and plan.new_spec is None:
            return plan
        # migration re-places the partitions wholesale: the extra copies
        # are stale the moment rows move, so drop them first and rebuild
        # (from the post-flip primaries) on the way out — whatever map the
        # migration ends on, even a failed one's interim map
        group = [ctable] + deps
        for t in group:
            self._drop_replicas(t)
        try:
            self._rebalance_moves(cqp, ctable, plan, deps, keys)
        finally:
            for t in group:
                self._rebuild_replicas(cqp, t)
        return plan

    def _rebalance_moves(self, cqp: ClusterQP, ctable: ClusterTable,
                         plan: MigrationPlan, deps: list,
                         keys: "np.ndarray | None") -> None:
        if deps:
            self._flip_group(cqp, ctable, plan, deps)
        elif plan.new_spec is not None:
            # stepping is safe without dependents, but the stale rule must
            # not be captured by a co_partition= alloc mid-plan: a build
            # placed by it would chase rows that already moved. Blank it;
            # co_partition= falls back to replicate (safe) until the new
            # rule lands. If a step fails, the table keeps serving
            # byte-identically from the interim map with NO key rule (the
            # truthful state: a half-moved map follows neither rule —
            # keys= rewrites are refused and co_partition= replicates);
            # a later rebalance() re-plans from the stored keys and
            # completes the migration.
            old_spec, done = ctable.co_spec, 0
            ctable.co_spec = None
            try:
                for step in plan.steps:
                    self._apply_step(cqp, ctable, step)
                    done += 1
            except Exception:
                if done == 0:
                    ctable.co_spec = old_spec   # nothing moved: still exact
                ctable.heat.reset()     # observations predate the interim map
                raise
            ctable.co_spec = plan.new_spec
            ctable.partitioner = plan.new_spec.kind
        else:
            try:
                for step in plan.steps:
                    self._apply_step(cqp, ctable, step)
            except Exception:
                ctable.heat.reset()
                raise
        if keys is not None:
            ctable.keys = np.asarray(keys)
        ctable.heat.reset()
        for t in deps:
            t.heat.reset()

    def auto_rebalance(self, cqp: ClusterQP, *, threshold: float = 1.5,
                       max_step_bytes: int | None = None) -> dict:
        """Detector-driven sweep: rebalance every catalog table whose
        observed load imbalance exceeds `threshold`. Co-partitioned
        builds are carried by their probe's plan, never rebalanced alone.
        Returns {table name: executed MigrationPlan}."""
        out = {}
        for name, report in self.check_drift(threshold=threshold).items():
            with self._lock:
                ctable = self.catalog.get(name)
            if (ctable is None or not report.drifted
                    or ctable.partitioner.startswith("co[")):
                continue
            out[name] = self.rebalance(cqp, ctable,
                                       max_step_bytes=max_step_bytes)
        return out

    # ---------------------------------------------------------- memory tiering
    def demote_cold(self, max_heat_rows: int = 0, *,
                    tables: "list[str] | None" = None) -> dict:
        """Heat-driven tier sweep: demote every table copy sitting on a
        node whose heat ledger shows at most `max_heat_rows` rows touched
        since the last reset — the cluster-level trigger for the pool's
        hot/cold page tiering (pool.demote_table). Replicas demote with
        their primaries: a cold partition is cold on every node holding
        a copy. Settles first — in-flight dispatches hold raw page
        extents that demotion is about to free. Remote node handles (no
        in-process pool) and dead nodes are skipped; re-promotion is the
        pool's job, on access, with hysteresis. Returns
        {table: [(partition, pages_demoted), ...]} for what moved."""
        with self._lock:
            cts = [t for t in self.catalog.values()
                   if tables is None or t.name in tables]
        if not cts:
            return {}
        self.settle()
        report: dict = {}
        for t in cts:
            rows = (t.heat.rows_snapshot() if t.heat is not None
                    else np.zeros(self.n_nodes, np.int64))
            demoted = []
            for i, part in enumerate(t.parts):
                if part is None or part.n_rows == 0:
                    continue
                if t.replicated:
                    copies = [(i, part)]
                    node_heat = rows[i]
                else:
                    copies = ([(t.home[i], part)]
                              + sorted(t.replicas[i].items()))
                    node_heat = rows[t.home[i]]
                if node_heat > max_heat_rows:
                    continue
                n = 0
                for node_id, handle in copies:
                    pool = getattr(self.nodes[node_id], "pool", None)
                    if pool is None or not self.health.is_alive(node_id):
                        continue
                    n += pool.demote_table(handle)
                if n:
                    demoted.append((i, n))
            if demoted:
                report[t.name] = demoted
        return report

    def tier_summary(self) -> dict:
        """Aggregate capacity accounting over every in-process pool."""
        sums = [node.pool.tier_summary() for node in self.nodes
                if getattr(node, "pool", None) is not None]
        out: dict = {}
        for s in sums:
            for k, v in s.items():
                if k != "effective_capacity":   # a ratio — recomputed below
                    out[k] = out.get(k, 0) + v
        out["effective_capacity"] = (
            out["logical_bytes"] / out["physical_bytes"]
            if out.get("physical_bytes") else 0.0)
        return out

    # ------------------------------------------------------------ self-healing
    def _cyclic_alive(self, i: int) -> int:
        """First alive node in cyclic order from i — the deterministic
        placement rule shared by replication, promotion, and restore."""
        for off in range(self.n_nodes):
            j = (i + off) % self.n_nodes
            if self.health.is_alive(j):
                return j
        raise ReplicaUnavailableError("every node in the cluster is dead")

    def heal(self, cqp: ClusterQP, *, manager=None,
             step: int | None = None) -> dict:
        """Self-healing rebuild after node death: make every catalog
        table fully served and fully redundant again, using only the
        survivors.

        Per table: (1) drop handles stranded on DEAD nodes; (2) promote
        a replica for every dead primary — the first alive copy in
        cyclic placement order, the same deterministic rule the replicas
        were placed by, so a probe's partition i and its co-partitioned
        build's partition i promote onto the SAME node and local joins
        stay local; (3) re-replicate back to the k-copy contract,
        copying bytes from the (post-promotion) primaries through the
        pool read path; then flip the versioned map once — verbs in
        flight splice under the map they were scattered with, exactly
        like a rebalance flip. A partition whose every copy died is
        re-materialized from the latest cold-storage snapshot when a
        `CheckpointManager` is passed (`manager=`, optional `step=`),
        and raises `ReplicaUnavailableError` otherwise — loud beats
        silently serving holes. Idempotent; a no-op on a healthy
        cluster. Returns a report dict (dead_nodes / promoted /
        re_replicated / restored / under_replicated)."""
        self.settle()
        dead = set(self.health.dead_nodes())
        report: dict = {"dead_nodes": sorted(dead), "promoted": [],
                        "re_replicated": [], "restored": [],
                        "under_replicated": []}
        if not dead:
            return report
        with self._lock:
            healing = list(self.catalog.items())
        for name, t in healing:
            if t.replicated:
                continue    # any alive node serves the full copy as-is
            changed = False
            for i in range(len(t.parts)):
                for j in [j for j in t.replicas[i] if j in dead]:
                    del t.replicas[i][j]    # pages died with the node
                    changed = True
            lost: list = []
            touched: list = []      # partitions whose serving copy moved
            for i, part in enumerate(t.parts):
                if t.home[i] not in dead:
                    continue
                if part is None:            # no rows: nothing to lose,
                    t.home[i] = self._cyclic_alive(i)   # re-home for later
                    changed = True          # allocs (rekey/migration)
                    continue
                cands = sorted(t.replicas[i],
                               key=lambda j: (j - i) % self.n_nodes)
                if cands:
                    j = cands[0]
                    t.parts[i] = t.replicas[i].pop(j)
                    t.home[i] = j
                    report["promoted"].append((name, i, j))
                    touched.append(i)
                    changed = True
                else:
                    lost.append(i)
            if lost:
                if manager is None:
                    raise ReplicaUnavailableError(
                        f"table {name!r}: partitions {lost} lost every "
                        f"copy to dead nodes {sorted(dead)} and no "
                        "snapshot manager was given — allocate with "
                        "replicas>=2 or pass manager= to restore from "
                        "cold storage")
                self.restore_table(cqp, t, manager, step=step,
                                   partitions=lost)
                report["restored"].append((name, tuple(lost)))
                changed = True
            if t.k_replicas > 1:
                made = self._replicate(t, data=self._read_all(cqp, t))
                if made:
                    report["re_replicated"].append((name, made))
                    changed = True
                short = [i for i, part in enumerate(t.parts)
                         if part is not None
                         and len(t.replicas[i]) < t.k_replicas - 1]
                if short:
                    report["under_replicated"].append((name, short))
                    warnings.warn(
                        f"heal: table {name!r} partitions {short} are "
                        f"below {t.k_replicas} copies — not enough alive "
                        "nodes", stacklevel=2)
            if changed:
                # restore_table already bumped the partitions it rebuilt;
                # here the promotions flip their own epochs too
                t.bump(touched)
                self._refresh_aliases(t)
                t.heat.reset()
        return report

    def snapshot(self, cqp: ClusterQP, manager,
                 *, step: int | None = None) -> int:
        """Consistent point-in-time snapshot of every catalog table to
        simulated cold storage (a `checkpoint.CheckpointManager`).

        Settles the cluster first so the captured bytes reflect every
        acknowledged write, then gathers each table through the
        failover-aware read path (a dead primary does not block the
        snapshot while a replica survives) and saves one atomic step
        directory. The snapshot is the LAST-RESORT recovery tier:
        `heal(manager=...)` / `restore_table` re-materialize partitions
        whose every live copy died. Returns the step written."""
        self.settle()
        if step is None:
            last = manager.latest_step()
            step = 0 if last is None else last + 1
        tree: dict = {}
        tables_meta: dict = {}
        with self._lock:    # point-in-time view; reads go via table_read
            snap_tables = list(self.catalog.items())
        for name, t in snap_tables:
            entry: dict = {}
            if t.schema.str_width or t.n_rows == 0:
                # string shells carry their bytes per-request; the pool
                # holds no state worth shipping — snapshot the shape only
                entry["words"] = np.zeros(
                    (t.n_rows, t.schema.row_words), np.float32)
            else:
                entry["words"] = np.asarray(self.table_read(cqp, t))
            if t.keys is not None:
                entry["keys"] = np.asarray(t.keys)
            tree[name] = entry
            tables_meta[name] = {
                "n_rows": int(t.n_rows), "partitioner": t.partitioner,
                "replicated": bool(t.replicated),
                "k_replicas": int(t.k_replicas),
                "version": int(t.version),
                "str_width": int(t.schema.str_width)}
        manager.save(step, tree, {"kind": "farcluster",
                                  "tables": tables_meta})
        return step

    def restore_table(self, cqp: ClusterQP, ctable: ClusterTable,
                      manager, *, step: int | None = None,
                      partitions: "list[int] | None" = None) -> list:
        """Re-materialize lost partitions from a cold-storage snapshot.

        `partitions` names the partition indices to rebuild (default:
        every partition whose home node is DEAD). Each is re-allocated
        on the first alive node in cyclic order, rewritten from the
        snapshot's original-order row matrix, and flipped into the
        versioned map. The bytes are as-of the snapshot — cold-storage
        recovery trades recency for survival, which is why it is the
        tier BELOW replica promotion. Returns the partitions rebuilt."""
        tree, _meta = manager.restore(step)
        if tree is None or ctable.name not in tree:
            raise ReplicaUnavailableError(
                f"no snapshot of table {ctable.name!r} under "
                f"{manager.dir!r}")
        words = np.asarray(tree[ctable.name]["words"], np.float32)
        if words.shape[0] != ctable.n_rows:
            raise fv.FarviewError(
                f"snapshot of {ctable.name!r} covers {words.shape[0]} "
                f"rows; the table has {ctable.n_rows}")
        if partitions is None:
            partitions = [i for i in range(len(ctable.parts))
                          if not self.health.is_alive(ctable.home[i])]
        sch = ctable.schema
        restored: list = []
        for i in partitions:
            idx = np.asarray(ctable.part_rows[i])
            if len(idx) == 0 and ctable.parts[i] is None:
                continue
            j = self._cyclic_alive(i)
            rt = FTable(sch.name, sch.columns, n_rows=len(idx),
                        str_width=sch.str_width)
            fv.alloc_table_mem(cqp.qps[j], rt)
            if len(idx) and not sch.str_width:
                fv.table_write(cqp.qps[j], rt, words[idx])
            ctable.parts[i] = rt
            ctable.home[i] = j
            restored.append(i)
        if restored:
            ctable.bump(restored)
            self._refresh_aliases(ctable)
        return restored

    def _read_all(self, cqp: ClusterQP, ctable: ClusterTable):
        """Full original-order row matrix via the pool read path, or None
        when there is nothing to copy (string shells carry their bytes
        per-request; zero-row tables have no data)."""
        if ctable.schema.str_width or ctable.n_rows == 0:
            return None
        return np.asarray(self.table_read(cqp, ctable))

    def _flip_group(self, cqp: ClusterQP, ctable: ClusterTable,
                    plan: MigrationPlan, deps: list) -> None:
        """Atomic migration of a probe + its co-partitioned builds: one
        settle, one flip, so build-probe locality holds at every dispatch
        boundary. Work is per-NODE minimal: only partitions whose target
        index array differs are read, reallocated and rewritten — an
        unchanged node keeps its pages and never sees traffic (a fully
        unchanged table is a pure spec-object swap). Rolls back cleanly
        (old map untouched) if an affected node's pool cannot hold the
        transient old+new copies."""
        new_spec = plan.new_spec
        jobs = []           # (table, target, changed-node mask)
        for t, target in [(ctable, plan.target_part_rows)] + [
                (dep, partition_rows(dep.n_rows, self.n_nodes,
                                     keys=dep.keys, co_partition=new_spec))
                for dep in deps]:
            changed = [not np.array_equal(np.asarray(a), np.asarray(b))
                       for a, b in zip(target, t.part_rows)]
            if any(changed):
                jobs.append((t, target, changed))
            else:
                # placement already matches the re-captured rule: adopt
                # the new spec object (identity is what locality checks
                # compare) without touching a single page
                t.co_spec = new_spec
                t.partitioner = (new_spec.kind if t is ctable
                                 else f"co[{new_spec.kind}]")
        if not jobs:
            return
        # drain in-flight dispatches first: they reference the old
        # partitions' pages and resolve builds by name at dispatch time
        self.settle()
        datas = [self._read_nodes(cqp, t, changed)
                 for t, _, changed in jobs]
        news: list = []
        try:
            for t, target, changed in jobs:
                news.append(self._alloc_parts_masked(
                    cqp, t, [len(i) for i in target], changed,
                    alloc_empty=t.partitioner.startswith("co[")))
        except Exception:
            for (t, _, changed), parts in zip(jobs, news):
                for i, (part, ch) in enumerate(zip(parts, changed)):
                    if ch and part is not None:
                        fv.free_table_mem(cqp.qps[t.home[i]], part)
            self._restore_node_catalogs(jobs)
            raise
        for (t, target, changed), words, parts in zip(jobs, datas, news):
            if words is None:
                continue
            for i, (part, idx, ch) in enumerate(zip(parts, target, changed)):
                if ch and part is not None and part.n_rows:
                    fv.table_write(cqp.qps[t.home[i]], part,
                                   words[np.asarray(idx)])
        for (t, target, changed), parts in zip(jobs, news):
            old = t.parts
            t.parts = parts
            t.part_rows = [np.asarray(i) for i in target]
            t.bump([i for i, ch in enumerate(changed) if ch])
            t.co_spec = new_spec
            t.partitioner = (new_spec.kind if t is ctable
                             else f"co[{new_spec.kind}]")
            for i, (part, ch) in enumerate(zip(old, changed)):
                if ch and part is not None:
                    fv.free_table_mem(cqp.qps[t.home[i]], part)
            self._refresh_aliases(t)

    def _read_nodes(self, cqp: ClusterQP, ctable: ClusterTable, changed):
        """Row matrix holding the CHANGED partitions' rows at their
        original positions (unchanged nodes' rows are neither read nor
        needed — they stay where they are). None for string shells and
        empty tables."""
        if ctable.schema.str_width or ctable.n_rows == 0:
            return None
        out = np.zeros((ctable.n_rows, ctable.schema.row_words), np.float32)
        for i, (part, idx, ch) in enumerate(zip(ctable.parts,
                                                ctable.part_rows, changed)):
            if ch and part is not None and part.n_rows:
                out[np.asarray(idx)] = np.asarray(
                    fv.table_read(cqp.qps[ctable.home[i]], part))
        return out

    def _alloc_parts_masked(self, cqp: ClusterQP, ctable: ClusterTable,
                            rows_per_node: list, changed, *,
                            alloc_empty: bool) -> list:
        """Like `_alloc_parts`, but nodes whose placement is unchanged
        keep their existing partition object (no realloc, no traffic);
        rolls back this call's own allocations on failure."""
        sch = ctable.schema
        parts: list = []
        try:
            for i, (cur, n, ch) in enumerate(zip(ctable.parts,
                                                 rows_per_node, changed)):
                if not ch:
                    parts.append(cur)       # carried forward untouched
                    continue
                if n == 0 and not alloc_empty:
                    parts.append(None)
                    continue
                part = FTable(sch.name, sch.columns, n_rows=n,
                              str_width=sch.str_width)
                fv.alloc_table_mem(cqp.qps[ctable.home[i]], part)
                parts.append(part)
        except Exception:
            for i, (part, ch) in enumerate(zip(parts, changed)):
                if ch and part is not None:
                    fv.free_table_mem(cqp.qps[ctable.home[i]], part)
            raise
        return parts

    def _restore_node_catalogs(self, jobs) -> None:
        """Rollback helper: a failed migration alloc may have overwritten
        a node's name catalog with since-freed shards; re-point the
        entries (plain names AND shard aliases) at the still-serving old
        partitions so join build resolution cannot touch freed pages."""
        for t, *_ in jobs:
            self._refresh_aliases(t)

    def _retarget(self, cqp: ClusterQP, ctable: ClusterTable,
                  target_part_rows: list, spec, *,
                  copy_data: bool = True) -> None:
        """Whole-table re-placement under an unchanged key rule (the
        rekeying write path): settle, realloc to the target sizes,
        optionally copy the old contents, flip, free."""
        self.settle()
        words = self._read_all(cqp, ctable) if copy_data else None
        try:
            parts = self._alloc_parts(
                cqp, ctable.schema, [len(i) for i in target_part_rows],
                alloc_empty=ctable.partitioner.startswith("co["),
                homes=ctable.home)
        except Exception:
            self._restore_node_catalogs([(ctable, None)])
            raise
        if words is not None:
            for i, (part, idx) in enumerate(zip(parts, target_part_rows)):
                if part is not None and part.n_rows:
                    fv.table_write(cqp.qps[ctable.home[i]], part,
                                   words[np.asarray(idx)])
        old = ctable.parts
        ctable.parts = parts
        ctable.part_rows = [np.asarray(i) for i in target_part_rows]
        ctable.bump()
        ctable.co_spec = spec
        for i, part in enumerate(old):
            if part is not None:
                fv.free_table_mem(cqp.qps[ctable.home[i]], part)
        self._refresh_aliases(ctable)

    def _apply_step(self, cqp: ClusterQP, ctable: ClusterTable,
                    step) -> None:
        """Execute one bounded migration step: copy `step.row_ids` from
        node `src` to node `dst` via the pool read path, rebuild the two
        affected partitions, flip the versioned map, free the old pages.
        Results stay byte-identical at every step boundary — the map
        always covers every row exactly once."""
        src, dst = step.src, step.dst
        src_qp = cqp.qps[ctable.home[src]]
        dst_qp = cqp.qps[ctable.home[dst]]
        src_rows = np.asarray(ctable.part_rows[src])
        dst_rows = np.asarray(ctable.part_rows[dst])
        moving = np.asarray(step.row_ids)
        pos = np.searchsorted(src_rows, moving)
        if (len(src_rows) == 0 or not np.all(pos < len(src_rows))
                or not np.array_equal(src_rows[np.minimum(
                    pos, len(src_rows) - 1)], moving)):
            raise fv.FarviewError(
                f"stale migration step for {ctable.name!r}: rows are no "
                "longer on the source node (re-plan against the current "
                "map version)")
        keep = np.ones(len(src_rows), bool)
        keep[pos] = False
        new_src_rows = src_rows[keep]
        merged = np.concatenate([dst_rows, moving])
        order = np.argsort(merged, kind="stable")
        new_dst_rows = merged[order]

        # in-flight dispatches hold the old partitions' pages (and joins
        # resolve build shards by name at dispatch time): drain before the
        # extents change hands
        self.settle()
        is_str = bool(ctable.schema.str_width)
        kept_words = moved_words = dst_words = None
        if not is_str:
            src_part = ctable.parts[src]
            moved_words = np.asarray(
                fv.table_read_rows(src_qp, src_part, pos))
            kept_words = np.asarray(fv.table_read_rows(
                src_qp, src_part, np.nonzero(keep)[0]))
            if ctable.parts[dst] is not None and ctable.parts[dst].n_rows:
                dst_words = np.asarray(
                    fv.table_read(dst_qp, ctable.parts[dst]))
        dmat = (moved_words if dst_words is None and moved_words is not None
                else None)
        if dst_words is not None:
            dmat = np.concatenate([dst_words, moved_words])[order]

        sch = ctable.schema
        new_src = new_dst = None
        allocd = []
        try:
            if len(new_src_rows):
                new_src = FTable(sch.name, sch.columns,
                                 n_rows=len(new_src_rows),
                                 str_width=sch.str_width)
                fv.alloc_table_mem(src_qp, new_src)
                allocd.append((src_qp, new_src))
            new_dst = FTable(sch.name, sch.columns,
                             n_rows=len(new_dst_rows),
                             str_width=sch.str_width)
            fv.alloc_table_mem(dst_qp, new_dst)
            allocd.append((dst_qp, new_dst))
        except Exception:
            for qp, part in allocd:
                fv.free_table_mem(qp, part)
            self._restore_node_catalogs([(ctable, None)])
            raise
        if not is_str:
            if new_src is not None and kept_words is not None:
                fv.table_write(src_qp, new_src, kept_words)
            if dmat is not None:
                fv.table_write(dst_qp, new_dst, dmat)
        old_src, old_dst = ctable.parts[src], ctable.parts[dst]
        ctable.parts[src] = new_src
        ctable.parts[dst] = new_dst
        ctable.part_rows[src] = new_src_rows
        ctable.part_rows[dst] = new_dst_rows
        ctable.bump((src, dst))
        if old_src is not None:
            fv.free_table_mem(src_qp, old_src)
        if old_dst is not None:
            fv.free_table_mem(dst_qp, old_dst)
        self._refresh_aliases(ctable)


def open_connection(cluster: FarCluster) -> ClusterQP:
    return cluster.open_connection()


def close_connection(cqp: ClusterQP) -> None:
    cqp.cluster.close_connection(cqp)
