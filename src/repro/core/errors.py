"""Base error types shared across layers.

`FarviewError` used to live in `core.client`; the tiering codec
(`distributed.compress`) and the pool both need to raise it, and client
imports pool — so the base class lives here, below everything. `core.client`
re-exports it unchanged (every existing `fv.FarviewError` call site keeps
working, including the net tier's typed error frames).
"""
from __future__ import annotations


class FarviewError(RuntimeError):
    """Base class for every typed Farview failure."""


class PageCodecError(FarviewError):
    """A compressed page failed validation (corrupt stream, bad checksum,
    impossible descriptor). Raised INSTEAD of returning wrong bytes — a
    cold page that cannot be decoded exactly is a loud error, never a
    silently-wrong result."""
