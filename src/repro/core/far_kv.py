"""Disaggregated KV cache with attention push-down (Farview for LM serving).

The KV cache is the LM's buffer pool: large, append-only, read-dominated.
We shard it by *sequence* over the pool axis (default "model") — the cache
rows live on "memory" devices like Farview's network-attached DRAM — and
offer three read paths per the paper's evaluation matrix:

  mode="far"    (FV):   partial flash-attention runs at each shard owner;
                        only (o, m, l) = Hq*(D+2) floats cross the wire.
                        This is operator push-down: softmax-weighted-sum is
                        the aggregation operator.
  mode="naive"  (RCPU): shards ship their raw KV rows to the compute side
                        (all_gather), which attends locally. Bytes ∝ 2*S*Hkv*D.
  mode="local"  (LCPU): no disaggregation — cache is head-sharded like
                        standard TP serving; needs the whole sequence to fit
                        next to compute.

All three functions are written for use *inside* `jax.shard_map` over the
pool axis, so the collective schedule is explicit and auditable in the
lowered HLO (that is what §Roofline measures). `attend_block` wires a whole
GQA attention block (projections TP-sharded by heads + far-pool cache).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# partial attention (XLA impl; kernels/decode_attention.py is the TPU kernel)
# ---------------------------------------------------------------------------
def partial_attention(q, k, v, length, *, scale: float, start: int | jnp.ndarray = 0):
    """Unnormalized flash partials over one KV chunk.

    q: (B, Hq, D); k/v: (B, S_loc, Hkv, D); length: (B,) *local* valid rows.
    Returns o (B, Hq, D) f32, m (B, Hq), l (B, Hq).
    """
    b, hq, d = q.shape
    s, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    # MXU-native: consume the cache in its stored dtype (bf16 on the wire),
    # accumulate in f32 — never materialize an f32 cache copy (§Perf B1).
    qc = q.astype(k.dtype).reshape(b, hkv, g, d)
    scores = jnp.einsum("bhgd,bshd->bhgs", qc, k, optimize=True,
                        preferred_element_type=jnp.float32) * scale
    pos = jnp.arange(s)[None, None, None, :]
    valid = pos < length[:, None, None, None]
    neg = jnp.float32(-1e30)
    scores = jnp.where(valid, scores, neg)
    m = jnp.max(scores, axis=-1)
    m_safe = jnp.maximum(m, neg)
    p = jnp.where(valid, jnp.exp(scores - m_safe[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p.astype(k.dtype), v, optimize=True,
                   preferred_element_type=jnp.float32)
    return (o.reshape(b, hq, d), m_safe.reshape(b, hq), l.reshape(b, hq))


def merge_partials_named(o, m, l, axis: str):
    """LSE-merge partials across a mesh axis; ships Hq*(D+2) floats/device."""
    m_g = jax.lax.pmax(m, axis)
    w = jnp.exp(m - m_g)
    l_g = jax.lax.psum(l * w, axis)
    o_g = jax.lax.psum(o * w[..., None], axis)
    return o_g / jnp.maximum(l_g, 1e-30)[..., None]


# ---------------------------------------------------------------------------
# cache append (write path) — sequence-sharded pool
# ---------------------------------------------------------------------------
def append_seq_sharded(k_cache, v_cache, k_new, v_new, pos, axis: str):
    """Write one token's K/V into the owning sequence shard.

    k_cache/v_cache: (B, S_loc, Hkv, D) local chunk; k_new/v_new (B, Hkv, D)
    replicated (callers all_gather head-sharded projections first).
    pos: () int32 global write position.
    """
    s_loc = k_cache.shape[1]
    idx = jax.lax.axis_index(axis)
    start = idx * s_loc
    off = jnp.clip(pos - start, 0, s_loc - 1)
    in_range = (pos >= start) & (pos < start + s_loc)
    k_upd = jax.lax.dynamic_update_slice(
        k_cache, k_new[:, None].astype(k_cache.dtype), (0, off, 0, 0))
    v_upd = jax.lax.dynamic_update_slice(
        v_cache, v_new[:, None].astype(v_cache.dtype), (0, off, 0, 0))
    k_cache = jnp.where(in_range, k_upd, k_cache)
    v_cache = jnp.where(in_range, v_upd, v_cache)
    return k_cache, v_cache


def local_lengths(global_len, s_loc: int, axis: str):
    """Per-shard valid-row counts given global cache lengths (B,)."""
    start = jax.lax.axis_index(axis) * s_loc
    return jnp.clip(global_len - start, 0, s_loc)


# ---------------------------------------------------------------------------
# the three read paths
# ---------------------------------------------------------------------------
def attend_far(q_rep, k_cache, v_cache, global_len, *, axis: str,
               scale: float):
    """FV: push-down. q replicated; cache seq-sharded; returns replicated."""
    s_loc = k_cache.shape[1]
    loc_len = local_lengths(global_len, s_loc, axis)
    o, m, l = partial_attention(q_rep, k_cache, v_cache, loc_len, scale=scale)
    return merge_partials_named(o, m, l, axis)


def attend_naive(q_rep, k_cache, v_cache, global_len, *, axis: str,
                 scale: float):
    """RCPU: fetch-then-compute. All KV rows cross the wire."""
    k_full = jax.lax.all_gather(k_cache, axis, axis=1, tiled=True)
    v_full = jax.lax.all_gather(v_cache, axis, axis=1, tiled=True)
    o, m, l = partial_attention(q_rep, k_full, v_full, global_len, scale=scale)
    return o / jnp.maximum(l, 1e-30)[..., None]


def attend_local(q_loc, k_cache_loc, v_cache_loc, global_len, *,
                 scale: float):
    """LCPU: heads-sharded cache, no cross-device traffic in attention."""
    o, m, l = partial_attention(q_loc, k_cache_loc, v_cache_loc, global_len,
                                scale=scale)
    return o / jnp.maximum(l, 1e-30)[..., None]


# ---------------------------------------------------------------------------
# full decode attention block (projections + far pool), for shard_map use
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class BlockWeights:
    """Per-device TP shards of one attention block's projections."""
    wq: jnp.ndarray    # (d, hq_loc * dh)
    wk: jnp.ndarray    # (d, hkv_loc * dh)
    wv: jnp.ndarray    # (d, hkv_loc * dh)
    wo: jnp.ndarray    # (hq_loc * dh, d)


def attend_block(x, w: BlockWeights, k_cache, v_cache, pos, global_len, *,
                 axis: str, n_q_heads: int, n_kv_heads: int, head_dim: int,
                 mode: str = "far", scale: float | None = None):
    """One decode attention block inside shard_map over `axis`.

    x: (B, d) replicated activations. Caches: mode far/naive -> seq-sharded
    (B, S_loc, Hkv, D); mode local -> head-sharded (B, S, Hkv_loc, D).
    Returns ((B, d) replicated output, updated caches).
    """
    tp = jax.lax.axis_size(axis)
    if scale is None:
        scale = 1.0 / float(np.sqrt(head_dim))
    b = x.shape[0]
    hq_loc = n_q_heads // tp
    hkv_loc = max(1, n_kv_heads // tp)

    q_loc = (x @ w.wq).reshape(b, hq_loc, head_dim)
    k_loc = (x @ w.wk).reshape(b, hkv_loc, head_dim)
    v_loc = (x @ w.wv).reshape(b, hkv_loc, head_dim)

    if mode == "local":
        k_cache = jax.lax.dynamic_update_slice(
            k_cache, k_loc[:, None].astype(k_cache.dtype), (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(
            v_cache, v_loc[:, None].astype(v_cache.dtype), (0, pos, 0, 0))
        attn = attend_local(q_loc, k_cache, v_cache,
                            jnp.maximum(global_len, pos + 1), scale=scale)
        out = jax.lax.psum(attn.reshape(b, -1).astype(x.dtype) @ w.wo, axis)
        return out, k_cache, v_cache

    # far / naive: replicate q + the new KV heads (tiny), seq-sharded pool.
    # When tp > n_kv_heads the kv projections are replicated per head group
    # (device i computes kv head i * n_kv // tp); de-dup by striding.
    q_rep = jax.lax.all_gather(q_loc, axis, axis=1, tiled=True)
    k_all = jax.lax.all_gather(k_loc, axis, axis=1, tiled=True)
    v_all = jax.lax.all_gather(v_loc, axis, axis=1, tiled=True)
    if tp > n_kv_heads:
        stride = tp // n_kv_heads
        k_new, v_new = k_all[:, ::stride], v_all[:, ::stride]
    else:
        k_new, v_new = k_all, v_all
    k_cache, v_cache = append_seq_sharded(k_cache, v_cache, k_new, v_new,
                                          pos, axis)
    glen = jnp.maximum(global_len, pos + 1)
    if mode == "far":
        attn = attend_far(q_rep, k_cache, v_cache, glen, axis=axis,
                          scale=scale)
    elif mode == "naive":
        attn = attend_naive(q_rep, k_cache, v_cache, glen, axis=axis,
                            scale=scale)
    else:
        raise ValueError(mode)
    # out-projection: my head slice x my wo shard, row-parallel + psum
    idx = jax.lax.axis_index(axis)
    attn_loc = jax.lax.dynamic_slice(
        attn, (0, idx * hq_loc, 0), (b, hq_loc, head_dim))
    out = jax.lax.psum(attn_loc.reshape(b, -1).astype(x.dtype) @ w.wo, axis)
    return out, k_cache, v_cache


def shipped_bytes_per_layer(mode: str, *, batch: int, hq: int, hkv: int,
                            head_dim: int, seq_len: int, tp: int,
                            bytes_per_el: int = 2) -> int:
    """Modeled network bytes per decode step per layer (the Fig. 8 economics)."""
    if mode == "local":
        return batch * hq * head_dim * bytes_per_el          # psum of out proj
    q_ship = batch * hq * head_dim * bytes_per_el            # all_gather q
    kv_new = 2 * batch * hkv * head_dim * bytes_per_el
    if mode == "far":
        merge = batch * hq * (head_dim + 2) * 4              # o,m,l f32 psum
        return q_ship + kv_new + merge
    if mode == "naive":
        fetch = 2 * batch * seq_len * hkv * head_dim * bytes_per_el * (tp - 1) // tp
        return q_ship + kv_new + fetch
    raise ValueError(mode)
