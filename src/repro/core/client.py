"""Farview programmatic interface (paper §4.2) + multi-client management.

Mirrors the paper's API surface:

    open_connection(node)          -> QPair   (assigns a dynamic region)
    alloc_table_mem / free_table_mem
    table_read / table_write                  (plain one-sided RDMA)
    farview_request(qp, pipeline)  -> result  (the Farview verb)

A `FViewNode` owns a FarPool and a fixed set of dynamic regions (default 6,
the paper's evaluation configuration; tested up to 10). Each open connection
is bound to a region; a region runs one operator pipeline at a time and its
compiled executable is swapped per request from the pipeline cache
(pipeline.py). Requests from different QPairs are scheduled round-robin —
the fair-share arbiter of §4.3.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

import jax.numpy as jnp
import numpy as np

from repro.core.offload import _merge
from repro.core.pipeline import PipelineResult, compile_pipeline
from repro.core.pool import FarPool
from repro.core.table import FTable, WORD_BYTES


class FarviewError(RuntimeError):
    pass


@dataclass
class QPair:
    """Connection state: ids, region binding, transfer accounting."""
    qp_id: int
    node: "FViewNode"
    region: int
    bytes_shipped: int = 0
    bytes_read_pool: int = 0
    requests: int = 0


@dataclass
class DynamicRegion:
    region_id: int
    loaded_signature: tuple | None = None   # which pipeline is "configured"
    reconfigurations: int = 0
    busy_qp: int | None = None


class FViewNode:
    """One smart disaggregated memory node (pool + regions + scheduler)."""

    def __init__(self, capacity_bytes: int = 64 * 2**20, *, n_regions: int = 6,
                 n_shards: int = 1, interpret: bool | None = None):
        self.pool = FarPool(capacity_bytes, n_shards=n_shards)
        self.regions = [DynamicRegion(i) for i in range(n_regions)]
        self._qp_counter = itertools.count()
        self._qpairs: dict[int, QPair] = {}
        self._rr = 0
        self.interpret = interpret
        self.tables: dict[str, FTable] = {}     # name -> handle (catalog)

    # ----------------------------------------------------------- connections
    def open_connection(self) -> QPair:
        free = [r for r in self.regions if r.busy_qp is None]
        if not free:
            raise FarviewError("no free dynamic region (all regions bound)")
        region = free[0]
        qp = QPair(qp_id=next(self._qp_counter), node=self, region=region.region_id)
        region.busy_qp = qp.qp_id
        self._qpairs[qp.qp_id] = qp
        return qp

    def close_connection(self, qp: QPair) -> None:
        self.regions[qp.region].busy_qp = None
        self._qpairs.pop(qp.qp_id, None)


def open_connection(node: FViewNode) -> QPair:
    return node.open_connection()


def close_connection(qp: QPair) -> None:
    qp.node.close_connection(qp)


# --------------------------------------------------------------------- memory
def alloc_table_mem(qp: QPair, ft: FTable) -> FTable:
    ft = qp.node.pool.alloc_table(ft)
    qp.node.tables[ft.name] = ft            # catalog entry (paper §4.1)
    return ft


def free_table_mem(qp: QPair, ft: FTable) -> None:
    qp.node.pool.free_table(ft)


def table_write(qp: QPair, ft: FTable, words: np.ndarray) -> None:
    qp.node.pool.write_table(ft, words)


def table_read(qp: QPair, ft: FTable) -> jnp.ndarray:
    """Plain one-sided RDMA read: ships the whole table (no push-down)."""
    rows = qp.node.pool.read_table(ft)
    qp.bytes_shipped += ft.n_bytes
    qp.bytes_read_pool += ft.n_bytes
    qp.requests += 1
    return rows


# ------------------------------------------------------------- Farview verb
def farview_request(qp: QPair, ft: FTable, pipeline: tuple,
                    *, lengths: np.ndarray | None = None,
                    strings: np.ndarray | None = None) -> PipelineResult:
    """The paper's extra one-sided verb: read + operator pipeline push-down.

    For word tables the rows come from the pool; string tables (regex) pass
    their byte matrix + lengths explicitly (string ingest keeps a byte-exact
    sideband since the pool stores f32 words).
    """
    node = qp.node
    region = node.regions[qp.region]
    sig = tuple(pipeline)
    if region.loaded_signature != sig:
        region.loaded_signature = sig      # "partial reconfiguration"
        region.reconfigurations += 1
    pipe = compile_pipeline(ft, sig, interpret=node.interpret)

    # small-table join: the node reads the build table into "on-chip
    # memory" (paper §Conclusions future work) and matches the stream
    from repro.core import operators as op_ir
    build = None
    for o in pipeline:
        if isinstance(o, op_ir.JoinSmall):
            bft = node.tables[o.build_table]
            brows = node.pool.read_table(bft)
            bkeys = jnp.rint(brows[:, bft.col_index(o.build_key)]
                             ).astype(jnp.int32)
            bcols = [bft.col_index(c) for c in o.build_cols]
            bvals = brows[:, np.asarray(bcols)]
            build = (bkeys, bvals)

    if strings is not None:
        res = pipe(jnp.asarray(strings), jnp.asarray(lengths))
    else:
        smart_cols = None
        for op in pipeline:
            if isinstance(op, op_ir.SmartAddress):
                smart_cols = [ft.col_index(c) for c in op.cols]
        if smart_cols is not None:
            # smart addressing: column-granular pool reads (paper §5.2)
            node.pool.read_columns(ft, smart_cols)  # accounting read path
        rows = node.pool.read_table(ft) if smart_cols is None else \
            node.pool.read_table(ft)  # kernel consumes rows; smart path
            # narrows inside the pipeline with column-read byte accounting
        res = pipe(rows, build=build) if build is not None else pipe(rows)

    qp.requests += 1
    qp.bytes_read_pool += res.read_bytes
    qp.bytes_shipped += res.shipped_bytes or 0
    node.pool.stats.bytes_shipped += res.shipped_bytes or 0
    node.pool.stats.requests += 1
    return res


def merge_group_partials(ft: FTable, pipeline: tuple,
                         partials: list[PipelineResult]) -> PipelineResult:
    """Client-side software merge (overflow buffers, multi-node partials)."""
    return _merge(ft, pipeline, partials)
