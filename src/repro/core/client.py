"""Farview programmatic interface (paper §4.2) + multi-client management.

Mirrors the paper's API surface:

    open_connection(node)          -> QPair   (assigns a dynamic region)
    alloc_table_mem / free_table_mem
    table_read / table_write                  (plain one-sided RDMA)
    farview_request(qp, pipeline)  -> result  (the Farview verb)
    submit_request(qp, pipeline)   -> pending (async verb; node.flush() runs
                                               the scheduler)

A `FViewNode` owns a FarPool and a fixed set of dynamic regions (default 6,
the paper's evaluation configuration; tested up to 10). Each open connection
is bound to a region; a region runs one operator pipeline at a time and its
compiled executable is swapped per request from the pipeline cache
(pipeline.py).

The request path is a batched scheduler: submitted requests queue on the
node; each scheduling round serves at most one request per QPair in
round-robin order (the fair-share arbiter of §4.3), and picked requests
with the same pipeline signature + table layout are coalesced into ONE
stacked executable dispatch (`CompiledPipeline.run_pages_batched` /
`run_strings_batched`). Every request kind rides the stack:

  * word tables shape-bucket: requests whose row counts share a
    power-of-two bucket run at the bucket shape — page lists are padded
    with the pool's pinned null page and the traced `n_valid` masks each
    tail — so K different-sized same-layout tables cost ONE executable;
  * string/regex requests stack as a (B, n, w) byte tensor, row- and
    width-bucketed (exact width when a pre-crypt makes the keystream
    position-sensitive);
  * join probes coalesce when they share a build table (the build is
    named in the signature, so same-signature implies same build): the
    build operand is broadcast across the stacked probes, not vmapped.

The dispatch itself is asynchronous — the fused executable consumes pool pages
directly (no separate read_table) and returns lazy `PipelineResult`s whose
`finalize()` is the only synchronization point. Data-dependent byte
accounting (response sizes) settles when results materialize; reading a
QPair's counters settles its node first. Padded rows are invisible to accounting:
read bytes bill each request's own rows, shipped bytes come from traced
counts that already exclude masked tails.
"""
from __future__ import annotations

import itertools
import math
import threading
import time
from collections import OrderedDict, deque
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core import operators as op_ir
# FarviewError moved to core.errors (the tiering codec below the pool needs
# to raise it); re-exported here so every `fv.FarviewError` call site —
# including the net tier's typed error frames — keeps working unchanged.
from repro.core.errors import FarviewError, PageCodecError  # noqa: F401
from repro.core.offload import _merge
from repro.core.pipeline import PipelineResult, compile_pipeline
from repro.core.pool import FarPool
from repro.core.table import FTable, WORD_BYTES


class NodeDeadError(FarviewError):
    """The node is gone (killed host, dead NIC): every verb against it
    fails until it is replaced. Cluster reads fail over to a replica;
    `ReplicaUnavailableError` (distributed/health.py) is raised when no
    replica survives. Carries the node identity for the health monitor."""

    def __init__(self, node_id: int, *, op: str = "dispatch"):
        super().__init__(f"node {node_id} is dead (failed {op})")
        self.node_id = node_id
        self.op = op


class DeadlineExceededError(FarviewError):
    """The request's deadline budget ran out before it was served, so it
    was SHED — never half-run. Sheds happen wherever the budget is next
    inspected: at `FViewNode.flush` pick time (in-process), at the
    server's admission / pre-dispatch check (over the wire, as a typed
    `DEADLINE_EXCEEDED` error frame), or client-side before a
    retry/hedge would spend budget that no longer exists. Deliberately
    NOT a health strike and NOT retried by failover
    (`ClusterPending._settle_entry` re-raises it): time ran out, not the
    node — rerouting would only return a late answer later."""

    def __init__(self, node_id: int | None = None, *,
                 op: str = "dispatch",
                 detail: str = "deadline budget exhausted"):
        where = "cluster" if node_id is None else f"node {node_id}"
        super().__init__(f"{where}: {detail} (request shed before {op})")
        self.node_id = node_id
        self.op = op
        self.detail = detail


class QPair:
    """Connection state: ids, region binding, transfer accounting.

    Byte counters settle lazily: responses are materialized asynchronously,
    so reading `bytes_shipped` / `bytes_read_pool` first finalizes any
    in-flight responses on the owning node (the only sync point)."""

    def __init__(self, qp_id: int, node: "FViewNode", region: int):
        self.qp_id = qp_id
        self.node = node
        self.region = region
        self.requests = 0
        self._bytes_shipped = 0
        self._bytes_read_pool = 0

    @property
    def bytes_shipped(self) -> int:
        self.node.settle()
        return self._bytes_shipped

    @property
    def bytes_read_pool(self) -> int:
        self.node.settle()
        return self._bytes_read_pool


class PageCache:
    """Bounded client-side partition cache with versioned invalidation.

    Entries are keyed `(table_name, partition_index)` and stamped with
    the partition's epoch (`ClusterTable.part_version[i]`) at fill time.
    Every lookup presents the CURRENT epoch; a mismatch means some flip
    — a write, a migration step, a heal promotion, a cold-storage
    restore — moved the partition on, so the stale copy is dropped on
    sight and the lookup misses. Invalidation therefore costs nothing at
    flip time: bumping the epoch counter IS the invalidation, and it
    invalidates exactly the partitions that moved (an untouched
    partition keeps serving from cache across its neighbors' flips).

    LRU over bytes: `capacity_bytes` bounds the sum of cached row
    matrices; filling past the bound evicts from the cold end. Cached
    arrays are private read-only copies — a hit may be handed to many
    readers concurrently and must never alias pool or caller memory.
    Thread-safe: cluster reads race rebalance/heal sweeps by design."""

    def __init__(self, capacity_bytes: int):
        if capacity_bytes <= 0:
            raise ValueError(
                f"PageCache needs a positive byte budget, got "
                f"{capacity_bytes}")
        self.capacity_bytes = int(capacity_bytes)
        self._lock = threading.Lock()
        # (name, part) -> (epoch, rows); insertion order = LRU order
        self._entries: OrderedDict = OrderedDict()
        self._bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.invalidations = 0

    def get(self, name: str, part: int, epoch: int):
        key = (name, part)
        with self._lock:
            ent = self._entries.get(key)
            if ent is None:
                self.misses += 1
                return None
            cached_epoch, rows = ent
            if cached_epoch != epoch:
                del self._entries[key]
                self._bytes -= rows.nbytes
                self.invalidations += 1
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return rows

    def put(self, name: str, part: int, epoch: int,
            rows: np.ndarray) -> None:
        rows = np.array(rows, copy=True)
        rows.setflags(write=False)
        if rows.nbytes > self.capacity_bytes:
            return          # would evict everything else for one entry
        key = (name, part)
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                self._bytes -= old[1].nbytes
            self._entries[key] = (epoch, rows)
            self._bytes += rows.nbytes
            while self._bytes > self.capacity_bytes:
                _, (_, dropped) = self._entries.popitem(last=False)
                self._bytes -= dropped.nbytes
                self.evictions += 1

    def drop_table(self, name: str) -> int:
        """Forget every partition of `name` (table freed — its epochs die
        with it, so a same-named future table must not hit)."""
        with self._lock:
            stale = [k for k in self._entries if k[0] == name]
            for key in stale:
                _, rows = self._entries.pop(key)
                self._bytes -= rows.nbytes
            return len(stale)

    @property
    def cached_bytes(self) -> int:
        with self._lock:
            return self._bytes

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def stats(self) -> dict:
        with self._lock:
            return {"entries": len(self._entries), "bytes": self._bytes,
                    "hits": self.hits, "misses": self.misses,
                    "evictions": self.evictions,
                    "invalidations": self.invalidations}


@dataclass
class DynamicRegion:
    region_id: int
    loaded_signature: tuple | None = None   # which pipeline is "configured"
    reconfigurations: int = 0
    busy_qp: int | None = None


@dataclass
class PendingRequest:
    """A submitted Farview verb awaiting a scheduling round."""
    qp: QPair
    ft: FTable
    pipeline: tuple
    lengths: np.ndarray | None = None
    strings: np.ndarray | None = None
    row_ids: np.ndarray | None = None   # original-table row indices (cluster
    #                                     partition dispatch; None = solo)
    result: PipelineResult | None = None
    error: Exception | None = None      # dispatch-time failure (this request)
    deadline_at: float | None = None    # time.monotonic() budget expiry; an
    #                                     expired request is shed at pick
    #                                     time, never dispatched

    def wait(self) -> PipelineResult:
        """Dispatch (if still queued) and materialize the response."""
        if self.result is None and self.error is None:
            try:
                self.qp.node.flush()
            except Exception:
                # a different request's dispatch failed; ours may be fine
                if self.result is None and self.error is None:
                    raise
        if self.error is not None:
            raise self.error
        return self.result.finalize()


class FViewNode:
    """One smart disaggregated memory node: a paged `FarPool`, a fixed
    set of dynamic regions, and the bucket-batched request scheduler.

    `capacity_bytes` sizes the pool (2 MiB page granularity); `n_regions`
    bounds concurrent connections (each `open_connection` binds a QPair
    to a free region — the paper evaluates 6, tested to 10); `n_shards`
    stripes pool pages across device shards; `interpret=None` picks the
    operator lowering automatically (Pallas kernels on TPU, XLA-native
    elsewhere — byte-identical results either way). Requests queue via
    `submit` and dispatch in `flush`'s scheduling rounds: one request per
    QPair per round (§4.3 fair share), same-(signature, layout, shape
    bucket) picks coalesced into ONE stacked executable. See
    docs/architecture.md for the scheduler's bucketing rules."""

    def __init__(self, capacity_bytes: int = 64 * 2**20, *, n_regions: int = 6,
                 n_shards: int = 1, interpret: bool | None = None,
                 node_id: int = 0, fault=None, page_bytes: int | None = None,
                 **pool_kw):
        # page_bytes / pool_kw pass through to FarPool — tiering tests use
        # small pages so multi-page (mixed-tier) tables stay cheap
        if page_bytes is not None:
            pool_kw["page_bytes"] = page_bytes
        self.pool = FarPool(capacity_bytes, n_shards=n_shards, **pool_kw)
        self.node_id = node_id      # cluster position (0 for a solo node)
        self.fault = fault          # FaultInjector (duck-typed) or None
        self.regions = [DynamicRegion(i) for i in range(n_regions)]
        self._qp_counter = itertools.count()
        self._qpairs: dict[int, QPair] = {}
        self._rr = 0
        self.interpret = interpret
        self.tables: dict[str, FTable] = {}     # name -> handle (catalog)
        self._queue: deque[PendingRequest] = deque()
        self._inflight: list[PipelineResult] = []
        self.dispatches = 0     # stacked-executable launches (scheduler SLO:
        #                         one per (signature, layout, bucket) group
        #                         per round, however many clients stacked)

    # ----------------------------------------------------------- connections
    def open_connection(self) -> QPair:
        free = [r for r in self.regions if r.busy_qp is None]
        if not free:
            raise FarviewError("no free dynamic region (all regions bound)")
        region = free[0]
        qp = QPair(qp_id=next(self._qp_counter), node=self,
                   region=region.region_id)
        region.busy_qp = qp.qp_id
        self._qpairs[qp.qp_id] = qp
        return qp

    def close_connection(self, qp: QPair) -> None:
        """Unbind the region and fail the QPair's still-queued requests.

        A request left in `_queue` past its connection's close would be
        dispatched by a later `flush()` against a region that may then be
        bound to a *different* connection — misattributing reconfigurations
        and counters to the new tenant. Cancel them now; their `wait()`
        raises."""
        still: deque[PendingRequest] = deque()
        for req in self._queue:
            if req.qp is qp:
                req.error = FarviewError(
                    f"connection qp{qp.qp_id} closed with request pending")
            else:
                still.append(req)
        self._queue = still
        self.regions[qp.region].busy_qp = None
        self._qpairs.pop(qp.qp_id, None)

    # ----------------------------------------------------------------- faults
    def check_fault(self, op: str = "dispatch") -> None:
        """Consult the injected fault set (distributed/health.py) before
        serving a verb: a killed node raises `NodeDeadError`, a slow node
        sleeps, a drop budget raises the transient `DroppedDispatchError`.
        Failures are first-class inputs — they hit exactly where a real
        dead host or NIC timeout would, so failover is testable."""
        if self.fault is not None:
            self.fault.check(self.node_id, op)

    # -------------------------------------------------------------- scheduler
    @property
    def has_queued(self) -> bool:
        """Whether any submitted request awaits a scheduling round (the
        cluster's scatter uses this to decide which nodes need a drain)."""
        return bool(self._queue)

    def submit(self, qp: QPair, ft: FTable, pipeline: tuple, *,
               lengths: np.ndarray | None = None,
               strings: np.ndarray | None = None,
               row_ids: np.ndarray | None = None,
               deadline_s: float | None = None) -> PendingRequest:
        """Queue a Farview verb; dispatched at the next scheduling round.
        `deadline_s` is the remaining budget: past it the request is shed
        (typed `DeadlineExceededError`) instead of dispatched."""
        if qp.qp_id not in self._qpairs:
            # a closed QPair's region may already be bound to a new tenant;
            # accepting the verb would ghost-dispatch against it
            raise FarviewError(f"connection qp{qp.qp_id} is closed")
        pipeline = op_ir.validate_pipeline(tuple(pipeline))
        # tiering hysteresis: every submitted verb is an access. Word tables
        # promote only after `promote_after` hits in the window (a lone cold
        # scan runs fused-decompressed, no tier-bit thrash); string tables
        # promote immediately (their dispatch reads the byte sideband, so
        # cold has no fused-decode path to stay on).
        self.pool.note_access(ft)
        req = PendingRequest(qp, ft, pipeline, lengths, strings, row_ids)
        if deadline_s is not None:
            if deadline_s <= 0:     # dead on arrival: shed, never queued
                req.error = DeadlineExceededError(self.node_id, op="submit")
                return req
            req.deadline_at = time.monotonic() + float(deadline_s)
        self._queue.append(req)
        return req

    def flush(self) -> None:
        """Drain the queue in scheduling rounds.

        Each round serves at most one request per QPair (§4.3 round-robin
        fair share; the service order rotates across rounds), then coalesces
        the round's picks by (signature, table layout) and dispatches every
        group as ONE stacked executable. A group whose dispatch fails does
        not take down the rest of the round: the error is attached to its
        requests (raised by `wait()`) and the first one re-raised after the
        queue drains."""
        first_err: Exception | None = None
        while self._queue:
            picks: list[PendingRequest] = []
            seen: set[int] = set()
            rest: deque[PendingRequest] = deque()
            now = time.monotonic()
            for req in self._queue:
                if (req.deadline_at is not None and now >= req.deadline_at):
                    # budget spent while queued: shed BEFORE dispatch —
                    # an expired request never half-runs
                    req.error = DeadlineExceededError(
                        self.node_id, op="dispatch")
                    if first_err is None:
                        first_err = req.error
                elif req.qp.qp_id in seen:
                    rest.append(req)
                else:
                    seen.add(req.qp.qp_id)
                    picks.append(req)
            self._queue = rest
            if not picks:
                continue
            k = self._rr % len(picks)
            picks = picks[k:] + picks[:k]       # rotate the arbiter
            self._rr += 1
            groups: dict[tuple, list[PendingRequest]] = {}
            for req in picks:
                groups.setdefault(self._dispatch_key(req), []).append(req)
            for reqs in groups.values():
                try:
                    self._dispatch(reqs)
                except Exception as e:
                    for req in reqs:
                        req.error = e
                    if first_err is None:
                        first_err = e
        if first_err is not None:
            raise first_err

    def settle(self) -> None:
        """Dispatch everything queued and materialize in-flight responses
        (fires the deferred byte accounting). Dispatch errors stay attached
        to their own PendingRequest (raised by its `wait()`) — an innocent
        counter read must not blow up on another client's bad request, and
        successful responses still settle."""
        try:
            self.flush()
        except Exception:
            pass
        inflight, self._inflight = self._inflight, []
        for res in inflight:
            res.finalize()

    def _dispatch_key(self, req: PendingRequest) -> tuple:
        """The coalescing key: requests with equal keys ride one stacked
        executable this round.

        The layout part must match compile_pipeline's cache key (column
        names/dtypes, not just shape) — same-shaped tables with permuted
        columns compile to different programs. Sizes enter only as
        power-of-two buckets: different-sized tables in one bucket share
        the executable (page lists padded with the pool null page, tails
        masked by the traced n_valid). Joins need no special casing — the
        build table is named in the signature, so one group always shares
        one build. String requests bucket on (rows, width); a pre-crypt
        pins the width exactly because the CTR keystream is positional
        over the row-major byte flattening (row padding appends whole
        rows and never shifts it)."""
        sig = op_ir.signature(req.pipeline)
        # partitioned requests (row_ids) ride their own stacks: the traced
        # program takes an extra ids operand, so mixing them with solo
        # requests would be a different executable signature anyway
        ids = req.row_ids is not None
        layout = (tuple((c.name, c.dtype) for c in req.ft.columns),
                  bool(req.ft.str_width))
        if req.strings is not None:
            n, w = np.asarray(req.strings).shape
            wkey = (int(w) if op_ir.has_crypt_pre(req.pipeline)
                    else op_ir.pow2_bucket(w))
            return ("str", sig, layout, op_ir.pow2_bucket(n), wkey, ids)
        # tiered tables ride their own stacks: their executable takes the
        # decode-descriptor operand (a different compile-cache entry), and
        # keeping flat tables off it preserves the pre-tiering fast path
        return ("word", sig, layout, req.ft.row_words,
                op_ir.pow2_bucket(req.ft.n_rows), ids,
                self.pool.is_tiered(req.ft))

    def _resolve_build(self, pipeline: tuple):
        """The node reads the join build table into "on-chip memory"
        (paper §Conclusions future work) and matches the stream against it."""
        for o in pipeline:
            if isinstance(o, op_ir.JoinSmall):
                bft = self.tables[o.build_table]
                brows = self.pool.read_table(bft)
                bkeys = jnp.rint(brows[:, bft.col_index(o.build_key)]
                                 ).astype(jnp.int32)
                bcols = [bft.col_index(c) for c in o.build_cols]
                # key uniqueness is validated by CompiledPipeline._as_build
                return (bkeys, brows[:, np.asarray(bcols)])
        return None

    def _dispatch(self, reqs: list[PendingRequest]) -> None:
        self.check_fault("dispatch")
        ft0 = reqs[0].ft
        sig = op_ir.signature(reqs[0].pipeline)
        # homogeneous by dispatch key: tiered-ness is part of the key, so
        # one group is all-tiered or all-flat
        tiered = reqs[0].strings is None and self.pool.is_tiered(ft0)
        pipe = compile_pipeline(ft0, reqs[0].pipeline,
                                interpret=self.interpret, tiered=tiered)
        for req in reqs:
            region = self.regions[req.qp.region]
            if region.loaded_signature != sig:
                region.loaded_signature = sig   # "partial reconfiguration"
                region.reconfigurations += 1

        if len(reqs) == 1:
            req = reqs[0]
            if req.strings is not None:
                res = pipe(jnp.asarray(req.strings),
                           jnp.asarray(req.lengths),
                           row_ids=req.row_ids)
            else:
                build = self._resolve_build(req.pipeline)
                tier = pw = phys = None
                if tiered:
                    tier = self.pool.tier_desc(req.ft)
                    pw = self.pool.page_words
                    phys = self.pool.tier_read_bytes(req.ft, pipe.read_cols)
                res = pipe.run_pages(self.pool.buf, req.ft.pages,
                                     req.ft.n_rows, build=build,
                                     n_rows=req.ft.n_rows,
                                     row_words=req.ft.row_words,
                                     row_ids=req.row_ids, tier=tier,
                                     page_words=pw, read_bytes=phys)
            results = [res]
        elif reqs[0].strings is not None:
            results = self._dispatch_strings_batched(pipe, reqs)
        else:
            results = self._dispatch_pages_batched(pipe, reqs)
        self.dispatches += 1        # counted only once the launch succeeded

        for req, res in zip(reqs, results):
            req.result = res
            self._account(req, res)

    def _dispatch_pages_batched(self, pipe, reqs) -> list[PipelineResult]:
        """Stacked word-table round: pad every page list to the shape
        bucket with the pool's pinned null page; the bucket executable
        reads zeros past each table's extent and n_valid masks them."""
        row_words = reqs[0].ft.row_words
        bucket = op_ir.shape_bucket(max(r.ft.n_rows for r in reqs))
        n_pages = max(1, math.ceil(bucket * row_words * WORD_BYTES
                                   / self.pool.page_bytes))
        pages = np.full((len(reqs), n_pages), self.pool.null_page, np.int32)
        for b, r in enumerate(reqs):
            pages[b, : len(r.ft.pages)] = r.ft.pages
        n_valid = np.asarray([r.ft.n_rows for r in reqs], np.int32)
        row_ids = None
        if reqs[0].row_ids is not None:     # homogeneous by dispatch key
            row_ids = np.zeros((len(reqs), bucket), np.int32)
            for b, r in enumerate(reqs):
                row_ids[b, : r.ft.n_rows] = r.row_ids    # tails masked
        build = self._resolve_build(reqs[0].pipeline)
        tier = pw = phys = None
        if pipe.tiered:
            # stack each request's decode descriptors, padded to the
            # bucket's page count with null-descriptor rows (mode RAW over
            # the pinned null page — reads zeros, masked by n_valid)
            descs = [self.pool.tier_desc_padded(r.ft, n_pages)
                     for r in reqs]
            tier = tuple(jnp.asarray(np.stack([d[i] for d in descs]))
                         for i in range(len(descs[0])))
            pw = self.pool.page_words
            phys = [self.pool.tier_read_bytes(r.ft, pipe.read_cols)
                    for r in reqs]
        return pipe.run_pages_batched(self.pool.buf, pages, n_valid,
                                      build=build, n_rows=bucket,
                                      row_words=row_words, row_ids=row_ids,
                                      tier=tier, page_words=pw,
                                      read_bytes=phys)

    def _dispatch_strings_batched(self, pipe, reqs) -> list[PipelineResult]:
        """Stacked string/regex round: zero-pad each request's byte matrix
        to the (rows, width) bucket and stack. Padded rows carry length 0
        and are masked via n_valid; widths stay exact when the key pinned
        them (pre-crypt keystream)."""
        mats = [np.asarray(r.strings, np.uint8) for r in reqs]
        bucket_n = op_ir.shape_bucket(max(m.shape[0] for m in mats))
        bucket_w = max(op_ir.shape_bucket(m.shape[1]) for m in mats) \
            if not op_ir.has_crypt_pre(reqs[0].pipeline) \
            else mats[0].shape[1]
        stacked = np.zeros((len(reqs), bucket_n, bucket_w), np.uint8)
        lengths = np.zeros((len(reqs), bucket_n), np.int32)
        for b, (m, r) in enumerate(zip(mats, reqs)):
            stacked[b, : m.shape[0], : m.shape[1]] = m
            lengths[b, : m.shape[0]] = np.asarray(r.lengths, np.int32)
        n_valid = np.asarray([m.shape[0] for m in mats], np.int32)
        widths = np.asarray([m.shape[1] for m in mats], np.int32)
        row_ids = None
        if reqs[0].row_ids is not None:     # homogeneous by dispatch key
            row_ids = np.zeros((len(reqs), bucket_n), np.int32)
            for b, (m, r) in enumerate(zip(mats, reqs)):
                row_ids[b, : m.shape[0]] = r.row_ids     # tails masked
        return pipe.run_strings_batched(stacked, lengths, n_valid,
                                        widths=widths, row_ids=row_ids)

    def _account(self, req: PendingRequest, res: PipelineResult) -> None:
        qp = req.qp
        qp.requests += 1
        qp._bytes_read_pool += res.read_bytes           # static: settle now
        self.pool.stats.bytes_read += res.read_bytes
        self.pool.stats.requests += 1

        def _credit(r, qp=qp):                          # data-dependent:
            qp._bytes_shipped += r._shipped              # settle at finalize
            self.pool.stats.bytes_shipped += r._shipped
            try:                        # settled results stop pinning memory
                self._inflight.remove(r)
            except ValueError:
                pass                    # already drained by settle()

        self._inflight.append(res)
        res.on_finalize(_credit)


def open_connection(node: FViewNode) -> QPair:
    return node.open_connection()


def close_connection(qp: QPair) -> None:
    qp.node.close_connection(qp)


# --------------------------------------------------------------------- memory
def alloc_table_mem(qp: QPair, ft: FTable) -> FTable:
    """Allocate pool pages for `ft` on the connection's node (paper §4.2).

    `ft` carries the schema (columns/dtypes, `n_rows`, optional
    `str_width` for byte-string tables); allocation fills its placement
    (`table_id`, `pages` — striped across pool shards) and registers the
    handle in the node's catalog so pipelines can resolve it by name
    (join build tables are looked up this way at dispatch). Raises
    `MemoryError` when the pool lacks free pages. The cluster-level
    `FarCluster.alloc_table_mem` wraps this per node with a partition
    map; see docs/cluster.md."""
    ft = qp.node.pool.alloc_table(ft)
    qp.node.tables[ft.name] = ft            # catalog entry (paper §4.1)
    return ft


def free_table_mem(qp: QPair, ft: FTable) -> None:
    qp.node.pool.free_table(ft)


def table_write(qp: QPair, ft: FTable, words: np.ndarray) -> None:
    qp.node.check_fault("table_write")
    qp.node.pool.write_table(ft, words)


def table_read(qp: QPair, ft: FTable) -> jnp.ndarray:
    """Plain one-sided RDMA read: ships the whole table (no push-down).

    A tiered extent bills its PHYSICAL bytes — the compressed stream is
    what crosses the wire; the decode (fused for word pages, block codec
    for string extents) reconstructs the logical rows byte-identically.
    `tier_read_bytes` degrades to `ft.n_bytes` for flat tables."""
    qp.node.check_fault("table_read")
    pool = qp.node.pool
    pool.note_access(ft)                    # reads count toward promotion
    phys = pool.tier_read_bytes(ft)
    rows = pool.read_table(ft)
    qp._bytes_shipped += phys
    qp._bytes_read_pool += phys
    qp.requests += 1
    return rows


def table_read_rows(qp: QPair, ft: FTable, row_idx) -> jnp.ndarray:
    """Row-subset one-sided read: ships only the selected LOCAL rows.

    The cluster's live migration copies partition rows node-to-node
    through this verb (read from the source pool, written to the
    destination), so the copy traffic is bounded by the rows actually
    moving and shows up in the QPair/pool byte counters like any other
    transfer."""
    qp.node.check_fault("table_read")
    rows = qp.node.pool.read_rows(ft, row_idx)
    n_bytes = int(np.asarray(row_idx).size) * ft.row_words * WORD_BYTES
    qp._bytes_shipped += n_bytes
    qp._bytes_read_pool += n_bytes
    qp.requests += 1
    return rows


# ------------------------------------------------------------- Farview verb
def submit_request(qp: QPair, ft: FTable, pipeline: tuple, *,
                   lengths: np.ndarray | None = None,
                   strings: np.ndarray | None = None,
                   row_ids: np.ndarray | None = None) -> PendingRequest:
    """Async Farview verb: queue on the node. `node.flush()` dispatches;
    requests from different QPairs sharing a signature coalesce into one
    stacked executable per scheduling round. `row_ids` marks a partition
    dispatch (cluster scatter): original-table row indices that key the
    crypt keystream and come back as `PipelineResult.sel_ids`."""
    return qp.node.submit(qp, ft, pipeline, lengths=lengths, strings=strings,
                          row_ids=row_ids)


def farview_request(qp: QPair, ft: FTable, pipeline: tuple,
                    *, lengths: np.ndarray | None = None,
                    strings: np.ndarray | None = None,
                    row_ids: np.ndarray | None = None) -> PipelineResult:
    """The paper's extra one-sided verb: read + operator pipeline push-down.

    `pipeline` is an ordered tuple of operator descriptors (see
    docs/operators.md for every verb's payload and semantics). One fused
    executable per (signature, layout) does page gather + operators +
    byte accounting; the returned `PipelineResult` is lazy — touch
    `.count` / `.shipped_bytes` / `.groups` or call `.finalize()` to
    sync. Word tables stream from the pool; string tables (regex) pass
    their byte matrix via `strings=` + `lengths=` (string ingest keeps a
    byte-exact sideband since the pool stores f32 words). `row_ids`
    marks a cluster partition dispatch: the rows' original-table indices,
    which address the pre-crypt keystream and come back as `sel_ids` for
    the order-restoring gather merge.
    """
    req = submit_request(qp, ft, pipeline, lengths=lengths, strings=strings,
                         row_ids=row_ids)
    try:
        qp.node.flush()
    except Exception:
        # a different queued request's dispatch failed; ours may be fine
        if req.result is None and req.error is None:
            raise
    if req.error is not None:
        raise req.error
    return req.result


def merge_group_partials(ft: FTable, pipeline: tuple,
                         partials: list[PipelineResult], *,
                         n_rows: int | None = None,
                         part_rows: list | None = None) -> PipelineResult:
    """Client-side software merge (overflow buffers, multi-node partials).

    Groups-kind partials — each a compact (bucket table + packed collision
    rows) per node — concatenate and fold in ONE device-side segment-reduce
    dispatch (offload.merge_groups_device); only the per-key totals cross
    back to the host dict. `n_rows` / `part_rows` are the cluster
    scatter-gather extras: the original table's row count and the partition
    map, which let rows-kind and mask-kind partials splice back
    byte-identically to a single-node response (see offload._merge)."""
    return _merge(ft, pipeline, partials, n_rows=n_rows, part_rows=part_rows)
