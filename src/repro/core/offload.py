"""Near-data offload engine: pipelines over a device-sharded pool (paper §4).

The single-node FarPool covers the paper's actual prototype (one FPGA node).
This module is the scale-out: the table's row matrix is sharded over the
mesh's pool axis (default "model"); `shard_map` runs the compiled pipeline
*on the device that owns the shard* (near-data), and only the reduced
results are exchanged:

  * rows kind:    per-shard packed survivors + counts are all-gathered
                  (variable-length response packets, like the RDMA sender);
  * groups kind:  per-shard partial aggregates (fixed B buckets) are shipped
                  and merged client-side — the multi-node generalization of
                  the paper's single hash table;
  * mask kind:    1 byte/row decisions.

`shipped_fraction` quantifies the data-movement reduction vs. fetching raw
rows — the metric behind Figs. 8-10.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import operators as op_ir
from repro.core.pipeline import PipelineResult, compile_pipeline
from repro.core.table import FTable, WORD_BYTES
from repro.kernels import ref as kref

# group-merge pad key: sorts past every real key (|key| < 2^24 at ingest),
# the bucket sentinel (int32 min) and the drop key (int32 min + 1)
_PAD_KEY = np.int32(np.iinfo(np.int32).max)
_BIG = np.float32(np.finfo(np.float32).max)


@jax.jit
def _segment_merge_groups(keys, cnt, sums, mins, maxs):
    """Fused device-side merge of concatenated group partials.

    keys (M,) i32 (invalid entries pre-masked to _PAD_KEY); cnt (M,) i32;
    sums/mins/maxs (M, V) f32. Stable-sorts by key and reduces each key's
    segment in ONE log-depth segmented scan — the multi-node generalization
    of the paper's client-side software merge, but as a single jitted
    dispatch instead of a Python dict loop over every bucket of every
    partial. Returns per-row (sorted_keys, end_mask, count, sum, min, max);
    each key's totals sit at its segment-end row (select with end_mask).
    """
    order = jnp.argsort(keys, stable=True)
    k = keys[order]
    n = k.shape[0]
    one = jnp.ones((min(n, 1),), bool)
    flags = jnp.concatenate([one, k[1:] != k[:-1]])
    cs, ss, mns, mxs = kref.segmented_reduce(
        sums[order], mins[order], maxs[order], flags, counts=cnt[order])
    end = jnp.concatenate([flags[1:], one])
    return k, end, cs, ss, mns, mxs


# farlint: finalize-boundary (the group merge IS the designed sync point)
def merge_groups_device(groups: "list[dict]",
                        drop: "int | None") -> dict:
    """Concatenate N partials' (bucket entries + overflow rows) and
    segment-reduce them device-side; only the compact per-key totals cross
    back to the host dict. Overflow rows ride the same path as the bucket
    partials: a collision row is just a (key, count=1, sum=min=max=value)
    partial aggregate."""
    drop_val = np.int32(_PAD_KEY if drop is None else drop)
    ks, cs, ss, mns, mxs = [], [], [], [], []
    for g in groups:
        bk = jnp.asarray(g["bucket_keys"], jnp.int32)
        cnt = jnp.asarray(g["count"], jnp.int32)
        bsum = jnp.asarray(g["sum"], jnp.float32)
        bad = ((bk == np.int32(kref.KEY_SENTINEL)) | (cnt <= 0)
               | (bk == drop_val))
        ks.append(jnp.where(bad, _PAD_KEY, bk))
        cs.append(jnp.where(bad, 0, cnt))
        ss.append(jnp.where(bad[:, None], 0.0, bsum))
        mns.append(jnp.where(bad[:, None], _BIG,
                             jnp.asarray(g["min"], jnp.float32)))
        mxs.append(jnp.where(bad[:, None], -_BIG,
                             jnp.asarray(g["max"], jnp.float32)))
        ok = jnp.asarray(g["ovf_keys"], jnp.int32)
        if ok.shape[0]:
            ov = jnp.asarray(g["ovf_vals"], jnp.float32)
            obad = ok == drop_val
            ks.append(jnp.where(obad, _PAD_KEY, ok))
            cs.append(jnp.where(obad, 0, 1).astype(jnp.int32))
            ks_bad = obad[:, None]
            ss.append(jnp.where(ks_bad, 0.0, ov))
            mns.append(jnp.where(ks_bad, _BIG, ov))
            mxs.append(jnp.where(ks_bad, -_BIG, ov))
    m = sum(int(a.shape[0]) for a in ks)
    pad = op_ir.pow2_bucket(m) - m      # bound jit retraces across shapes
    v = int(ss[0].shape[1])
    if pad:
        ks.append(jnp.full((pad,), _PAD_KEY, jnp.int32))
        cs.append(jnp.zeros((pad,), jnp.int32))
        ss.append(jnp.zeros((pad, v), jnp.float32))
        mns.append(jnp.full((pad, v), _BIG, jnp.float32))
        mxs.append(jnp.full((pad, v), -_BIG, jnp.float32))
    keys = jnp.concatenate(ks)
    cnt = jnp.concatenate(cs)
    sums = jnp.concatenate(ss)
    mins = jnp.concatenate(mns)
    maxs = jnp.concatenate(mxs)
    k, end, tc, tsum, tmin, tmax = _segment_merge_groups(
        keys, cnt, sums, mins, maxs)
    sel = np.asarray(end) & (np.asarray(k) != _PAD_KEY)
    uk = np.asarray(k)[sel]
    uc = np.asarray(tc)[sel]
    us = np.asarray(tsum)[sel]
    umn = np.asarray(tmin)[sel]
    umx = np.asarray(tmax)[sel]
    return {int(key): [int(c), s, mn, mx]
            for key, c, s, mn, mx in zip(uk.tolist(), uc.tolist(),
                                         us, umn, umx)}


@dataclass
class OffloadResult:
    result: PipelineResult
    raw_bytes: int              # what a no-pushdown fetch would ship
    shipped_bytes: int          # what push-down actually ships

    @property
    def shipped_fraction(self) -> float:
        return self.shipped_bytes / max(1, self.raw_bytes)


def shard_table(mesh: Mesh, axis: str, rows: jnp.ndarray) -> jnp.ndarray:
    """Place a row matrix row-sharded over the pool axis (striping)."""
    n = rows.shape[0]
    size = mesh.shape[axis]
    pad = (-n) % size
    if pad:
        rows = jnp.pad(rows, ((0, pad), (0, 0)))
    return jax.device_put(rows, NamedSharding(mesh, P(axis, None)))


def run_offloaded(mesh: Mesh, axis: str, schema: FTable, pipeline: tuple,
                  rows_sharded: jnp.ndarray, n_valid: int,
                  *, interpret: bool | None = None) -> OffloadResult:
    """Execute pipeline near-data on every pool shard, merge client-side."""
    pipe = compile_pipeline(schema, tuple(pipeline), interpret=interpret)
    nshards = mesh.shape[axis]
    n_padded = rows_sharded.shape[0]
    per = n_padded // nshards

    # valid row counts per shard (tail shards may hold padding)
    starts = np.arange(nshards) * per
    valid = np.clip(n_valid - starts, 0, per).astype(np.int32)

    # Run the pipeline per shard. We express this as a simple loop over
    # shard slices rather than shard_map because the pipeline returns
    # host-side dicts (client merge); the dry-run/serving paths use the
    # jit'd shard_map far-KV engine instead. Device placement still holds:
    # each slice is resident on its owning device and the kernel executes
    # there (XLA keeps computation where operands live).
    partials: list[PipelineResult] = []
    for s in range(nshards):
        local = jax.lax.slice_in_dim(rows_sharded, s * per, (s + 1) * per)
        if schema.str_width:
            # string tables carry lengths in the last column? lengths are
            # provided by the caller via closure in client.py path.
            raise ValueError("string tables use run_offloaded_strings")
        # mask padding rows inside each shard: pipeline predicates operate on
        # valid rows only; we pass exact valid counts by slicing.
        local = local[:max(int(valid[s]), 0)]
        if local.shape[0] == 0:
            continue
        partials.append(pipe(local))

    raw_bytes = n_valid * schema.row_words * WORD_BYTES
    return OffloadResult(result=_merge(schema, pipeline, partials),
                         raw_bytes=raw_bytes,
                         shipped_bytes=sum(p.shipped_bytes or 0
                                           for p in partials))


def _merge(schema: FTable, pipeline: tuple,
           partials: list[PipelineResult], *,
           n_rows: int | None = None,
           part_rows: "list[np.ndarray] | None" = None) -> PipelineResult:
    """Client-side software merge of per-shard / per-node partials.

    The base behavior (offload engine) concatenates partials in shard
    order. The cluster scatter-gather path passes two extras that make the
    merged response byte-identical to a single-node dispatch:

      n_rows      the un-partitioned table's row count: rows-kind results
                  are rebuilt as the full (n_rows, width) packed buffer
                  (survivors front, zero tail) and mask-kind results as the
                  full row mask;
      part_rows   per-partial original-row index arrays (the partition
                  map), used to scatter mask partials back to their rows.

    When every rows-kind partial carries `sel_ids` (partition dispatch),
    survivors are spliced in original-row order — hash/skew partitions
    merge as byte-exactly as contiguous range partitions. A response
    encrypted per-node (post-crypt) is decrypted with each node's local
    keystream, spliced in the clear, and re-encrypted at merged positions
    (same involutive CTR cipher; the client holds the pipeline's key)."""
    if not partials:
        # nothing was dispatched (zero-row table): the empty result must
        # still have the pipeline's kind and response width — both come
        # from the canonical compiled plan, never re-derived here
        plan = compile_pipeline(schema, tuple(pipeline))
        if plan.kind == "mask":
            return PipelineResult(
                kind="mask", mask=jnp.zeros((n_rows or 0,), bool))
        if plan.kind == "groups":
            return PipelineResult(kind="groups", groups={})
        return PipelineResult(kind="rows", rows=jnp.zeros(
            (n_rows or 0, plan.response_width), jnp.float32), count=0)
    kind = partials[0].kind
    if kind == "rows":
        counts = [int(p.count) for p in partials]
        cpost = op_ir.crypt_post_of(pipeline) if n_rows is not None else None
        if cpost is not None:
            key = jnp.asarray(cpost.key, jnp.uint32)
            survivors = []
            for p, c in zip(partials, counts):
                # undo each node's local response crypt — survivors only:
                # they are packed at the front, and the keystream is
                # contiguous from position 0, so decrypt cost scales with
                # the RESULT size, not the partition size
                buf = jnp.asarray(p.rows, jnp.float32)[:c]
                dec = kref.ctr_crypt(buf.reshape(-1).view(jnp.uint32),
                                     key, cpost.nonce)
                survivors.append(dec.view(jnp.float32).reshape(buf.shape))
        else:
            survivors = [p.rows[:c] for p, c in zip(partials, counts)]
        rows = jnp.concatenate(survivors, axis=0)
        ids_list = [p.sel_ids for p in partials]
        merged_ids = None
        if all(i is not None for i in ids_list):
            merged_ids = np.concatenate(
                [np.asarray(i) for i in ids_list])
            if merged_ids.size and np.any(np.diff(merged_ids) < 0):
                # hash/skew partitions interleave; range partitions come
                # back already ordered and skip the gather entirely
                order = np.argsort(merged_ids)  # ids unique: original order
                rows = jnp.asarray(rows)[jnp.asarray(order)]
                merged_ids = merged_ids[order]
        count = int(rows.shape[0])
        if n_rows is not None:      # single-node-shaped packed response
            full = jnp.zeros((n_rows, int(rows.shape[1])), jnp.float32)
            full = full.at[:count].set(rows)
            if cpost is not None:   # re-encrypt at merged stream positions
                enc = kref.ctr_crypt(full.reshape(-1).view(jnp.uint32),
                                     jnp.asarray(cpost.key, jnp.uint32),
                                     cpost.nonce)
                full = enc.view(jnp.float32).reshape(full.shape)
            rows = full
        return PipelineResult(kind="rows", rows=rows, count=count,
                              sel_ids=merged_ids,
                              shipped_bytes=sum(p.shipped_bytes or 0
                                                for p in partials),
                              read_bytes=sum(p.read_bytes for p in partials))
    if kind == "groups":
        # device-side software merge: every partial's bucket table AND its
        # collision overflow rows concatenate into one segment-reduce
        # dispatch (merge_groups_device) — the Python dict loop this
        # replaces walked N x B buckets per cluster verb and was the
        # client-side serial floor under group scale-out
        merged = merge_groups_device(
            [p.groups for p in partials],
            partials[0].groups.get("drop_key"))
        return PipelineResult(kind="groups", groups=merged,
                              shipped_bytes=sum(p.shipped_bytes or 0
                                                for p in partials),
                              read_bytes=sum(p.read_bytes for p in partials))
    if kind == "mask":
        if part_rows is not None and n_rows is not None:
            # scatter each partition's per-row decisions back to the rows'
            # original positions (any partitioner, not just contiguous)
            full = np.zeros((n_rows,), bool)
            for p, idx in zip(partials, part_rows):
                idx = np.asarray(idx)
                full[idx] = np.asarray(p.mask)[: len(idx)]
            mask = jnp.asarray(full)
        else:
            mask = jnp.concatenate([p.mask for p in partials])
        return PipelineResult(kind="mask", mask=mask,
                              shipped_bytes=sum(p.shipped_bytes or 0
                                                for p in partials),
                              read_bytes=sum(p.read_bytes for p in partials))
    raise ValueError(kind)
