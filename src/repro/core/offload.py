"""Near-data offload engine: pipelines over a device-sharded pool (paper §4).

The single-node FarPool covers the paper's actual prototype (one FPGA node).
This module is the scale-out: the table's row matrix is sharded over the
mesh's pool axis (default "model"); `shard_map` runs the compiled pipeline
*on the device that owns the shard* (near-data), and only the reduced
results are exchanged:

  * rows kind:    per-shard packed survivors + counts are all-gathered
                  (variable-length response packets, like the RDMA sender);
  * groups kind:  per-shard partial aggregates (fixed B buckets) are shipped
                  and merged client-side — the multi-node generalization of
                  the paper's single hash table;
  * mask kind:    1 byte/row decisions.

`shipped_fraction` quantifies the data-movement reduction vs. fetching raw
rows — the metric behind Figs. 8-10.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import operators as op_ir
from repro.core.pipeline import PipelineResult, compile_pipeline
from repro.core.table import FTable, WORD_BYTES
from repro.kernels import ref as kref


@dataclass
class OffloadResult:
    result: PipelineResult
    raw_bytes: int              # what a no-pushdown fetch would ship
    shipped_bytes: int          # what push-down actually ships

    @property
    def shipped_fraction(self) -> float:
        return self.shipped_bytes / max(1, self.raw_bytes)


def shard_table(mesh: Mesh, axis: str, rows: jnp.ndarray) -> jnp.ndarray:
    """Place a row matrix row-sharded over the pool axis (striping)."""
    n = rows.shape[0]
    size = mesh.shape[axis]
    pad = (-n) % size
    if pad:
        rows = jnp.pad(rows, ((0, pad), (0, 0)))
    return jax.device_put(rows, NamedSharding(mesh, P(axis, None)))


def run_offloaded(mesh: Mesh, axis: str, schema: FTable, pipeline: tuple,
                  rows_sharded: jnp.ndarray, n_valid: int,
                  *, interpret: bool | None = None) -> OffloadResult:
    """Execute pipeline near-data on every pool shard, merge client-side."""
    pipe = compile_pipeline(schema, tuple(pipeline), interpret=interpret)
    nshards = mesh.shape[axis]
    n_padded = rows_sharded.shape[0]
    per = n_padded // nshards

    # valid row counts per shard (tail shards may hold padding)
    starts = np.arange(nshards) * per
    valid = np.clip(n_valid - starts, 0, per).astype(np.int32)

    # Run the pipeline per shard. We express this as a simple loop over
    # shard slices rather than shard_map because the pipeline returns
    # host-side dicts (client merge); the dry-run/serving paths use the
    # jit'd shard_map far-KV engine instead. Device placement still holds:
    # each slice is resident on its owning device and the kernel executes
    # there (XLA keeps computation where operands live).
    partials: list[PipelineResult] = []
    for s in range(nshards):
        local = jax.lax.slice_in_dim(rows_sharded, s * per, (s + 1) * per)
        if schema.str_width:
            # string tables carry lengths in the last column? lengths are
            # provided by the caller via closure in client.py path.
            raise ValueError("string tables use run_offloaded_strings")
        # mask padding rows inside each shard: pipeline predicates operate on
        # valid rows only; we pass exact valid counts by slicing.
        local = local[:max(int(valid[s]), 0)]
        if local.shape[0] == 0:
            continue
        partials.append(pipe(local))

    raw_bytes = n_valid * schema.row_words * WORD_BYTES
    return OffloadResult(result=_merge(schema, pipeline, partials),
                         raw_bytes=raw_bytes,
                         shipped_bytes=sum(p.shipped_bytes or 0
                                           for p in partials))


def _merge(schema: FTable, pipeline: tuple,
           partials: list[PipelineResult], *,
           n_rows: int | None = None,
           part_rows: "list[np.ndarray] | None" = None) -> PipelineResult:
    """Client-side software merge of per-shard / per-node partials.

    The base behavior (offload engine) concatenates partials in shard
    order. The cluster scatter-gather path passes two extras that make the
    merged response byte-identical to a single-node dispatch:

      n_rows      the un-partitioned table's row count: rows-kind results
                  are rebuilt as the full (n_rows, width) packed buffer
                  (survivors front, zero tail) and mask-kind results as the
                  full row mask;
      part_rows   per-partial original-row index arrays (the partition
                  map), used to scatter mask partials back to their rows.

    When every rows-kind partial carries `sel_ids` (partition dispatch),
    survivors are spliced in original-row order — hash/skew partitions
    merge as byte-exactly as contiguous range partitions. A response
    encrypted per-node (post-crypt) is decrypted with each node's local
    keystream, spliced in the clear, and re-encrypted at merged positions
    (same involutive CTR cipher; the client holds the pipeline's key)."""
    if not partials:
        # nothing was dispatched (zero-row table): the empty result must
        # still have the pipeline's kind and response width — both come
        # from the canonical compiled plan, never re-derived here
        plan = compile_pipeline(schema, tuple(pipeline))
        if plan.kind == "mask":
            return PipelineResult(
                kind="mask", mask=jnp.zeros((n_rows or 0,), bool))
        if plan.kind == "groups":
            return PipelineResult(kind="groups", groups={})
        return PipelineResult(kind="rows", rows=jnp.zeros(
            (n_rows or 0, plan.response_width), jnp.float32), count=0)
    kind = partials[0].kind
    if kind == "rows":
        counts = [int(p.count) for p in partials]
        cpost = op_ir.crypt_post_of(pipeline) if n_rows is not None else None
        if cpost is not None:
            key = jnp.asarray(cpost.key, jnp.uint32)
            survivors = []
            for p, c in zip(partials, counts):
                # undo each node's local response crypt — survivors only:
                # they are packed at the front, and the keystream is
                # contiguous from position 0, so decrypt cost scales with
                # the RESULT size, not the partition size
                buf = jnp.asarray(p.rows, jnp.float32)[:c]
                dec = kref.ctr_crypt(buf.reshape(-1).view(jnp.uint32),
                                     key, cpost.nonce)
                survivors.append(dec.view(jnp.float32).reshape(buf.shape))
        else:
            survivors = [p.rows[:c] for p, c in zip(partials, counts)]
        rows = jnp.concatenate(survivors, axis=0)
        ids_list = [p.sel_ids for p in partials]
        merged_ids = None
        if all(i is not None for i in ids_list):
            merged_ids = np.concatenate(
                [np.asarray(i) for i in ids_list])
            if merged_ids.size and np.any(np.diff(merged_ids) < 0):
                # hash/skew partitions interleave; range partitions come
                # back already ordered and skip the gather entirely
                order = np.argsort(merged_ids)  # ids unique: original order
                rows = jnp.asarray(rows)[jnp.asarray(order)]
                merged_ids = merged_ids[order]
        count = int(rows.shape[0])
        if n_rows is not None:      # single-node-shaped packed response
            full = jnp.zeros((n_rows, int(rows.shape[1])), jnp.float32)
            full = full.at[:count].set(rows)
            if cpost is not None:   # re-encrypt at merged stream positions
                enc = kref.ctr_crypt(full.reshape(-1).view(jnp.uint32),
                                     jnp.asarray(cpost.key, jnp.uint32),
                                     cpost.nonce)
                full = enc.view(jnp.float32).reshape(full.shape)
            rows = full
        return PipelineResult(kind="rows", rows=rows, count=count,
                              sel_ids=merged_ids,
                              shipped_bytes=sum(p.shipped_bytes or 0
                                                for p in partials),
                              read_bytes=sum(p.read_bytes for p in partials))
    if kind == "groups":
        merged: dict[int, list] = {}
        drop = partials[0].groups.get("drop_key")
        for p in partials:
            g = p.groups
            bk = np.asarray(g["bucket_keys"])
            cnt = np.asarray(g["count"])
            ssum = np.asarray(g["sum"])
            smin = np.asarray(g["min"])
            smax = np.asarray(g["max"])
            for i in range(bk.shape[0]):
                k = int(bk[i])
                if k == kref.KEY_SENTINEL or cnt[i] <= 0 or k == drop:
                    continue
                e = merged.setdefault(k, [0, 0.0, np.inf, -np.inf])
                e[0] += int(cnt[i])
                e[1] = e[1] + ssum[i]
                e[2] = np.minimum(e[2], smin[i])
                e[3] = np.maximum(e[3], smax[i])
            # client-side software merge of the shipped collision buffer
            for k, row in zip(g["ovf_keys"].tolist(), g["ovf_vals"]):
                if k == drop:
                    continue
                e = merged.setdefault(int(k), [0, 0.0, np.inf, -np.inf])
                e[0] += 1
                e[1] = e[1] + row
                e[2] = np.minimum(e[2], row)
                e[3] = np.maximum(e[3], row)
        return PipelineResult(kind="groups", groups=merged,
                              shipped_bytes=sum(p.shipped_bytes or 0
                                                for p in partials),
                              read_bytes=sum(p.read_bytes for p in partials))
    if kind == "mask":
        if part_rows is not None and n_rows is not None:
            # scatter each partition's per-row decisions back to the rows'
            # original positions (any partitioner, not just contiguous)
            full = np.zeros((n_rows,), bool)
            for p, idx in zip(partials, part_rows):
                idx = np.asarray(idx)
                full[idx] = np.asarray(p.mask)[: len(idx)]
            mask = jnp.asarray(full)
        else:
            mask = jnp.concatenate([p.mask for p in partials])
        return PipelineResult(kind="mask", mask=mask,
                              shipped_bytes=sum(p.shipped_bytes or 0
                                                for p in partials),
                              read_bytes=sum(p.read_bytes for p in partials))
    raise ValueError(kind)
