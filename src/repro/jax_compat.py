"""Version shims for jax APIs that moved between 0.4.x and current jax.

The repo targets current jax (`jax.shard_map`, `jax.set_mesh`,
`jax.sharding.AxisType`) but must run on the 0.4.x line too, where those
live under `jax.experimental.shard_map` / don't exist yet. Every call site
imports from here instead of feature-testing jax inline, so the support
matrix is defined in exactly one place.

Covered:
  make_mesh(shape, axes)      — `axis_types=(AxisType.Auto, ...)` when the
                                installed jax has AxisType, plain otherwise
                                (Auto is the 0.4.x implicit behaviour).
  shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=...)
                              — `jax.shard_map` when present, else the
                                experimental one with check_vma mapped onto
                                its old name `check_rep`.
  set_mesh(mesh)              — context manager; `jax.set_mesh` /
                                `jax.sharding.use_mesh` when present, else a
                                no-op (on 0.4.x every sharded entry point in
                                this repo passes its mesh explicitly).
  cost_analysis(compiled)     — normalizes the pre-0.5 list-of-dicts return
                                of `Compiled.cost_analysis()` to one dict.
"""
from __future__ import annotations

import contextlib

import jax

try:  # jax >= 0.5: explicit axis types on mesh creation
    _AXIS_TYPE = jax.sharding.AxisType
except AttributeError:  # 0.4.x: meshes are implicitly Auto
    _AXIS_TYPE = None


def make_mesh(shape, axes):
    """`jax.make_mesh` with Auto axis types where the kwarg exists."""
    if _AXIS_TYPE is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(_AXIS_TYPE.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


if hasattr(jax, "shard_map"):
    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
else:
    from jax.experimental.shard_map import shard_map as _shard_map_exp

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
        # pre-0.5 spelling: the replication check is `check_rep`
        return _shard_map_exp(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma)


def set_mesh(mesh):
    """Context manager installing `mesh` as the ambient mesh (best effort)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return contextlib.nullcontext(mesh)


def cost_analysis(compiled) -> dict:
    """`Compiled.cost_analysis()` as a single dict on every jax version."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return ca
