"""Pallas interpret-mode parity: the hash_group / hash_join kernels run
with `interpret=True` against the kernels/ref.py oracles, field for field.

This chips at the PR 1 follow-up ("a TPU run to validate the Pallas
lowering"): everything except the Mosaic compile itself is validated here —
BlockSpec structure, the one-hot MXU formulation, the bucket-sorted
block-local partials and their tree merge (PR 4), and the pad/unpad glue in
kernels/ops.py. What remains TPU-only is code generation, not semantics.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels import hash_group as hg
from repro.kernels import ref as kref


@pytest.mark.parametrize("n,card,nb,v", [
    (256, 10, 64, 1), (1024, 300, 128, 2), (512, 512, 32, 3),
    (2048, 7, 1024, 2),
])
def test_hash_group_raw_fields_vs_ref(rng, n, card, nb, v):
    """Raw kernel outputs == ref oracle: claims, counts and overflow are
    bit-identical; float aggregates match to tree-merge rounding."""
    keys = rng.integers(-card, card, size=n).astype(np.int32)
    vals = rng.normal(size=(n, v)).astype(np.float32)
    bk, cnt, s, mn, mx, ovf = hg.group_aggregate(
        jnp.asarray(keys[:, None]), jnp.asarray(vals),
        n_buckets=nb, interpret=True)
    r = kref.group_aggregate(jnp.asarray(keys), jnp.asarray(vals), nb)
    np.testing.assert_array_equal(np.asarray(bk[:, 0]),
                                  np.asarray(r["bucket_keys"]))
    np.testing.assert_array_equal(np.asarray(cnt[:, 0]),
                                  np.asarray(r["count"]))
    np.testing.assert_array_equal(np.asarray(ovf[:, 0]).astype(bool),
                                  np.asarray(r["overflow_mask"]))
    np.testing.assert_allclose(np.asarray(s), np.asarray(r["sum"]),
                               rtol=1e-4, atol=1e-4)
    # min/max of UNCLAIMED buckets carry the implementations' respective
    # identities (kernel 3.0e38 vs ref finfo.max) and are dropped by every
    # consumer; compare claimed buckets only
    claimed = np.asarray(cnt[:, 0]) > 0
    np.testing.assert_allclose(np.asarray(mn)[claimed],
                               np.asarray(r["min"])[claimed], rtol=1e-6)
    np.testing.assert_allclose(np.asarray(mx)[claimed],
                               np.asarray(r["max"])[claimed], rtol=1e-6)


def test_hash_group_integer_data_bit_identical(rng):
    """Integer-valued f32 data: every field, sums included, is exact."""
    keys = rng.integers(0, 100, size=1024).astype(np.int32)
    vals = rng.integers(-50, 50, size=(1024, 2)).astype(np.float32)
    bk, cnt, s, mn, mx, ovf = hg.group_aggregate(
        jnp.asarray(keys[:, None]), jnp.asarray(vals),
        n_buckets=128, interpret=True)
    r = kref.group_aggregate(jnp.asarray(keys), jnp.asarray(vals), 128)
    claimed = np.asarray(cnt[:, 0]) > 0
    np.testing.assert_array_equal(np.asarray(s), np.asarray(r["sum"]))
    np.testing.assert_array_equal(np.asarray(mn)[claimed],
                                  np.asarray(r["min"])[claimed])
    np.testing.assert_array_equal(np.asarray(mx)[claimed],
                                  np.asarray(r["max"])[claimed])


@pytest.mark.parametrize("blocks", [1, 2, 3, 5, 8])
def test_tree_merge_any_block_count(rng, blocks):
    """The log-depth pairwise merge handles odd levels via identity pads
    and equals a flat reduction for any partial count."""
    b, v = 16, 2
    cnt = rng.integers(0, 9, size=(blocks, b, 1)).astype(np.int32)
    s = rng.normal(size=(blocks, b, v)).astype(np.float32)
    mn = rng.normal(size=(blocks, b, v)).astype(np.float32)
    mx = rng.normal(size=(blocks, b, v)).astype(np.float32)
    tc, ts, tmn, tmx = hg.tree_merge(jnp.asarray(cnt), jnp.asarray(s),
                                     jnp.asarray(mn), jnp.asarray(mx))
    np.testing.assert_array_equal(np.asarray(tc), cnt.sum(0))
    np.testing.assert_allclose(np.asarray(ts), s.sum(0), rtol=1e-5,
                               atol=1e-5)
    np.testing.assert_array_equal(np.asarray(tmn), mn.min(0))
    np.testing.assert_array_equal(np.asarray(tmx), mx.max(0))


def test_segment_spans_and_segmented_reduce(rng):
    """The shared sort-based segment helpers in ref.py (used by the XLA
    path, the Pallas prologue and the cluster group merge)."""
    ids = np.sort(rng.integers(0, 10, size=64)).astype(np.int32)
    start, end, nonempty = kref.segment_spans(jnp.asarray(ids), 12)
    for seg in range(12):
        where = np.nonzero(ids == seg)[0]
        assert bool(nonempty[seg]) == (len(where) > 0)
        if len(where):
            assert int(start[seg]) == where[0]
            assert int(end[seg]) == where[-1]
    vals = rng.normal(size=(64, 2)).astype(np.float32)
    flags = np.concatenate([[True], ids[1:] != ids[:-1]])
    s, mn, mx = kref.segmented_reduce(
        jnp.asarray(vals), jnp.asarray(vals), jnp.asarray(vals),
        jnp.asarray(flags))
    for seg in range(12):
        where = np.nonzero(ids == seg)[0]
        if not len(where):
            continue
        i = where[-1]
        np.testing.assert_allclose(np.asarray(s)[i], vals[where].sum(0),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(np.asarray(mn)[i], vals[where].min(0))
        np.testing.assert_array_equal(np.asarray(mx)[i], vals[where].max(0))


@pytest.mark.parametrize("n,k,v", [(256, 8, 1), (512, 40, 3), (256, 1, 2)])
def test_hash_join_raw_vs_ref(rng, n, k, v):
    probe = rng.integers(0, 64, size=n).astype(np.int32)
    bkeys = rng.permutation(64)[:k].astype(np.int32)
    bvals = rng.normal(size=(k, v)).astype(np.float32)
    # pad to the kernel's tile contract exactly as ops.py does
    from repro.kernels import ops as kops
    joined, hit = kops.hash_join(jnp.asarray(probe), jnp.asarray(bkeys),
                                 jnp.asarray(bvals), interpret=True)
    rj, rh = kref.hash_join(probe, bkeys, bvals)
    np.testing.assert_array_equal(np.asarray(hit), rh)
    np.testing.assert_allclose(np.asarray(joined), rj, rtol=1e-6)


def test_hash_join_empty_build(rng):
    """K=0 (an empty co-partitioned build shard): no probe row matches, on
    both the Pallas pad path and the XLA lowering."""
    from repro.kernels import ops as kops
    probe = rng.integers(0, 64, size=128).astype(np.int32)
    empty_k = jnp.zeros((0,), jnp.int32)
    empty_v = jnp.zeros((0, 2), jnp.float32)
    joined, hit = kops.hash_join(jnp.asarray(probe), empty_k, empty_v,
                                 interpret=True)
    assert not np.asarray(hit).any()
    assert not np.asarray(joined).any()
    joined, hit = kops.hash_join_xla(jnp.asarray(probe), empty_k, empty_v)
    assert not np.asarray(hit).any()
    assert np.asarray(joined).shape == (128, 2)
