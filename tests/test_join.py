"""Small-table join (paper §Conclusions future work, implemented):
kernel vs oracle sweeps + end-to-end pipeline + hypothesis property."""
import numpy as np
import jax.numpy as jnp
import pytest

# hypothesis is optional: only the property test needs it — the
# deterministic kernel/pipeline tests below must keep running without it
try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import operators as op
from repro.core.client import (FViewNode, alloc_table_mem, farview_request,
                               open_connection, table_write)
from repro.core.table import FTable, Column
from repro.kernels import ops as kops
from repro.kernels import ref as kref


@pytest.mark.parametrize("n,k,v", [(100, 8, 1), (1000, 64, 3), (257, 37, 2),
                                   (4096, 200, 4), (1, 1, 1)])
def test_hash_join_vs_oracle(rng, n, k, v):
    bk = rng.permutation(10 * k)[:k].astype(np.int32)
    bv = rng.normal(size=(k, v)).astype(np.float32)
    pk = rng.integers(0, 10 * k, n).astype(np.int32)
    j, h = kops.hash_join(jnp.asarray(pk), jnp.asarray(bk), jnp.asarray(bv))
    rj, rh = kref.hash_join(pk, bk, bv)
    np.testing.assert_array_equal(np.asarray(h), rh)
    np.testing.assert_allclose(np.asarray(j), rj, rtol=1e-6)


def test_hash_join_rejects_duplicate_build_keys(rng):
    bk = np.asarray([1, 2, 2], np.int32)
    bv = np.ones((3, 1), np.float32)
    with pytest.raises(ValueError):
        kops.hash_join(jnp.asarray(np.ones(10, np.int32)), jnp.asarray(bk),
                       jnp.asarray(bv))


def test_join_pipeline_end_to_end(rng):
    node = FViewNode(64 * 2**20)
    qp = open_connection(node)
    orders = FTable("orders", (Column("cust", "i32"), Column("amount")),
                    n_rows=1024)
    alloc_table_mem(qp, orders)
    od = {"cust": rng.integers(0, 50, 1024).astype(np.int32),
          "amount": rng.random(1024).astype(np.float32)}
    table_write(qp, orders, orders.encode(od))
    cust = FTable("customers", (Column("cust", "i32"),
                                Column("discount")), n_rows=20)
    alloc_table_mem(qp, cust)
    ck = rng.permutation(50)[:20].astype(np.int32)
    cd = {"cust": ck, "discount": rng.random(20).astype(np.float32)}
    table_write(qp, cust, cust.encode(cd))

    pipe = (op.Select((op.Predicate("amount", "<", 0.5),)),
            op.JoinSmall(probe_key="cust", build_table="customers",
                         build_key="cust", build_cols=("discount",)))
    res = farview_request(qp, orders, pipe)
    mask = (od["amount"] < 0.5) & np.isin(od["cust"], ck)
    assert int(res.count) == int(mask.sum())
    lut = {int(k): float(d) for k, d in zip(cd["cust"], cd["discount"])}
    got = np.asarray(res.rows[: int(res.count)])
    for row in got:
        np.testing.assert_allclose(row[2], lut[int(round(row[0]))],
                                   rtol=1e-5)


def test_join_then_group_rejected(rng):
    from repro.core.pipeline import compile_pipeline
    ft = FTable("t", (Column("k", "i32"), Column("v")), n_rows=8)
    bad = (op.JoinSmall("k", "b", "k", ("v",)), op.GroupBy("k", ("v",)))
    with pytest.raises(ValueError):
        compile_pipeline(ft, bad)


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=20)
    @given(n=st.integers(1, 500), k=st.integers(1, 60),
           seed=st.integers(0, 2**31 - 1))
    def test_join_hit_count_property(n, k, seed):
        """#survivors == |{probe keys} ∩ {build keys}| occurrences."""
        rng = np.random.default_rng(seed)
        bk = rng.permutation(200)[:k].astype(np.int32)
        bv = rng.normal(size=(k, 1)).astype(np.float32)
        pk = rng.integers(0, 200, n).astype(np.int32)
        _, h = kops.hash_join(jnp.asarray(pk), jnp.asarray(bk),
                              jnp.asarray(bv))
        assert int(np.asarray(h).sum()) == int(np.isin(pk, bk).sum())
else:
    @pytest.mark.skip(reason="optional dep: pip install hypothesis")
    def test_join_hit_count_property():
        pass
