"""Substrate tests: optimizer, checkpoint (atomic/async/elastic), data
pipeline determinism, gradient compression, sharding rules."""
import os
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim import adamw


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
class TestAdamW:
    def test_quadratic_convergence(self):
        cfg = adamw.AdamWConfig(learning_rate=0.1, warmup_steps=1,
                                total_steps=300, weight_decay=0.0,
                                schedule="const", grad_clip=100.0)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = adamw.init(params)

        @jax.jit
        def step(params, state):
            loss, g = jax.value_and_grad(
                lambda p: jnp.sum(p["w"] ** 2))(params)
            p, s, m = adamw.update(cfg, params, g, state)
            return p, s, loss

        for _ in range(300):
            params, state, loss = step(params, state)
        assert float(loss) < 1e-3

    def test_grad_clip(self):
        g = {"w": jnp.full((4,), 100.0)}
        clipped, gn = adamw.clip_by_global_norm(g, 1.0)
        assert abs(float(adamw.global_norm(clipped)) - 1.0) < 1e-5
        assert float(gn) == pytest.approx(200.0)

    def test_schedule_warmup_and_decay(self):
        cfg = adamw.AdamWConfig(learning_rate=1.0, warmup_steps=10,
                                total_steps=100, schedule="cosine",
                                min_lr_frac=0.1)
        lrs = [float(adamw.lr_at(cfg, jnp.asarray(s)))
               for s in [0, 4, 9, 50, 99]]
        assert lrs[0] < lrs[1] < lrs[2]               # warming up
        assert lrs[2] == pytest.approx(1.0, rel=1e-3)
        assert lrs[3] > lrs[4]                        # decaying
        assert lrs[4] >= 0.1 * 0.99                   # floor

    def test_weight_decay_decoupled(self):
        cfg = adamw.AdamWConfig(learning_rate=0.1, weight_decay=0.5,
                                warmup_steps=1, schedule="const",
                                grad_clip=1e9)
        params = {"w": jnp.asarray([1.0])}
        state = adamw.init(params)
        zero_g = {"w": jnp.asarray([0.0])}
        p2, _, _ = adamw.update(cfg, params, zero_g, state)
        # pure decay step: w -> w * (1 - lr*wd)
        assert float(p2["w"][0]) == pytest.approx(1.0 - 0.1 * 0.5, rel=1e-4)


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------
class TestCheckpoint:
    def _tree(self):
        return {"params": {"a": jnp.arange(12.0).reshape(3, 4),
                           "nested": {"b": jnp.ones((5,), jnp.bfloat16)}},
                "opt": (jnp.zeros(3), jnp.ones(2))}

    def test_roundtrip_sync(self):
        from repro.checkpoint.manager import CheckpointManager
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d)
            cm.save(3, self._tree(), {"loss": 1.5})
            tree, meta = cm.restore()
            assert meta["step"] == 3 and meta["loss"] == 1.5
            np.testing.assert_array_equal(
                tree["params"]["a"], np.arange(12.0).reshape(3, 4))
            assert isinstance(tree["opt"], tuple)

    def test_async_and_retention(self):
        from repro.checkpoint.manager import CheckpointManager
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d, keep_last=2)
            for s in (1, 2, 3, 4):
                cm.save(s, self._tree(), asynchronous=True)
                cm.wait()
            assert cm.all_steps() == [3, 4]

    def test_atomicity_no_partial_dirs(self):
        from repro.checkpoint.manager import CheckpointManager
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d)
            cm.save(1, self._tree())
            # a stale tmp dir must never be listed as a checkpoint
            os.makedirs(os.path.join(d, "step_9.tmp"))
            assert cm.all_steps() == [1]
            assert cm.latest_step() == 1

    def test_elastic_restore_reshard(self):
        """Saved unsharded -> restored with explicit shardings (new mesh)."""
        from repro.checkpoint.manager import CheckpointManager
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.jax_compat import make_mesh
        mesh = make_mesh((1,), ("model",))
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d)
            cm.save(1, self._tree())
            sh = NamedSharding(mesh, P())
            shardings = jax.tree.map(lambda _: sh, self._tree())
            tree, _ = cm.restore(shardings=shardings)
            leaf = tree["params"]["a"]
            assert isinstance(leaf, jax.Array)
            assert leaf.sharding == sh

    def test_restore_empty_dir(self):
        from repro.checkpoint.manager import CheckpointManager
        with tempfile.TemporaryDirectory() as d:
            cm = CheckpointManager(d)
            tree, meta = cm.restore()
            assert tree is None and meta is None


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
class TestData:
    def test_determinism_and_step_dependence(self):
        from repro.data.pipeline import TokenPipeline
        tp = TokenPipeline(vocab=100, seq_len=32, global_batch=4, seed=1)
        b0a, b0b, b1 = tp.batch_at(0), tp.batch_at(0), tp.batch_at(1)
        np.testing.assert_array_equal(b0a["tokens"], b0b["tokens"])
        assert not np.array_equal(b0a["tokens"], b1["tokens"])
        # labels are next-token shifted
        np.testing.assert_array_equal(b0a["tokens"][:, 1:],
                                      b0a["labels"][:, :-1])

    def test_host_sharding_partition(self):
        from repro.data.pipeline import TokenPipeline
        full = TokenPipeline(vocab=50, seq_len=16, global_batch=8, seed=2)
        parts = [TokenPipeline(vocab=50, seq_len=16, global_batch=8, seed=2,
                               host_index=i, host_count=4) for i in range(4)]
        got = [p.batch_at(5)["tokens"] for p in parts]
        assert all(g.shape == (2, 16) for g in got)
        # different hosts draw different slices
        assert not np.array_equal(got[0], got[1])

    def test_prefetcher(self):
        from repro.data.pipeline import TokenPipeline, Prefetcher
        tp = TokenPipeline(vocab=50, seq_len=16, global_batch=2, seed=3)
        pf = Prefetcher(tp, start_step=7)
        step, batch = pf.next()
        assert step == 7
        np.testing.assert_array_equal(np.asarray(batch["tokens"]),
                                      tp.batch_at(7)["tokens"])
        pf.close()

    def test_skip_ahead_restart_semantics(self):
        """Restart at step k reproduces exactly the batches a continuous
        run would have seen (fault-tolerance invariant)."""
        from repro.data.pipeline import TokenPipeline
        tp = TokenPipeline(vocab=100, seq_len=8, global_batch=2, seed=4)
        run1 = [tp.batch_at(s)["tokens"] for s in range(10)]
        tp2 = TokenPipeline(vocab=100, seq_len=8, global_batch=2, seed=4)
        run2 = [tp2.batch_at(s)["tokens"] for s in range(5, 10)]
        for a, b in zip(run1[5:], run2):
            np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------
class TestCompression:
    def test_error_feedback_preserves_mean_signal(self):
        from repro.distributed import compress as C
        rng = np.random.default_rng(0)
        g_true = {"w": jnp.asarray(rng.normal(size=(256,)) * 1e-3)}
        err = C.init_error_state(g_true)
        acc = np.zeros(256)
        for _ in range(50):
            g, err = C.compress_grads(g_true, err)
            acc += np.asarray(g["w"])
        # accumulated compressed grads converge to accumulated true grads
        np.testing.assert_allclose(acc / 50, np.asarray(g_true["w"]),
                                   atol=2e-6)

    def test_compression_ratio(self):
        from repro.distributed import compress as C
        g = {"w": jnp.zeros((1000,)), "b": jnp.zeros((24,))}
        assert C.compressed_bytes(g) == 1024 + 8
        assert C.raw_bytes(g) == 4096


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------
class TestSharding:
    def test_param_specs_cover_all_archs(self):
        from repro.configs import ARCHS, get_config
        from repro.distributed import sharding as S
        from repro.launch.mesh import make_test_mesh
        from repro.models.lm import LM
        mesh = make_test_mesh((1, 1), ("data", "model"))
        for arch in ARCHS:
            cfg = get_config(arch)
            lm = LM(cfg)
            shapes = jax.eval_shape(lm.init, jax.random.PRNGKey(0))
            specs = S.param_specs(shapes, mesh, cfg)
            # every leaf got a PartitionSpec of the right rank
            def check(sd, sp):
                assert len(sp) <= len(sd.shape)
            jax.tree.map(check, shapes, specs)

    def test_divisibility_fallback(self):
        """Indivisible dims are replicated, not failed."""
        from repro.distributed.sharding import param_spec
        from repro.launch.mesh import make_test_mesh
        from jax.sharding import PartitionSpec as P
        mesh = make_test_mesh((1, 1), ("data", "model"))
        # prime dims can never shard over >1 axes; with 1x1 mesh they can
        spec = param_spec("groups/attn_0/attn/wq", (4, 7, 13), mesh)
        assert isinstance(spec, P)
