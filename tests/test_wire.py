"""Wire-format robustness (src/repro/net/wire.py).

Three layers of defense, each tested:

  1. value codec — every type the verbs carry round-trips exactly,
     including the operator-IR dataclasses the scheduler keys on (the
     parity guarantee starts here: identical bytes in, identical
     dispatch key out);
  2. framing — headers with bad magic / version / type / length and
     truncated or trailing payloads raise the typed `ProtocolError`,
     never hang and never mis-parse;
  3. typed errors — `encode_error`/`decode_error` rebuild the SAME
     exception class cross-process, which is what PR 6 failover keys
     its retry-vs-reroute decision on.

A hypothesis property sweep runs when the extra is installed
(importorskip — the CI image has it, a bare checkout may not).
"""
from __future__ import annotations

import struct

import numpy as np
import pytest

from repro.core import operators as op_ir
from repro.core.client import FarviewError, NodeDeadError
from repro.core.table import Column, FTable
from repro.distributed.health import (DroppedDispatchError, OverloadedError,
                                      ReplicaUnavailableError)
from repro.net import wire
from repro.net.wire import ProtocolError


def roundtrip(obj):
    return wire.decode_value(wire.encode_value(obj))


# -------------------------------------------------------------- value codec
SCALARS = [None, True, False, 0, 1, -1, 2**62, -(2**62), 2**100, -(2**100),
           0.0, -1.5, 3.141592653589793, "", "héllo ✓", b"", b"\x00\xff",
           np.int32(7), np.float64(2.5), np.bool_(True)]


@pytest.mark.parametrize("obj", SCALARS, ids=[repr(s)[:24] for s in SCALARS])
def test_scalar_roundtrip(obj):
    got = roundtrip(obj)
    if isinstance(obj, (np.integer, np.floating, np.bool_)):
        obj = obj.item()        # numpy scalars normalize to python scalars
    assert got == obj and type(got) is type(obj)


def test_container_roundtrip():
    obj = {"a": [1, 2.5, "x", None], "b": (True, b"raw", (1, (2,))),
           3: {"nested": [(), [], {}]}}
    assert roundtrip(obj) == obj
    # tuple vs list identity is preserved (dispatch keys hash tuples)
    assert isinstance(roundtrip((1, 2)), tuple)
    assert isinstance(roundtrip([1, 2]), list)


@pytest.mark.parametrize("arr", [
    np.arange(12, dtype=np.float32).reshape(3, 4),
    np.array([], dtype=np.int64),
    np.array(2.5),                              # 0-d
    np.arange(8, dtype=np.uint8)[::2],          # non-contiguous
    np.array([[1, 2], [3, 4]], dtype=np.int32).T,
])
def test_ndarray_roundtrip(arr):
    got = roundtrip(arr)
    np.testing.assert_array_equal(got, np.ascontiguousarray(arr))
    assert got.dtype == arr.dtype and got.shape == arr.shape
    # the decoded array owns its memory (not a view of the frame buffer)
    assert got.flags.owndata or got.ndim == 0


def test_operator_ir_roundtrip():
    pipeline = (
        op_ir.Crypt(key=(1234, 5678), nonce=99, when="pre"),
        op_ir.Project(cols=("a", "b")),
        op_ir.Select(predicates=(op_ir.Predicate("a", "<", 0.5),
                                 op_ir.Predicate("b", ">=", -1.0))),
        op_ir.GroupBy(key="a", values=("b",), aggs=("count", "sum"),
                      n_buckets=512),
        op_ir.Pack(),
    )
    got = roundtrip(pipeline)
    assert got == pipeline
    assert all(type(g) is type(p) for g, p in zip(got, pipeline))
    # equality AND hash survive: the server-side coalescing key is the
    # same frozen dataclass tuple the in-process scheduler uses
    assert hash(got) == hash(pipeline)


def test_ftable_roundtrip():
    ft = FTable("t", (Column("a"), Column("s", "str")), n_rows=100,
                str_width=16, table_id=3, pages=(0, 1, 2))
    got = roundtrip(ft)
    assert got == ft and isinstance(got.columns[0], Column)


def test_unregistered_types_are_rejected_at_encode():
    class NotWire:
        pass
    with pytest.raises(TypeError, match="wire-encode"):
        wire.encode_value({"x": NotWire()})


# ----------------------------------------------------------------- framing
def test_frame_roundtrip_and_empty_payload():
    buf = wire.encode_frame(wire.SUBMIT, 42, {"qp": 1})
    assert wire.decode_frame(buf) == (wire.SUBMIT, 42, {"qp": 1})
    ftype, rid, obj = wire.decode_frame(wire.encode_frame(wire.FLUSH, 7))
    assert (ftype, rid, obj) == (wire.FLUSH, 7, None)


def test_bad_headers_raise_typed_errors():
    good = wire.encode_frame(wire.OK, 1, {})
    with pytest.raises(ProtocolError, match="truncated header"):
        wire.parse_header(good[:10])
    bad_magic = b"XX" + good[2:wire.HEADER_SIZE]
    with pytest.raises(ProtocolError, match="bad magic"):
        wire.parse_header(bad_magic)
    bad_ver = good[:2] + b"\x63" + good[3:wire.HEADER_SIZE]
    with pytest.raises(ProtocolError, match="version"):
        wire.parse_header(bad_ver)
    bad_type = good[:3] + b"\xee" + good[4:wire.HEADER_SIZE]
    with pytest.raises(ProtocolError, match="unknown frame type"):
        wire.parse_header(bad_type)


def test_oversized_length_field_is_rejected_before_allocation():
    hdr = wire.HEADER.pack(wire.MAGIC, wire.VERSION, wire.OK, 1, 2**31)
    with pytest.raises(ProtocolError, match="exceeds"):
        wire.parse_header(hdr)
    # and a tighter per-server bound applies when configured
    hdr2 = wire.HEADER.pack(wire.MAGIC, wire.VERSION, wire.OK, 1, 1 << 20)
    with pytest.raises(ProtocolError, match="exceeds"):
        wire.parse_header(hdr2, max_payload=1 << 16)


def test_truncated_and_trailing_payloads_raise():
    payload = wire.encode_value({"k": np.arange(4.0), "s": "abcdef"})
    for cut in (1, len(payload) // 2, len(payload) - 1):
        with pytest.raises(ProtocolError):
            wire.decode_value(payload[:cut])
    with pytest.raises(ProtocolError, match="trailing"):
        wire.decode_value(payload + b"\x00")


def test_garbage_payload_bytes_raise_not_hang():
    rng = np.random.default_rng(0)
    for _ in range(64):
        junk = rng.integers(0, 256, size=rng.integers(1, 80),
                            dtype=np.uint8).tobytes()
        try:
            wire.decode_value(junk)
        except ProtocolError:
            pass        # typed failure is the contract; success is luck


def test_malformed_ndarray_and_dataclass_payloads():
    with pytest.raises(ProtocolError, match="dtype"):
        wire.decode_value(b"a" + struct.pack(">I", 3) + b"zzz" + b"\x00")
    arr = wire.encode_value(np.arange(4, dtype=np.int64))
    # corrupt the raw-bytes length so shape*itemsize != payload
    with pytest.raises(ProtocolError):
        wire.decode_value(arr[:-8])
    name = b"NotRegistered"
    bad = b"D" + struct.pack(">I", len(name)) + name + b"t" + b"\x00" * 4
    with pytest.raises(ProtocolError, match="unknown wire dataclass"):
        wire.decode_value(bad)
    # right class, wrong arity
    name = b"Project"
    bad = (b"D" + struct.pack(">I", len(name)) + name
           + b"t" + struct.pack(">I", 2) + b"N" + b"N")
    with pytest.raises(ProtocolError, match="bad field tuple"):
        wire.decode_value(bad)


# ------------------------------------------------------------- typed errors
@pytest.mark.parametrize("exc, code, cls", [
    (NodeDeadError(3, op="submit"), wire.E_NODE_DEAD, NodeDeadError),
    (DroppedDispatchError(2), wire.E_DROPPED, DroppedDispatchError),
    (ReplicaUnavailableError("no replica for t"), wire.E_REPLICA,
     ReplicaUnavailableError),
    (OverloadedError(1, detail="queue full"), wire.E_OVERLOADED,
     OverloadedError),
    (ProtocolError("bad magic"), wire.E_PROTOCOL, ProtocolError),
    (FarviewError("boom"), wire.E_GENERIC, FarviewError),
    (MemoryError("pool out of pages"), wire.E_MEMORY, MemoryError),
])
def test_error_codes_rebuild_same_type(exc, code, cls):
    payload = wire.encode_error(exc)
    assert payload["code"] == code
    back = roundtrip(payload)           # errors travel as a value payload
    rebuilt = wire.decode_error(back)
    assert type(rebuilt) is cls


def test_error_payload_carries_failover_fields():
    payload = wire.encode_error(NodeDeadError(5, op="flush"))
    rebuilt = wire.decode_error(roundtrip(payload))
    assert rebuilt.node_id == 5 and rebuilt.op == "flush"
    payload = wire.encode_error(OverloadedError(2, detail="tenant share"))
    rebuilt = wire.decode_error(roundtrip(payload))
    assert rebuilt.node_id == 2 and rebuilt.detail == "tenant share"
    # an unclassified exception degrades to FarviewError, never crashes
    rebuilt = wire.decode_error(
        roundtrip(wire.encode_error(RuntimeError("??"), node_id=4)))
    assert isinstance(rebuilt, FarviewError)


# ------------------------------------------------- property sweep (optional)
# guard with a plain try so ONLY these tests skip when the extra is
# missing (a module-level importorskip would skip the whole file)
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                                 # pragma: no cover
    st = None

if st is not None:
    _scalars = (st.none() | st.booleans()
                | st.integers(min_value=-2**80, max_value=2**80)
                | st.floats(allow_nan=False)
                | st.text(max_size=40) | st.binary(max_size=40))
    _values = st.recursive(
        _scalars,
        lambda kids: (st.lists(kids, max_size=5)
                      | st.lists(kids, max_size=5).map(tuple)
                      | st.dictionaries(st.text(max_size=8), kids,
                                        max_size=5)),
        max_leaves=25)

    @settings(max_examples=200, deadline=None)
    @given(_values)
    def test_property_value_roundtrip(obj):
        assert roundtrip(obj) == obj

    @settings(max_examples=200, deadline=None)
    @given(st.binary(max_size=120))
    def test_property_garbage_never_hangs_or_leaks(junk):
        try:
            wire.decode_value(junk)
        except ProtocolError:
            pass

    @settings(max_examples=100, deadline=None)
    @given(st.binary(min_size=wire.HEADER_SIZE, max_size=wire.HEADER_SIZE))
    def test_property_header_parse_is_total(hdr):
        try:
            wire.parse_header(hdr)
        except ProtocolError:
            pass
else:
    def test_property_sweep_requires_hypothesis():
        pytest.skip("hypothesis extra not installed")
