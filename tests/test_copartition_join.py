"""Co-partitioned build-probe joins (PR 4 tentpole).

Contract: when a build table is allocated with `co_partition=<probe>`, its
rows land on whichever node the probe's key rule assigned that key, so

  (a) every node answers the join from its LOCAL build shard and the
      merged result is byte-identical to solo AND to the replicated
      broadcast path, for hash and skew probes at 1..4 nodes;
  (b) the build table is written exactly ONCE cluster-wide
      (bytes_written == single-copy size, vs N x under replicate=True);
  (c) a probe with no key rule (range partitioned) silently falls back to
      the replicated broadcast layout — co-location is impossible there;
  (d) a build that is partitioned but NOT co-partitioned with the probe is
      refused loudly (a silent scatter would drop matches).
"""
import numpy as np
import pytest

from repro.core import operators as op
from repro.core.client import (FarviewError, FViewNode, alloc_table_mem,
                               farview_request, open_connection, table_write)
from repro.core.cluster import FarCluster
from repro.core.table import FTable, Column
from repro.distributed.sharding import CoPartition, co_partition_spec

N = 700
PCOLS = tuple(Column(f"c{i}", "i32" if i == 0 else "f32") for i in range(6))
BCOLS = (Column("k", "i32"), Column("v"), Column("w"))
PIPE = (op.JoinSmall(probe_key="c0", build_table="dim",
                     build_key="k", build_cols=("v", "w")),)


@pytest.fixture(scope="module")
def tables():
    rng = np.random.default_rng(17)
    d = {"c0": rng.integers(0, 96, N).astype(np.int32)}
    for i in range(1, 6):
        d[f"c{i}"] = rng.integers(-50, 50, N).astype(np.float32)
    bk = rng.permutation(128)[:64].astype(np.int32)   # half the keys match
    bd = {"k": bk, "v": rng.integers(0, 99, 64).astype(np.float32),
          "w": rng.integers(0, 99, 64).astype(np.float32)}
    return d, bd


def solo_ref(tables):
    d, bd = tables
    node = FViewNode(64 * 2**20)
    qp = open_connection(node)
    bft = FTable("dim", BCOLS, n_rows=64)
    alloc_table_mem(qp, bft)
    table_write(qp, bft, bft.encode(bd))
    ft = FTable("t", PCOLS, n_rows=N)
    alloc_table_mem(qp, ft)
    table_write(qp, ft, ft.encode(d))
    return farview_request(qp, ft, PIPE).finalize()


def cluster_join(tables, k, partitioner, *, co: bool):
    d, bd = tables
    cl = FarCluster(k)
    cqp = cl.open_connection()
    ct = cl.alloc_table_mem(cqp, FTable("t", PCOLS, n_rows=N),
                            partitioner=partitioner, keys=d["c0"])
    cl.table_write(cqp, ct, FTable("t", PCOLS, n_rows=N).encode(d))
    bft = FTable("dim", BCOLS, n_rows=64)
    w0 = cl.stats.bytes_written
    if co:
        cb = cl.alloc_table_mem(cqp, bft, co_partition=ct, keys=bd["k"])
    else:
        cb = cl.alloc_table_mem(cqp, bft, replicate=True)
    cl.table_write(cqp, cb, bft.encode(bd))
    written = cl.stats.bytes_written - w0
    res = cl.farview_request(cqp, ct, PIPE).finalize()
    return res, written, cb, cl


@pytest.mark.parametrize("partitioner", ("hash", "skew"))
@pytest.mark.parametrize("k", (1, 2, 3, 4))
def test_byte_identical_and_single_copy(tables, k, partitioner):
    ref = solo_ref(tables)
    res, written, cb, _ = cluster_join(tables, k, partitioner, co=True)
    assert res.count == ref.count
    np.testing.assert_array_equal(np.asarray(res.rows), np.asarray(ref.rows))
    assert res.shipped_bytes == ref.shipped_bytes
    # (b) no build replicas: exactly the single-copy bytes hit the pools
    assert written == FTable("dim", BCOLS, n_rows=64).n_bytes
    assert not cb.replicated


@pytest.mark.parametrize("k", (2, 3))
def test_matches_replicated_path(tables, k):
    co_res, co_written, _, _ = cluster_join(tables, k, "hash", co=True)
    re_res, re_written, _, _ = cluster_join(tables, k, "hash", co=False)
    np.testing.assert_array_equal(np.asarray(co_res.rows),
                                  np.asarray(re_res.rows))
    assert co_res.count == re_res.count
    single = FTable("dim", BCOLS, n_rows=64).n_bytes
    assert co_written == single
    assert re_written == k * single       # the broadcast join's N x cost


def test_empty_build_shards_allocated(tables):
    """A key distribution can leave a node with ZERO build rows; the shard
    is still allocated + cataloged so that node's local join resolves (and
    finds no matches, correctly)."""
    d, bd = tables
    cl = FarCluster(4)
    cqp = cl.open_connection()
    ct = cl.alloc_table_mem(cqp, FTable("t", PCOLS, n_rows=N),
                            partitioner="skew", keys=d["c0"])
    cl.table_write(cqp, ct, FTable("t", PCOLS, n_rows=N).encode(d))
    # a single-key build: 3 of 4 nodes own zero build rows
    bft = FTable("dim", BCOLS, n_rows=1)
    bd1 = {"k": bd["k"][:1], "v": bd["v"][:1], "w": bd["w"][:1]}
    cb = cl.alloc_table_mem(cqp, bft, co_partition=ct, keys=bd1["k"])
    assert all(p is not None for p in cb.parts)
    assert sum(p.n_rows == 0 for p in cb.parts) >= 3
    cl.table_write(cqp, cb, bft.encode(bd1))
    res = cl.farview_request(cqp, ct, PIPE).finalize()
    exp = int((d["c0"] == int(bd1["k"][0])).sum())
    assert res.count == exp


def test_range_probe_falls_back_to_replicate(tables):
    d, bd = tables
    cl = FarCluster(3)
    cqp = cl.open_connection()
    ct = cl.alloc_table_mem(cqp, FTable("t", PCOLS, n_rows=N))   # range
    cl.table_write(cqp, ct, FTable("t", PCOLS, n_rows=N).encode(d))
    bft = FTable("dim", BCOLS, n_rows=64)
    cb = cl.alloc_table_mem(cqp, bft, co_partition=ct, keys=bd["k"])
    assert cb.replicated       # (c) automatic broadcast fallback
    cl.table_write(cqp, cb, bft.encode(bd))
    ref = solo_ref(tables)
    res = cl.farview_request(cqp, ct, PIPE).finalize()
    np.testing.assert_array_equal(np.asarray(res.rows), np.asarray(ref.rows))


def test_incompatible_build_layout_refused(tables):
    d, bd = tables
    cl = FarCluster(2)
    cqp = cl.open_connection()
    ct = cl.alloc_table_mem(cqp, FTable("t", PCOLS, n_rows=N),
                            partitioner="hash", keys=d["c0"])
    cl.table_write(cqp, ct, FTable("t", PCOLS, n_rows=N).encode(d))
    bft = FTable("dim", BCOLS, n_rows=64)
    cb = cl.alloc_table_mem(cqp, bft)          # range-partitioned build
    cl.table_write(cqp, cb, bft.encode(bd))
    with pytest.raises(FarviewError, match="co-partitioned"):
        cl.farview_request(cqp, ct, PIPE)


def test_spec_only_matches_itself(tables):
    """Co-location holds only for the CAPTURED spec object: a spec does
    not know which column its keys came from, so two structurally-equal
    hash rules (same n_parts) must NOT count as co-located — a probe
    hash-partitioned on a non-join column would silently drop matches."""
    h2a = co_partition_spec("hash", 2, np.arange(10))
    h2b = co_partition_spec("hash", 2, np.arange(99))
    sk = co_partition_spec("skew", 2, np.asarray([1, 1, 1, 2, 3]))
    assert h2a.compatible_with(h2a)
    assert not h2a.compatible_with(h2b)
    assert sk.compatible_with(sk)
    assert not sk.compatible_with(h2a)
    assert not h2a.compatible_with(None)


def test_probe_partitioned_on_other_column_refused(tables):
    """Probe hash-partitioned on a NON-join column, build hash-partitioned
    on the join key: same rule shape, different key domain — equal join
    keys are NOT co-located, so the dispatch must refuse rather than
    return a silently-partial join."""
    d, bd = tables
    cl = FarCluster(2)
    cqp = cl.open_connection()
    ct = cl.alloc_table_mem(cqp, FTable("t", PCOLS, n_rows=N),
                            partitioner="hash", keys=d["c1"])   # not c0!
    cl.table_write(cqp, ct, FTable("t", PCOLS, n_rows=N).encode(d))
    bft = FTable("dim", BCOLS, n_rows=64)
    cb = cl.alloc_table_mem(cqp, bft, partitioner="hash", keys=bd["k"])
    cl.table_write(cqp, cb, bft.encode(bd))
    with pytest.raises(FarviewError, match="co-partitioned"):
        cl.farview_request(cqp, ct, PIPE)


def test_replicated_probe_partitioned_build_refused(tables):
    """A replicated probe is served whole from node 0, which holds only
    node 0's shard of a partitioned build — refuse instead of silently
    dropping the other shards' matches."""
    d, bd = tables
    cl = FarCluster(2)
    cqp = cl.open_connection()
    ct = cl.alloc_table_mem(cqp, FTable("t", PCOLS, n_rows=N),
                            replicate=True)
    cl.table_write(cqp, ct, FTable("t", PCOLS, n_rows=N).encode(d))
    bft = FTable("dim", BCOLS, n_rows=64)
    cb = cl.alloc_table_mem(cqp, bft, partitioner="hash", keys=bd["k"])
    cl.table_write(cqp, cb, bft.encode(bd))
    with pytest.raises(FarviewError, match="co-partitioned"):
        cl.farview_request(cqp, ct, PIPE)
    # a replicated build serves the replicated probe fine
    cl2 = FarCluster(2)
    cqp2 = cl2.open_connection()
    ct2 = cl2.alloc_table_mem(cqp2, FTable("t", PCOLS, n_rows=N),
                              replicate=True)
    cl2.table_write(cqp2, ct2, FTable("t", PCOLS, n_rows=N).encode(d))
    cb2 = cl2.alloc_table_mem(cqp2, bft, replicate=True)
    cl2.table_write(cqp2, cb2, bft.encode(bd))
    ref = solo_ref(tables)
    res = cl2.farview_request(cqp2, ct2, PIPE).finalize()
    assert res.count == ref.count


def test_co_partition_owner_consistency():
    """The same key always lands on the same node as the referenced
    partitioning put it — including keys the probe never held (hash rule
    fallback for skew)."""
    rng = np.random.default_rng(23)
    probe_keys = rng.integers(0, 50, 400)
    for kind in ("hash", "skew"):
        spec = co_partition_spec(kind, 3, probe_keys)
        assert isinstance(spec, CoPartition)
        from repro.distributed.sharding import partition_rows
        parts = partition_rows(400, 3, kind, keys=probe_keys)
        owner = np.empty(400, np.int64)
        for i, p in enumerate(parts):
            owner[p] = i
        np.testing.assert_array_equal(spec.owners_of(probe_keys), owner)
        # unseen keys are still assigned deterministically in range
        unseen = spec.owners_of(np.arange(1000, 1050))
        assert ((unseen >= 0) & (unseen < 3)).all()
    assert co_partition_spec("range", 3, probe_keys) is None
    assert co_partition_spec("hash", 3, None) is None
